"""Thread-safety of the compile/lower caches.

The process-wide compile cache used to be a plain dict with bare int
counter mutation; per-machine μProgram Memories make concurrent compile
traffic likelier (one service thread per machine), so both the
:class:`~repro.core.trace.TraceCache` and the lowering memo are now
lock-guarded.  These tests hammer them from multiple threads and assert
the invariants a race would break: exact counters, one compile per key,
and bounded size.
"""
import threading

import pytest

from repro.core.trace import (GLOBAL_TRACE_CACHE, TraceCache, compile_trace,
                              lower_program)
from repro.core.uprogram import AAP, DRow, P_T0, UProgram

OPS = ("addition", "subtraction", "greater", "relu")
WIDTHS = (4, 8)
THREADS = 2
ROUNDS = 40


def _hammer(cache, errors, barrier, check_identity=True):
    try:
        barrier.wait(timeout=30)
        for r in range(ROUNDS):
            for op in OPS:
                for n in WIDTHS:
                    prog, trace = cache.get(op, n, True)
                    again, t2 = cache.get(op, n, True)
                    # an unbounded cache must hand every thread the same
                    # objects (a bounded one may legitimately re-compile
                    # after a concurrent eviction)
                    if check_identity and (again is not prog
                                           or t2 is not trace):
                        raise AssertionError(
                            f"cache returned different objects for {op}/{n}")
    except BaseException as e:       # noqa: BLE001 — surfaced by the test
        errors.append(e)


def test_two_thread_compile_stress_exact_counters():
    cache = TraceCache()
    errors: list = []
    barrier = threading.Barrier(THREADS)
    threads = [threading.Thread(target=_hammer,
                                args=(cache, errors, barrier))
               for _ in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    st = cache.stats()
    total = THREADS * ROUNDS * len(OPS) * len(WIDTHS) * 2
    n_keys = len(OPS) * len(WIDTHS)
    # lock-guarded counters are exact: every get is a hit or a miss, and
    # each key compiled exactly once process-wide
    assert st["hits"] + st["misses"] == total
    assert st["misses"] == n_keys
    assert st["entries"] == n_keys


def test_threaded_gets_against_bounded_cache():
    """Eviction under contention: the cache never exceeds its capacity and
    the counters still balance."""
    cache = TraceCache(capacity=3)
    errors: list = []
    barrier = threading.Barrier(THREADS)
    threads = [threading.Thread(target=_hammer,
                                args=(cache, errors, barrier, False))
               for _ in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    st = cache.stats()
    assert st["entries"] <= 3
    assert st["hits"] + st["misses"] == THREADS * ROUNDS * len(OPS) * \
        len(WIDTHS) * 2
    assert st["evictions"] == st["misses"] - st["entries"]


def test_threaded_lower_memo():
    """Concurrent lower_program on a shared set of ad-hoc μPrograms: one
    trace per program object, no torn LRU state."""
    progs = [UProgram(name=f"toy{i}", n_bits=4,
                      prologue=[AAP(DRow("a", 0), (P_T0,))],
                      body=[], body_reps=0, inputs=("a",), outputs=("a",))
             for i in range(8)]
    results: dict[int, list] = {i: [] for i in range(len(progs))}
    errors: list = []
    barrier = threading.Barrier(THREADS)

    def worker():
        try:
            barrier.wait(timeout=30)
            for _ in range(ROUNDS):
                for i, p in enumerate(progs):
                    results[i].append(lower_program(p))
        except BaseException as e:   # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    for i, traces in results.items():
        assert len(traces) == THREADS * ROUNDS
        assert all(t is traces[0] for t in traces), f"prog {i} re-lowered"


def test_global_cache_is_the_shared_instance():
    prog, trace = compile_trace("addition", 8)
    assert GLOBAL_TRACE_CACHE.get("addition", 8)[1] is trace
    assert ("addition", 8, True) in GLOBAL_TRACE_CACHE


def test_trace_cache_rejects_bad_capacity():
    with pytest.raises(ValueError):
        TraceCache(capacity=0)
