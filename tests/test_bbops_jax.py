"""bbop public API under jit (Table 1 ISA surface)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ops import (bbop_abs, bbop_add, bbop_bitcount, bbop_div,
                       bbop_equal, bbop_greater, bbop_if_else, bbop_max,
                       bbop_mul, bbop_relu, bbop_sub, bbop_xor)

RNG = np.random.default_rng(3)
N = 100
A = jnp.array(RNG.integers(0, 256, N), jnp.int32)
B = jnp.array(RNG.integers(0, 256, N), jnp.int32)
An, Bn = np.asarray(A), np.asarray(B)


@pytest.mark.parametrize("fn,exp", [
    (lambda: bbop_add(A, B, 8), (An + Bn) & 255),
    (lambda: bbop_sub(A, B, 8), (An - Bn) & 255),
    (lambda: bbop_mul(A, B, 8), (An * Bn) & 255),
    (lambda: bbop_div(A, jnp.maximum(B, 1), 8), An // np.maximum(Bn, 1)),
    (lambda: bbop_greater(A, B, 8), (An > Bn).astype(np.int32)),
    (lambda: bbop_greater(A, B, 8, signed=True),
     (An.astype(np.int8) > Bn.astype(np.int8)).astype(np.int32)),
    (lambda: bbop_equal(A, B, 8), (An == Bn).astype(np.int32)),
    (lambda: bbop_relu(A, 8), np.where(An.astype(np.int8) >= 0, An, 0)),
    (lambda: bbop_abs(A, 8), np.abs(An.astype(np.int8).astype(int)) & 255),
    (lambda: bbop_max(A, B, 8), np.maximum(An, Bn)),
    (lambda: bbop_bitcount(A, 8),
     np.array([bin(x).count("1") for x in An.tolist()])),
    (lambda: bbop_xor([A, B, A], 8), An ^ Bn ^ An),
])
def test_bbop(fn, exp):
    np.testing.assert_array_equal(np.asarray(fn()), exp)


def test_bbop_under_jit_and_vmap_lanes():
    f = jax.jit(lambda x, y: bbop_add(x, y, 8))
    np.testing.assert_array_equal(np.asarray(f(A, B)), (An + Bn) & 255)


def test_predication_example_from_paper_listing1():
    """Paper Listing 1: C = (A > pred) ? A+B : A−B."""
    pred = jnp.array(RNG.integers(0, 256, N), jnp.int32)
    d = bbop_add(A, B, 8)
    e = bbop_sub(A, B, 8)
    f = bbop_greater(A, pred, 8)
    c = bbop_if_else(f, d, e, 8)
    exp = np.where(An > np.asarray(pred), (An + Bn) & 255, (An - Bn) & 255)
    np.testing.assert_array_equal(np.asarray(c), exp)
