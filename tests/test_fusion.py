"""Cross-op trace fusion: whole pipelines compiled to ONE LoweredTrace.

Covers the compiler chain pass (``compile_chain``/``fuse_chain``), the
chain-aware TraceCache (signature keys + invalidation), seam lint for
fused traces, the ``fused_trace=True`` pipeline recorder, scheduling of
fused chains as single FR-FCFS units, and the fused-vs-unfused parity /
movement-elision / replay-latency claims."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.circuits import (compile_operation, register_operation,
                                 unregister_operation)
from repro.core.circuits import rebase
from repro.core.compiler import (ChainStage, chain_signature, compile_chain,
                                 fuse_chain)
from repro.core.trace import (CMD_COPY, GLOBAL_TRACE_CACHE, TraceCache,
                              canonical_uops, compile_chain_trace,
                              lower_program)
from repro.core.tracelint import lint_graph
from repro.core.graph import LogicGraph
from repro.core.uprogram import concat_programs
from repro.ops import (bbop_abs, bbop_add, bbop_greater, bbop_if_else,
                       bbop_mul, bbop_relu, bbop_sub, simdram_pipeline)
from repro.simdram.machine import SimdramMachine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAS_HYPOTHESIS = False

RNG = np.random.default_rng(0xF05E)
N = 96

STAGES_3 = (("addition", ("a", "b"), "v0"),
            ("subtraction", ("v0", "a"), "v1"),
            ("relu", ("v1",), "v2"))


def _chain_fn(a, b, n_bits, with_mul=True):
    x = bbop_add(a, b, n_bits)
    if with_mul:
        x = bbop_mul(x, a, n_bits)
    x = bbop_sub(x, b, n_bits)
    return bbop_relu(x, n_bits)


# ---------------------------------------------------------------------------
# fused ≡ unfused parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "unrolled", "pallas"])
@pytest.mark.parametrize("banked", [False, True])
@pytest.mark.parametrize("n_bits", [4, 8, 16])
def test_fused_matches_unfused(backend, banked, n_bits):
    """The fused single-trace pipeline is bit-exact against the per-op
    pipeline on every backend × bankedness × element width."""
    hi = 1 << n_bits
    shape = (2, 64) if banked else (N,)
    av = jnp.asarray(RNG.integers(0, hi, shape), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, hi, shape), jnp.int32)
    with_mul = n_bits <= 8               # cap trace size at wide widths
    outs = []
    for fused in (False, True):
        with simdram_pipeline(backend=backend,
                              banks=2 if banked else None,
                              fused_trace=fused) as p:
            a, b = p.load([av, bv], n_bits)
            outs.append(np.asarray(p.store(_chain_fn(a, b, n_bits,
                                                     with_mul))))
    np.testing.assert_array_equal(outs[0], outs[1])


@pytest.mark.parametrize("k", [2, 3, 4, 5, 6, 7, 8])
def test_chain_lengths_parity(k):
    """2- through 8-op chains: fused output equals unfused output."""
    av = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    steps = [lambda x, a, b: bbop_add(x, b, 8),
             lambda x, a, b: bbop_sub(x, b, 8),
             lambda x, a, b: bbop_relu(x, 8),
             lambda x, a, b: bbop_abs(x, 8),
             lambda x, a, b: bbop_mul(x, a, 8)]
    outs = []
    for fused in (False, True):
        with simdram_pipeline(fused_trace=fused) as p:
            a, b = p.load([av, bv], 8)
            x = a
            for i in range(k):
                x = steps[i % len(steps)](x, a, b)
            outs.append(np.asarray(p.store(x)))
    np.testing.assert_array_equal(outs[0], outs[1])


def _random_chain_case(rng):
    n_bits = int(rng.choice([4, 8]))
    hi = 1 << n_bits
    av = jnp.asarray(rng.integers(0, hi, 64), jnp.int32)
    bv = jnp.asarray(rng.integers(0, hi, 64), jnp.int32)
    k = int(rng.integers(2, 7))
    picks = rng.integers(0, 5, k)
    u_pick = rng.integers(0, 1 << 30, k)       # mod live value count at use
    v_pick = rng.integers(0, 1 << 30, k)
    outs = []
    for fused in (False, True):
        with simdram_pipeline(fused_trace=fused) as p:
            a, b = p.load([av, bv], n_bits)
            vals = [a, b]
            for i, which in enumerate(picks):
                u = vals[int(u_pick[i]) % len(vals)]
                v = vals[int(v_pick[i]) % len(vals)]
                x = [lambda: bbop_add(u, v, n_bits),
                     lambda: bbop_sub(u, v, n_bits),
                     lambda: bbop_mul(u, v, n_bits),
                     lambda: bbop_relu(u, n_bits),
                     lambda: bbop_abs(u, n_bits)][int(which)]()
                vals.append(x)
            outs.append(np.asarray(p.store(vals[-1])))
    np.testing.assert_array_equal(outs[0], outs[1])


if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(hst.integers(0, 2 ** 32 - 1))
    def test_random_chain_sweep(seed):
        """Hypothesis sweep: random DAG-shaped chains stay bit-exact."""
        _random_chain_case(np.random.default_rng(seed))
else:                                                  # pragma: no cover
    @pytest.mark.parametrize("seed", range(12))
    def test_random_chain_sweep(seed):
        """Seeded sweep (hypothesis unavailable): random chains stay
        bit-exact."""
        _random_chain_case(np.random.default_rng(seed))


# ---------------------------------------------------------------------------
# compiler + IR
# ---------------------------------------------------------------------------


def test_ir_roundtrip_decode():
    """decode(fused trace) reproduces the chain μProgram's canonical μOps,
    and the seam metadata tiles the whole trace."""
    trace = fuse_chain(STAGES_3, 8)
    prog = compile_chain(STAGES_3, 8)
    assert trace.decode() == canonical_uops(prog)
    chain = trace.chain
    assert chain is not None and chain.n_stages == 3
    assert chain.ops == ("addition", "subtraction", "relu")
    assert chain.stages[0].seq_start == 0
    for prev, cur in zip(chain.stages, chain.stages[1:]):
        assert cur.seq_start == prev.seq_end
    assert chain.stages[-1].seq_end == len(trace.seqs)
    assert chain.elided_rows > 0


def test_fused_trace_lints_clean():
    report = fuse_chain(STAGES_3, 8).lint()
    assert not report.errors


def test_chain_allocation_reuses_rows():
    """The fused allocator shares rows across op boundaries: the fused
    trace needs fewer D-rows than the constituent ops summed."""
    trace = fuse_chain(STAGES_3, 8)
    per_op = sum(len(lower_program(compile_operation(op, 8)).d_rows)
                 for op in ("addition", "subtraction", "relu"))
    assert len(trace.d_rows) < per_op
    assert trace.chain.elided_rows == per_op - len(trace.d_rows)


def test_compile_chain_validation():
    with pytest.raises(ValueError, match="at least one stage"):
        compile_chain([], 8)
    with pytest.raises(ValueError, match="redefin"):
        compile_chain([("addition", ("a", "b"), "v0"),
                       ("relu", ("v0",), "v0")], 8)
    with pytest.raises(ValueError, match="2 operand"):
        compile_chain([("addition", ("a",), "v0")], 8)
    with pytest.raises(ValueError, match="not produced by any stage"):
        compile_chain(STAGES_3, 8, outputs=("nope",))


def test_chain_signature_and_stage_coercion():
    sig = chain_signature([ChainStage("relu", ("a",), "v0")])
    assert sig == chain_signature([("relu", "a", "v0")])
    assert sig.startswith("chain:")
    assert chain_signature(STAGES_3, outputs=("v2",)) != \
        chain_signature(STAGES_3)


# ---------------------------------------------------------------------------
# TraceCache: chain keys + invalidation (the bugfix regression)
# ---------------------------------------------------------------------------


def test_chain_cache_hits_on_signature():
    cache = TraceCache(capacity=8)
    p1, t1 = cache.get_chain(STAGES_3, 8)
    h0 = cache._hits
    p2, t2 = cache.get_chain(list(STAGES_3), 8)       # same signature
    assert t1 is t2 and p1 is p2
    assert cache._hits == h0 + 1
    _, t3 = cache.get_chain(STAGES_3, 8, outputs=("v0", "v2"))
    assert t3 is not t1                                # distinct key
    assert t3.outputs == ("v0", "v2")


def _compile_twiceadd(n_bits, optimize=True):
    p1 = rebase(compile_operation("addition", n_bits, optimize), {},
                {"out": "_s"})
    p2 = rebase(compile_operation("addition", n_bits, optimize), {},
                {"a": "_s", "out": "out"})
    return concat_programs("twiceadd", [p1, p2], n_bits,
                           inputs=("a", "b"), outputs=("out",),
                           scratch=("_s",))


def test_invalidate_evicts_stale_chain_entries_everywhere():
    """Redefining/unregistering an op must evict every fused chain entry
    whose signature references it — in EVERY live cache, including
    entries keyed by chain signature rather than by the op's own name."""
    register_operation("twiceadd", _compile_twiceadd)
    try:
        stages = [("twiceadd", ("a", "b"), "t0"), ("relu", ("t0",), "t1")]
        other = TraceCache(capacity=8)
        compile_chain_trace(stages, 8)                 # global cache
        other.get_chain(stages, 8)
        assert any("twiceadd" in k[0] for k in GLOBAL_TRACE_CACHE._entries)
        assert any("twiceadd" in k[0] for k in other._entries)
        register_operation("twiceadd", _compile_twiceadd, override=True)
        for cache in (GLOBAL_TRACE_CACHE, other):
            assert not any("twiceadd" in k[0] for k in cache._entries), \
                "stale fused chain survived op redefinition"
    finally:
        unregister_operation("twiceadd")


def test_machine_redefine_evicts_named_chain():
    """A machine-registered chain caches under its own name but still
    references its constituent ops: redefining one evicts it."""
    def build_xor(g):
        g.add_output("out", g.gate_xor(g.input("a"), g.input("b")))

    def build_and(g):
        g.add_output("out", g.gate_and(g.input("a"), g.input("b")))

    m = SimdramMachine(backend="unrolled")
    m.define_op("xorish", build_xor)
    chain = m.define_chain("xchain", [("xorish", ("a", "b"), "t0"),
                                      ("xorish", ("t0", "b"), "t1")])
    a = jnp.full((32,), 6, jnp.int32)
    b = jnp.full((32,), 3, jnp.int32)
    out = np.asarray(chain(a, b, n_bits=8))
    np.testing.assert_array_equal(out, (6 ^ 3) ^ 3)    # xor∘xor
    assert any(getattr(t.chain, "ops", None) == ("xorish",)
               for _p, t in m.memory._entries.values())
    m.define_op("xorish", build_and, override=True)
    assert not any(getattr(t.chain, "ops", None) == ("xorish",)
                   for _p, t in m.memory._entries.values()), \
        "stale fused chain survived machine op redefinition"
    out2 = np.asarray(chain(a, b, n_bits=8))
    np.testing.assert_array_equal(out2, (6 & 3) & 3)   # and∘and now


# ---------------------------------------------------------------------------
# TraceLint: seams + user graphs
# ---------------------------------------------------------------------------


def test_seam_clobber_diagnostic():
    """A stage overwriting another stage's still-live value rows is a
    seam-clobber error on the fused trace."""
    prog, trace = compile_chain_trace(
        [("addition", ("a", "b"), "v0"),
         ("subtraction", ("v0", "a"), "v1")], 4, outputs=("v0", "v1"))
    assert not trace.lint().errors
    row = trace.row_index[("v0", 0)]
    s1 = trace.chain.stages[1]
    cmds = np.array(trace.cmds, copy=True)
    target = next(i for i in range(s1.cmd_start, s1.cmd_end)
                  if cmds[i, 0] == CMD_COPY and abs(int(cmds[i, 1])) != row)
    cmds[target, 1] = row                              # clobber v0's bit 0
    bad = dataclasses.replace(trace, cmds=cmds, _lint=None,
                              _fingerprint=None, _decoded=None,
                              _act_struct=None)
    codes = {d.kind for d in bad.lint().errors}
    assert "seam-clobber" in codes


def test_lint_graph_diagnostics():
    g = LogicGraph()
    g.input("a")
    assert any(d.kind == "graph-no-outputs"
               for d in lint_graph(g).errors)

    g2 = LogicGraph()
    x = g2.gate_and(g2.input("a"), g2.input("b"))
    g2.add_output("out", x)
    rep = lint_graph(g2)
    assert not rep.errors

    g3 = LogicGraph()
    a3 = g3.input("a")
    g3.input("unused")
    g3.add_output("out", a3)
    rep3 = lint_graph(g3)
    assert not rep3.errors
    assert any(d.kind == "graph-unused-input" for d in rep3.diagnostics)

    g4 = LogicGraph()
    a4 = g4.input("a")
    g4.add_output("out", a4)
    g4.outputs.append(("out", a4))                     # duplicate name
    assert any(d.kind == "graph-dup-output"
               for d in lint_graph(g4).errors)

    g5 = LogicGraph()
    g5.add_output("out", 9999)                         # dangling literal
    assert any(d.kind == "graph-bad-literal"
               for d in lint_graph(g5).errors)


def test_define_op_lints_user_graph():
    m = SimdramMachine()
    with pytest.raises(Exception, match="graph-bad-literal"):
        m.define_op("dangling", lambda g: g.add_output("out", 9999))


# ---------------------------------------------------------------------------
# machine: define_chain + scheduling as one FR-FCFS unit
# ---------------------------------------------------------------------------


def test_define_chain_validation():
    m = SimdramMachine()
    with pytest.raises(ValueError, match=">= 1 stage"):
        m.define_chain("empty", [])
    with pytest.raises(ValueError, match="itself"):
        m.define_chain("loop", [("loop", ("a",), "t0")])


def test_define_chain_submit_drain_single_request():
    m = SimdramMachine(backend="unrolled")
    m.define_chain("fma_relu", [("addition", ("a", "b"), "t0"),
                                ("multiplication", ("t0", "a"), "t1"),
                                ("relu", ("t1",), "t2")])
    av = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    an, bn = np.asarray(av), np.asarray(bv)
    t1 = (((an + bn) & 255) * an) & 255
    ref = np.where(t1 < 128, t1, 0)

    fut = m.submit("fma_relu", av, bv, n_bits=8)
    sched = m.drain()
    assert sched.n_requests == 1                      # ONE FR-FCFS unit
    r = sched.requests[0]
    assert [op for op, _ in r.fused_stages] == \
        ["addition", "multiplication", "relu"]
    assert all(n > 0 for _, n in r.fused_stages)
    assert sum(r.stage_split().values()) == pytest.approx(r.service_ns)
    np.testing.assert_array_equal(np.asarray(fut.result()), ref)

    # unfused submissions of the same ops schedule as three requests
    m.submit("addition", av, bv, n_bits=8)
    m.submit("multiplication", av, av, n_bits=8)
    m.submit("relu", av, n_bits=8)
    sched3 = m.drain()
    assert sched3.n_requests == 3
    assert all(not r.fused_stages for r in sched3.requests)
    r0 = sched3.requests[0]
    assert r0.stage_split() == {r0.name: r0.service_ns}


# ---------------------------------------------------------------------------
# movement elision + replay latency (the provable wins)
# ---------------------------------------------------------------------------


def test_fused_pipeline_elides_movement_hops():
    av = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    stats = {}
    for fused in (False, True):
        with simdram_pipeline(timed=True, fused_trace=fused) as p:
            a, b = p.load([av, bv], 8)
            p.store(_chain_fn(a, b, 8))
        stats[fused] = p.stats
    unf, fus = stats[False], stats[True]
    assert unf.n_moves_intra == 3          # one hop per chained operand
    assert fus.n_moves_intra == 0          # the fused allocator elided all
    assert fus.n_moves_elided == unf.n_moves_intra
    assert fus.movement_intra_ns == 0.0
    snap = fus.snapshot()
    assert snap["movement"]["per_kind"]["elided"]["n"] == 3
    assert fus.n_programs == 1 and unf.n_programs == 4
    # per-op attribution survives fusion: one row per constituent op
    assert set(fus.per_op) == set(unf.per_op)
    assert sum(d["ns"] for d in fus.per_op.values()) == \
        pytest.approx(fus.exec_ns)


def test_fused_replay_not_worse_than_unfused():
    """Replayed latency of the fused trace ≤ the phase-threaded unfused
    chain (the boundary tRC gap replaces each op's trailing tRAS+tRP)."""
    av = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    replay = {}
    for fused in (False, True):
        with simdram_pipeline(model="replay", refresh_phase=True,
                              fused_trace=fused) as p:
            a, b = p.load([av, bv], 8)
            p.store(_chain_fn(a, b, 8))
        replay[fused] = p.stats.replay_ns
    assert replay[True] <= replay[False] + 1e-6


# ---------------------------------------------------------------------------
# recorder edge cases
# ---------------------------------------------------------------------------


def test_fused_pipeline_seals_on_unfusible_op():
    """A width-changing op (greater → 1 bit) runs eagerly, sealing the
    pending chain; the overall result stays exact."""
    av = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    outs = []
    for fused in (False, True):
        with simdram_pipeline(fused_trace=fused) as p:
            a, b = p.load([av, bv], 8)
            s = bbop_add(a, b, 8)
            sel = bbop_greater(s, a, 8)                # out_bits=1: eager
            outs.append(np.asarray(p.store(
                bbop_if_else(sel, s, b, 8))))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_fused_pipeline_multiple_stored_values():
    """Every recorded value is retrievable — intermediates included."""
    av = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with simdram_pipeline(fused_trace=True) as p:
        a, b = p.load([av, bv], 8)
        x = bbop_add(a, b, 8)
        y = bbop_mul(x, a, 8)
        rx, ry = p.store(x, y)
    an, bn = np.asarray(av), np.asarray(bv)
    np.testing.assert_array_equal(np.asarray(rx), (an + bn) & 255)
    np.testing.assert_array_equal(np.asarray(ry),
                                  (((an + bn) & 255) * an) & 255)


def test_fused_pipeline_banked_chain():
    av = jnp.asarray(RNG.integers(0, 256, (4, 64)), jnp.int32)
    bv = jnp.asarray(RNG.integers(0, 256, (4, 64)), jnp.int32)
    outs = []
    for fused in (False, True):
        with simdram_pipeline(banks=4, fused_trace=fused) as p:
            a, b = p.load([av, bv], 8)
            outs.append(np.asarray(p.store(_chain_fn(a, b, 8))))
    np.testing.assert_array_equal(outs[0], outs[1])
