"""Training infrastructure: optimizer, microbatching, checkpoint/restore,
failover, straggler monitoring."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.failover import (FailoverConfig, FailoverRunner,
                                        StragglerMonitor)
from repro.models.params import init_params
from repro.models.transformer import model_defs
from repro.train.data import DataConfig, synthetic_batch
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

CFG = get_reduced("qwen1_5_0_5b")


def _state(seed=0, compressed=False):
    params = init_params(model_defs(CFG), jax.random.key(seed))
    return init_train_state(params, compressed=compressed)


def _batch(step=0, b=4, s=64):
    d = DataConfig(vocab=CFG.vocab, seq_len=s, global_batch=b)
    return synthetic_batch(d, step)


def test_loss_decreases_over_steps():
    state = _state()
    step = jax.jit(make_train_step(CFG, AdamWConfig(lr=3e-3)))
    first = last = None
    for s in range(20):
        state, m = step(state, _batch(s))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first


def test_microbatch_accumulation_matches_full_batch():
    """Accumulated microbatch gradients equal the full-batch gradient (up to
    bf16 reduction-order noise)."""
    import dataclasses
    from repro.models.params import init_params as _ip
    from repro.train.train_step import make_loss_fn
    cfg32 = dataclasses.replace(CFG, compute_dtype="float32")
    state = init_train_state(_ip(model_defs(cfg32), jax.random.key(0)))
    loss_fn = make_loss_fn(cfg32)
    grad = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))
    b = _batch(0, b=8)
    g_full = grad(state.params, b)
    half1 = jax.tree.map(lambda x: x[:4], b)
    half2 = jax.tree.map(lambda x: x[4:], b)
    g_acc = jax.tree.map(lambda x, y: (x + y) / 2,
                         grad(state.params, half1),
                         grad(state.params, half2))
    for a, c in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, blocking=True)
    assert mgr.latest_step() == 7
    restored = mgr.restore(7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_atomicity(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.steps() == [3, 4]
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_failover_restores_after_persistent_failure(tmp_path):
    state = _state()
    opt = AdamWConfig(lr=1e-3)
    raw_step = jax.jit(make_train_step(CFG, opt))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, state, blocking=True)
    boom = {"armed": True}

    def injector(step):
        if step == 3 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    runner = FailoverRunner(raw_step, mgr,
                            FailoverConfig(checkpoint_every=2, max_retries=0),
                            failure_injector=injector)
    final, hist = runner.run(state, lambda s: _batch(s), 0, 6)
    assert any("restored" in e for e in runner.events)
    assert int(final.opt.step) >= 6


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(factor=3.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 1.0)
    assert mon.flagged and mon.flagged[0][0] == 10


def test_data_pipeline_determinism_and_sharding():
    d = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    b1 = synthetic_batch(d, 5)
    b2 = synthetic_batch(d, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    half = synthetic_batch(d, 5, lo=4, hi=8)
    np.testing.assert_array_equal(np.asarray(half["tokens"]),
                                  np.asarray(b1["tokens"][4:8]))
    assert not np.array_equal(np.asarray(synthetic_batch(d, 6)["tokens"]),
                              np.asarray(b1["tokens"]))


def test_majority_vote_compression_math():
    """Packed sign majority == elementwise sign-of-sum (SIMDRAM TRA lifted
    to gradient aggregation)."""
    import jax.numpy as jnp
    from repro.train.train_step import _majority_from_packed, _pack_signs
    rng = np.random.default_rng(0)
    grads = rng.normal(size=(5, 130)).astype(np.float32)
    packed = jnp.stack([_pack_signs(jnp.asarray(g)) for g in grads])
    maj = _majority_from_packed(packed, 5, 130)
    votes = (grads >= 0).sum(0)
    exp = np.where(2 * votes > 5, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(maj), exp)
