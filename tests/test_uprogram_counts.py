"""Command-sequence counts vs paper Table 5 — the reproduction fidelity
metric.  Our compiler must (a) never be worse than the paper on the ops whose
schedules the paper derives in closed form, and (b) beat the Ambit baseline
by about the paper's 2.0× aggregate."""
import pytest

from repro.core.circuits import ALL_OPS, PAPER_COUNTS, compile_operation


@pytest.mark.parametrize("n", [8, 16, 32])
@pytest.mark.parametrize("op", ["addition", "subtraction", "greater",
                                "greater_equal", "multiplication"])
def test_counts_meet_or_beat_paper(op, n):
    got = compile_operation(op, n).command_count()
    assert got <= PAPER_COUNTS[op](n) + n, (op, n, got, PAPER_COUNTS[op](n))


@pytest.mark.parametrize("n", [8, 16])
def test_addition_matches_paper_closed_form(n):
    """Paper Table 5: addition = 8n+1 command sequences, exactly."""
    got = compile_operation("addition", n).command_count()
    assert got <= 8 * n + 1


@pytest.mark.parametrize("n", [8, 16])
def test_comparison_matches_paper_exactly(n):
    assert compile_operation("greater", n).command_count() == 3 * n + 2
    assert compile_operation("greater_equal", n).command_count() == 3 * n + 2


def test_simdram_vs_ambit_aggregate_ratio():
    """Paper headline: SIMDRAM:1 ≈ 2.0× Ambit throughput (= 1/commands)."""
    tot_s = tot_a = 0
    for op in ALL_OPS:
        tot_s += compile_operation(op, 8).command_count()
        tot_a += compile_operation(op, 8, optimize=False).command_count()
    ratio = tot_a / tot_s
    assert ratio > 1.6, ratio


def test_every_op_not_worse_than_ambit():
    for op in ALL_OPS:
        s = compile_operation(op, 8).command_count()
        a = compile_operation(op, 8, optimize=False).command_count()
        assert s <= a, (op, s, a)


def test_decoder_triple_budget():
    """The B-group decoder exposes a bounded multi-row address set; the
    compiled programs must not require unboundedly many distinct TRA
    triples (§3.1 hardware budget audit)."""
    triples = set()
    for op in ALL_OPS:
        triples |= compile_operation(op, 8).used_triples()
    # 32 triple addresses (+8 single, +4 pair) = 6 decoder address bits; a
    # documented superset of Ambit's 16 addresses (DESIGN.md)
    assert len(triples) <= 32, sorted(map(str, triples))
