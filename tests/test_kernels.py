"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitplane_transpose import bitplane_transpose
from repro.kernels.bitserial_matmul import bitserial_matmul, pack_signs
from repro.kernels.ops import run_uprogram_kernel, transpose_to_planes
from repro.kernels.ref import (bitplane_transpose_ref, bitserial_matmul_ref,
                               popcount_ref)

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("groups", [128, 256, 512])
def test_bitplane_transpose_matches_ref(groups):
    g = jnp.array(RNG.integers(0, 2**32, (groups, 32), dtype=np.uint32))
    got = bitplane_transpose(g, interpret=True)
    exp = bitplane_transpose_ref(g)
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_transpose_matches_layout_module():
    from repro.simdram.layout import to_bitplanes
    x = jnp.array(RNG.integers(0, 2**31, 32 * 128), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(transpose_to_planes(x, 32, interpret=True)),
        np.asarray(to_bitplanes(x, 32)))


def test_transpose_involution():
    """Transposing planes back recovers the input (self-inverse pairing)."""
    g = jnp.array(RNG.integers(0, 2**32, (128, 32), dtype=np.uint32))
    planes = bitplane_transpose(g, interpret=True)       # (32, 128)
    back = bitplane_transpose(planes.T.reshape(128, 32), interpret=True)
    np.testing.assert_array_equal(np.asarray(back.T), np.asarray(g))


@pytest.mark.parametrize("m,n,k", [(128, 128, 32), (128, 256, 64),
                                   (256, 128, 128)])
def test_bitserial_matmul_sweep(m, n, k):
    af = jnp.array(RNG.choice([-1.0, 1.0], (m, k)).astype(np.float32))
    bf = jnp.array(RNG.choice([-1.0, 1.0], (n, k)).astype(np.float32))
    ap, bp = pack_signs(af), pack_signs(bf)
    got = bitserial_matmul(ap, bp, k, bk=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(bitserial_matmul_ref(ap, bp, k)))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(af @ bf.T).astype(np.int32))


def test_popcount_ref_exact():
    v = jnp.array(RNG.integers(0, 2**32, 1024, dtype=np.uint32))
    exp = np.array([bin(x).count("1") for x in np.asarray(v).tolist()])
    np.testing.assert_array_equal(np.asarray(popcount_ref(v)), exp)


@pytest.mark.parametrize("op", ["addition", "greater", "if_else"])
def test_uprog_kernel_matches_unrolled(op):
    from repro.core.unrolled import run_unrolled
    from repro.ops.bbops import compile_bbop, planes_of
    a = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    b = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    pa, _ = planes_of(a, 8)
    pb, _ = planes_of(b, 8)
    ops_in = {"a": pa, "b": pb}
    if op == "if_else":
        ps, _ = planes_of(jnp.array(RNG.integers(0, 2, 128), jnp.int32), 1)
        ops_in["sel"] = ps
    prog = compile_bbop(op, 8)
    ob = {"out": 1} if op == "greater" else None
    o1 = run_uprogram_kernel(prog, ops_in, out_bits=ob, interpret=True)
    o2 = run_unrolled(prog, ops_in, out_bits=ob)
    np.testing.assert_array_equal(np.asarray(o1[prog.outputs[0]]),
                                  np.asarray(o2[prog.outputs[0]]))
