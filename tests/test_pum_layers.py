"""The paper's technique as a framework feature: binarized (PuM) layers
numerically equal the Pallas bit-serial kernel contraction."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.kernels.bitserial_matmul import bitserial_matmul, pack_signs


def test_pum_mlp_matches_bitserial_kernel():
    from repro.models.layers import mlp_defs, pum_mlp
    from repro.models.params import init_params
    cfg = dataclasses.replace(get_reduced("qwen1_5_0_5b"),
                              compute_dtype="float32", pum_mlp=True)
    d, f = cfg.d_model, 128
    cfg = dataclasses.replace(cfg, d_ff=f)
    params = init_params(mlp_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 4, d))
    # the binarized gate contraction inside pum_mlp:
    xb = jnp.sign(x) + (x == 0)
    wb = jnp.sign(params["w_gate"]) + (params["w_gate"] == 0)
    ref = jnp.einsum("bsd,df->bsf", xb, wb)
    # same contraction via the packed XNOR-popcount kernel
    xp = pack_signs(x.reshape(-1, d))
    wp = pack_signs(params["w_gate"].T)
    kern = bitserial_matmul(xp, wp, d, bk=1, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(kern), np.asarray(ref.reshape(-1, f)).astype(np.int32))


def test_pum_model_trains():
    from repro.models.transformer import model_defs
    from repro.models.params import init_params
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step
    cfg = dataclasses.replace(get_reduced("qwen1_5_0_5b"), pum_mlp=True)
    params = init_params(model_defs(cfg), jax.random.key(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]          # STE gradients flow
