"""TraceLint: the static verifier over lowered command traces.

* **clean sweep** — every builtin × 4/8/16/32 bits lints with zero
  diagnostics (errors *and* warnings);
* **mutation tests** — corrupt a valid trace (swap a row, drop a command,
  break the seqs table, ...) and assert the linter rejects it with the
  right diagnostic ``kind``, naming the command index and human row key;
* **wiring** — ``compile_trace(..., verify=)`` and ``TraceCache`` reject
  broken traces and never re-lint cached ones, ``define_op`` rolls back a
  broken registration, ``BankScheduler.enqueue`` flags cross-tenant bank
  packing with overlapping row footprints;
* **fingerprint memos** — the PerfStats cost memos key on the stable trace
  fingerprint (regression for the recycled-``id()`` aliasing hazard).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.backends import PerfStats
from repro.core.circuits import (ALL_OPS, compile_operation,
                                 register_operation, unregister_operation)
from repro.core.trace import (CMD_COPY, CMD_MAJ, SEQ_AAP_TRA, TraceCache,
                              compile_trace, lower_program)
from repro.core.tracelint import (Diagnostic, LintReport, TraceLintError,
                                  lint_packing, lint_trace, row_footprint)
from repro.core.uprogram import AAP, DRow, Port, UProgram
from repro.simdram.machine import SimdramMachine
from repro.simdram.scheduler import BankScheduler

WIDTHS = (4, 8, 16, 32)


def _mutated(trace, **kw):
    """A structurally independent copy with fresh lint/fingerprint memos."""
    return dataclasses.replace(
        trace, cmds=kw.pop("cmds", trace.cmds).copy(),
        seqs=kw.pop("seqs", trace.seqs).copy(),
        _decoded=None, _lint=None, _fingerprint=None, **kw)


def _trace(name="addition", n_bits=8):
    return compile_trace(name, n_bits)[1]


# ---------------------------------------------------------------------------
# Clean sweep: every builtin × 4/8/16/32 bits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ALL_OPS)
def test_builtins_lint_clean(op):
    for n_bits in WIDTHS:
        report = _trace(op, n_bits).lint()
        assert report.ok, report.render()
        assert not report.diagnostics, report.render()


def test_report_surface():
    report = _trace("relu", 8).lint()
    assert isinstance(report, LintReport)
    assert report.name == "relu" and report.n_bits == 8
    assert report.kinds() == set()
    assert "0 error(s)" in report.render()
    # memoized on the trace: same object every time
    assert _trace("relu", 8).lint() is report


# ---------------------------------------------------------------------------
# Mutation tests: each corruption is caught with the right kind
# ---------------------------------------------------------------------------


def _cell_row(trace, cell):
    return trace.row_index[("cell", cell)]


def test_use_before_init_is_rejected():
    t = _trace()
    cmds = t.cmds.copy()
    # cmd 0 is the first command of the whole trace: nothing has written
    # any compute cell yet, so re-pointing its src at T3 reads garbage
    assert cmds[0, 0] == CMD_COPY
    victim = _cell_row(t, 3)
    cmds[0, 2] = cmds[0, 3] = victim
    report = lint_trace(_mutated(t, cmds=cmds))
    assert not report.ok
    d = next(d for d in report.diagnostics if d.kind == "use-before-init")
    assert d.cmd_index == 0 and d.row_key == "T3" and d.severity == "error"


def test_operand_clobber_is_rejected():
    t = _trace()
    cmds = t.cmds.copy()
    # retarget the first COPY's dst at a pure-input operand row
    assert cmds[0, 0] == CMD_COPY
    cmds[0, 1] = t.row_index[("a", 0)]
    report = lint_trace(_mutated(t, cmds=cmds))
    d = next(d for d in report.diagnostics if d.kind == "operand-clobber")
    assert d.cmd_index == 0 and d.row_key == "a[0]"


def test_const_write_is_rejected():
    t = _trace()
    cmds = t.cmds.copy()
    cmds[0, 1] = t.row_index["C0"]
    report = lint_trace(_mutated(t, cmds=cmds))
    d = next(d for d in report.diagnostics if d.kind == "const-write")
    assert d.cmd_index == 0 and d.row_key == "C0"


def test_row_bounds_is_rejected():
    t = _trace()
    for bad in (t.n_rows + 7, 0, -(t.n_rows + 3)):
        cmds = t.cmds.copy()
        cmds[0, 2] = cmds[0, 3] = bad
        report = lint_trace(_mutated(t, cmds=cmds))
        d = next(d for d in report.diagnostics if d.kind == "row-bounds")
        assert d.cmd_index == 0 and d.row == bad


def test_bad_neg_port_is_rejected():
    t = _trace()
    cmds = t.cmds.copy()
    # negate a COPY dst that names a T cell (no n-wordline)
    t0 = _cell_row(t, 0)
    hits = np.nonzero((cmds[:, 0] == CMD_COPY) & (cmds[:, 1] == t0))[0]
    assert hits.size, "addition never writes T0?"
    cmds[hits[0], 1] = -t0
    report = lint_trace(_mutated(t, cmds=cmds))
    d = next(d for d in report.diagnostics if d.kind == "bad-neg-port")
    assert d.cmd_index == int(hits[0]) and d.row_key == "T0"


def test_tra_operand_is_rejected():
    t = _trace()
    majs = np.nonzero(t.cmds[:, 0] == CMD_MAJ)[0]
    assert majs.size, "addition has no TRA?"
    # duplicate port: only two distinct rows activated
    cmds = t.cmds.copy()
    cmds[majs[0], 2] = cmds[majs[0], 1]
    report = lint_trace(_mutated(t, cmds=cmds))
    assert "tra-operand" in report.kinds()
    # non-B-group port: TRA cannot decode a D row
    cmds = t.cmds.copy()
    cmds[majs[0], 3] = t.row_index[("a", 0)]
    report = lint_trace(_mutated(t, cmds=cmds))
    d = next(d for d in report.diagnostics if d.kind == "tra-operand")
    assert d.cmd_index == int(majs[0]) and d.row_key == "a[0]"


def test_dropped_command_is_rejected():
    t = _trace()
    report = lint_trace(_mutated(t, cmds=t.cmds[:-1]))
    assert "malformed-seqs" in report.kinds()


def test_broken_seqs_table_is_rejected():
    t = _trace()
    # gap: drop the first sequence but keep its commands
    report = lint_trace(_mutated(t, seqs=t.seqs[1:]))
    assert "malformed-seqs" in report.kinds()
    # overlap: second sequence starts before the first ended
    seqs = t.seqs.copy()
    seqs[1, 1] -= 1
    assert "malformed-seqs" in lint_trace(_mutated(t, seqs=seqs)).kinds()
    # unknown kind
    seqs = t.seqs.copy()
    seqs[0, 0] = 7
    assert "malformed-seqs" in lint_trace(_mutated(t, seqs=seqs)).kinds()
    # a multi-source AAP (one activation latches one row)
    t2 = _trace("addition")
    wide = next(
        (k, s, e) for k, s, e in t2.seqs.tolist() if k == 0 and e - s >= 2)
    cmds = t2.cmds.copy()
    _, s, e = wide
    cmds[s, 2] = cmds[s, 3] = t2.row_index["C0"]
    cmds[s + 1, 2] = cmds[s + 1, 3] = t2.row_index["C1"]
    assert "malformed-seqs" in lint_trace(_mutated(t2, cmds=cmds)).kinds()


def test_destroyed_read_in_fused_aap_is_rejected():
    # abs compiles with Case-2 fused AAPs at 8 bits
    t = _trace("abs", 8)
    fused = next((s, e) for k, s, e in t.seqs.tolist() if k == SEQ_AAP_TRA)
    s, e = fused
    cmds = t.cmds.copy()
    # the fused COPY must read one of the three TRA rows — anything else
    # reads a row whose charge the activation sequence does not define
    cmds[s + 1, 2] = cmds[s + 1, 3] = t.row_index["C1"]
    report = lint_trace(_mutated(t, cmds=cmds))
    d = next(d for d in report.diagnostics if d.kind == "destroyed-read")
    assert d.cmd_index == s + 1


def test_undefined_output_is_rejected():
    t = _trace()
    out_row = t.row_index[("out", t.n_bits - 1)]
    cmds = t.cmds.copy()
    # divert every write of out[n-1] into a compute cell: the output row
    # is left undefined at the end of the trace
    writes = (cmds[:, 0] == CMD_COPY) & (cmds[:, 1] == out_row)
    assert writes.any()
    cmds[writes, 1] = _cell_row(t, 0)
    report = lint_trace(_mutated(t, cmds=cmds))
    d = next(d for d in report.diagnostics if d.kind == "undefined-output")
    assert d.row_key == f"out[{t.n_bits - 1}]"
    assert d.cmd_index == int(t.cmds.shape[0])


def test_unknown_opcode_is_rejected():
    t = _trace()
    cmds = t.cmds.copy()
    cmds[0, 0] = 9
    assert "malformed-cmds" in lint_trace(_mutated(t, cmds=cmds)).kinds()


def test_copy_src_dup_warns_but_passes():
    t = _trace()
    cmds = t.cmds.copy()
    i = int(np.nonzero(cmds[:, 0] == CMD_COPY)[0][0])
    cmds[i, 3] = t.row_index["C1"]          # c no longer duplicates b
    report = lint_trace(_mutated(t, cmds=cmds))
    assert report.ok                        # warning, not error
    assert "copy-src-dup" in report.kinds()


# ---------------------------------------------------------------------------
# Property: swapping an operand row with an output row is always caught
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                          # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from(("addition", "subtraction", "maximum", "abs")),
           st.sampled_from((4, 8)), st.data())
    def test_row_swap_mutation_always_caught(op, n_bits, data):
        t = _trace(op, n_bits)
        r_in = t.row_index[("a", data.draw(
            st.integers(0, n_bits - 1), label="input bit"))]
        r_out = t.row_index[("out", data.draw(
            st.integers(0, n_bits - 1), label="output bit"))]
        cmds = t.cmds.copy()
        a, b = cmds == r_in, cmds == r_out
        cmds[a], cmds[b] = r_out, r_in       # swap the two rows throughout
        report = lint_trace(_mutated(t, cmds=cmds))
        # the output row's writes now clobber the caller's operand row
        assert not report.ok
        assert "operand-clobber" in report.kinds()

    @settings(max_examples=50, deadline=None)
    @given(st.sampled_from(("relu", "greater", "xor_reduction")),
           st.data())
    def test_lint_never_crashes_on_corruption(op, data):
        t = _trace(op, 8)
        cmds = t.cmds.copy()
        i = data.draw(st.integers(0, cmds.shape[0] - 1), label="cmd")
        j = data.draw(st.integers(0, 3), label="col")
        cmds[i, j] = data.draw(
            st.integers(-t.n_rows - 3, t.n_rows + 3), label="value")
        report = lint_trace(_mutated(t, cmds=cmds))
        assert isinstance(report, LintReport)
        for d in report.diagnostics:
            assert isinstance(d, Diagnostic) and str(d)


# ---------------------------------------------------------------------------
# Wiring: compile_trace / TraceCache
# ---------------------------------------------------------------------------


def _broken_compile_fn(n_bits, optimize=True):
    """Reads T0 before anything ever wrote it — classic garbage read."""
    return UProgram(name="broken_op", n_bits=n_bits,
                    prologue=[AAP(Port(0), (DRow("out", 0, fixed=True),))],
                    body=[], epilogue=[], body_reps=0,
                    inputs=("a",), outputs=("out",))


def test_compile_trace_rejects_broken_op():
    register_operation("broken_op", _broken_compile_fn)
    try:
        with pytest.raises(TraceLintError) as ei:
            compile_trace("broken_op", 8)
        msg = str(ei.value)
        assert "use-before-init" in msg and "T0" in msg and "cmd 0" in msg
        assert ei.value.report.errors
        # the broken trace never entered the cache ...
        assert ("broken_op", 8, True) not in __import__(
            "repro.core.trace", fromlist=["GLOBAL_TRACE_CACHE"]
        ).GLOBAL_TRACE_CACHE
        # ... verify=False opts out, but a later default fetch of the
        # cached-unverified entry still raises (memoized report)
        compile_trace("broken_op", 8, verify=False)
        with pytest.raises(TraceLintError):
            compile_trace("broken_op", 8)
    finally:
        unregister_operation("broken_op")


def test_trace_cache_verify_off_by_construction():
    cache = TraceCache(compile_fn=lambda n, b, o: _broken_compile_fn(b),
                       verify=False)
    prog, trace = cache.get("whatever", 8)
    assert not trace.lint().ok               # broken, but accepted
    strict = TraceCache(compile_fn=lambda n, b, o: _broken_compile_fn(b))
    with pytest.raises(TraceLintError):
        strict.get("whatever", 8)
    assert len(strict) == 0


# ---------------------------------------------------------------------------
# Wiring: define_op rejection
# ---------------------------------------------------------------------------


def test_define_op_rejects_broken_user_op():
    m = SimdramMachine()
    with pytest.raises(TraceLintError) as ei:
        m.define_op("broken_op", compile_fn=_broken_compile_fn)
    assert "T0" in str(ei.value)
    # rolled back: not registered, not cached, name reusable
    assert "broken_op" not in m.ops()
    m.define_op("ident", compile_fn=lambda n, o=True: UProgram(
        name="ident", n_bits=n,
        prologue=[AAP(DRow("a", i), (DRow("out", i, fixed=True),))
                  for i in range(n)],
        body=[], epilogue=[], body_reps=0, inputs=("a",), outputs=("out",)))
    assert "ident" in m.ops()


def test_define_op_verify_false_skips_probe():
    m = SimdramMachine()
    m.define_op("broken_op", compile_fn=_broken_compile_fn, verify=False)
    assert "broken_op" in m.ops()
    with pytest.raises(TraceLintError):      # ... but execution still checks
        m.memory.get("broken_op", 8)


# ---------------------------------------------------------------------------
# Wiring: scheduler bank packing
# ---------------------------------------------------------------------------


def test_scheduler_flags_cross_tenant_bank_overlap():
    _, t_add = compile_trace("addition", 8)
    sched = BankScheduler(n_banks=4)
    sched.enqueue(t_add, tenant="A", name="add-A", bank_ids=(0,))
    assert sched.lint_diagnostics == []      # nothing to overlap with yet
    sched.enqueue(t_add, tenant="A", name="add-A2", bank_ids=(0,))
    assert sched.lint_diagnostics == []      # same tenant: not flagged
    sched.enqueue(t_add, tenant="B", name="add-B", bank_ids=(1,))
    assert sched.lint_diagnostics == []      # disjoint banks: not flagged
    sched.enqueue(t_add, tenant="B", name="add-B2", bank_ids=(0,))
    kinds = {d.kind for d in sched.lint_diagnostics}
    assert kinds == {"bank-overlap"}
    assert all(d.severity == "warning" for d in sched.lint_diagnostics)
    assert any("add-B2" in d.message and "tenant" in d.message
               for d in sched.lint_diagnostics)
    # warnings never reject the request
    assert sched.n_pending > 0
    sched.run()
    # a new busy period pairs afresh
    sched.enqueue(t_add, tenant="C", name="add-C", bank_ids=(0,))
    assert {d.kind for d in sched.lint_diagnostics} == {"bank-overlap"}
    assert not any("add-C" in d.message for d in sched.lint_diagnostics)


def test_scheduler_rejects_broken_trace():
    t = _trace()
    broken = _mutated(t, cmds=t.cmds[:-1])
    sched = BankScheduler(n_banks=2)
    with pytest.raises(TraceLintError):
        sched.enqueue(broken)
    assert sched.n_pending == 0
    BankScheduler(n_banks=2, verify=False).enqueue(broken)  # opt-out


def test_lint_packing_pure_function():
    fp1 = row_footprint(_trace("addition", 8))
    fp2 = row_footprint(_trace("relu", 8))
    assert ("a", 0) in fp1 and ("out", 0) in fp1
    out = lint_packing([("r0", "A", fp1, {0}), ("r1", "B", fp1, {0, 1}),
                        ("r2", "B", fp2 - fp1, {0})])
    assert len(out) == 1 and out[0].kind == "bank-overlap"


# ---------------------------------------------------------------------------
# Fingerprint-keyed cost memos (regression: recycled-id aliasing)
# ---------------------------------------------------------------------------


def test_fingerprint_stable_across_objects():
    prog1 = compile_operation("addition", 8)
    prog2 = compile_operation("addition", 8)
    t1, t2 = lower_program(prog1), lower_program(prog2)
    assert t1 is not t2
    assert t1.fingerprint == t2.fingerprint
    assert t1.fingerprint != lower_program(
        compile_operation("relu", 8)).fingerprint
    mutated = _mutated(t1)
    mutated.cmds[0, 2] += 1
    assert mutated.fingerprint != t1.fingerprint


def test_cost_memos_key_on_fingerprint_not_id():
    prog1 = compile_operation("addition", 8)
    prog2 = compile_operation("addition", 8)
    t1, t2 = lower_program(prog1), lower_program(prog2)
    st = PerfStats(mode="replay")
    st.charge_program(prog1, 1, 32, trace=t1)
    st.charge_program(prog2, 1, 32, trace=t2)   # distinct object, same trace
    # content-keyed: equal traces share one entry, so a recycled id() can
    # never serve another program's cost
    assert set(st._prog_costs) == {t1.fingerprint}
    assert [k[0] for k in st._replay_costs] == [t1.fingerprint]
    assert st.n_programs == 2                    # charging itself: per call
    other = lower_program(compile_operation("relu", 8))
    st.charge_program(compile_operation("relu", 8), 1, 32, trace=other)
    assert len(st._prog_costs) == 2


def test_charge_program_without_trace_uses_lowering_memo():
    prog = compile_operation("relu", 8)
    st = PerfStats()
    st.charge_program(prog, 1, 32)               # trace=None: analytic-only
    assert set(st._prog_costs) == {lower_program(prog).fingerprint}
    assert st.replay_ns == 0.0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_sweep_clean_and_failing(capsys):
    from repro.tools.tracelint import main
    assert main(["--ops", "relu,greater", "--bits", "4,8", "-v"]) == 0
    out = capsys.readouterr().out
    assert "0 failing" in out and "ok    relu/4b" in out
    register_operation("broken_op", _broken_compile_fn)
    try:
        assert main(["--ops", "broken_op", "--bits", "8"]) == 1
        out = capsys.readouterr().out
        assert "FAIL  broken_op/8b" in out and "use-before-init" in out
    finally:
        unregister_operation("broken_op")
