"""Vectorized trace-replay engine ≡ the stepped FSM oracle.

PR-8 property suite.  The closed-form replay engine
(``DRAMTiming(replay_engine="vectorized")``, the default) must reproduce
the per-edge stepped FSM *exactly* — same finish time, cycle count, ACT
count and stall attribution — or decline and let the stepped oracle run
(exact-or-absent).  :class:`ReplayResult` is a frozen dataclass, so plain
``==`` compares every field at once.

Also covered here: the :class:`TraceCache` replay memo (hit/miss counters,
key sensitivity, LRU bound), engine selection/validation, and the
scheduler's ``"defer"``-policy equivalence anchor re-checked against both
engines (the anchor is engine-independent precisely because the engines
agree).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.circuits import ALL_OPS
from repro.core.trace import TraceCache, compile_trace
from repro.simdram.timing import DRAMTiming, TraceReplayTiming

RNG = np.random.default_rng(0x8E9)


def _timing(**kw) -> DRAMTiming:
    return dataclasses.replace(DRAMTiming(), **kw)


TIMINGS = {
    "default": DRAMTiming(),
    "noref": _timing(tREFI_ns=0.0),
    "heavy": _timing(tREFI_ns=150.0, tRFC_ns=50.0),
}


def _both(t: DRAMTiming, trace, banks: int, offsets=None, phase=0.0):
    rt = TraceReplayTiming(t)
    v = rt.replay(trace, banks=banks, offsets_ns=offsets,
                  refresh_phase_ns=phase, engine="vectorized")
    s = rt.replay(trace, banks=banks, offsets_ns=offsets,
                  refresh_phase_ns=phase, engine="stepped")
    return v, s


# ---------------------------------------------------------------------------
# Property: vectorized ≡ stepped, full ReplayResult equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ALL_OPS)
def test_every_table5_op_matches_stepped(op):
    """All 16 Table-5 ops at 8 bits on the realistic 8-bank array."""
    _, trace = compile_trace(op, 8)
    v, s = _both(DRAMTiming(), trace, 8)
    assert v == s, op


@pytest.mark.parametrize("banks", [1, 2, 5, 8, 16])
@pytest.mark.parametrize("op,n_bits", [
    ("addition", 4), ("addition", 16), ("multiplication", 8),
    ("xor_reduction", 8), ("relu", 32), ("greater", 16)])
def test_width_bank_refresh_grid(op, n_bits, banks):
    """Representative ops across element widths × bank counts × the
    refresh grid (refresh off / DDR4 default / toy refresh-heavy)."""
    _, trace = compile_trace(op, n_bits)
    for tname, t in TIMINGS.items():
        v, s = _both(t, trace, banks)
        assert v == s, (op, n_bits, banks, tname)


@pytest.mark.parametrize("banks", [2, 5, 8])
def test_issue_offsets_and_refresh_phase(banks):
    """Per-bank issue offsets (skewed and scrambled) combined with a
    threaded cross-op refresh phase — the hard desynchronized cases."""
    _, trace = compile_trace("addition", 8)
    offsets_cases = (
        None,
        tuple(3.0 * i for i in range(banks)),
        tuple(float(o) for o in RNG.choice(256, size=banks, replace=False)),
    )
    for tname, t in (("default", DRAMTiming()), ("heavy", TIMINGS["heavy"])):
        for offs in offsets_cases:
            for phase in (0.0, 500.0, 7000.0):
                v, s = _both(t, trace, banks, offsets=offs, phase=phase)
                assert v == s, (banks, tname, offs, phase)


def test_lockstep_policy_matches():
    """The legacy broadcast FSM replays identically under both engines."""
    rt = TraceReplayTiming()
    for op in ("addition", "division"):
        _, trace = compile_trace(op, 8)
        v = rt.replay(trace, banks=8, policy="lockstep", engine="vectorized")
        s = rt.replay(trace, banks=8, policy="lockstep", engine="stepped")
        assert v == s, op


def test_vectorized_path_actually_engages():
    """Guard against the closed form silently declining everywhere —
    parity alone would still pass via the stepped fallback.  On the
    realistic default configuration the solver must produce the result
    itself, and that result must equal the oracle's."""
    rt = TraceReplayTiming()
    for op in ("addition", "relu", "greater", "xor_reduction"):
        _, trace = compile_trace(op, 8)
        res = rt._replay_vectorized(trace, 8, [0] * 8, False, 0)
        assert res is not None, f"{op}: closed form declined"
        assert res == rt._replay_stepped(trace, 8, [0] * 8, False, 0), op


def test_hypothesis_random_offsets_and_phases():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    _, trace = compile_trace("addition", 8)

    @settings(max_examples=25, deadline=None)
    @given(banks=st.integers(1, 8),
           phase=st.floats(0.0, 16000.0, allow_nan=False),
           seed=st.integers(0, 2 ** 16))
    def prop(banks, phase, seed):
        r = np.random.default_rng(seed)
        offs = tuple(float(x) for x in r.integers(0, 300, size=banks))
        v, s = _both(DRAMTiming(), trace, banks, offsets=offs, phase=phase)
        assert v == s

    prop()


# ---------------------------------------------------------------------------
# Engine selection and validation
# ---------------------------------------------------------------------------


def test_default_engine_is_vectorized_and_validated():
    assert DRAMTiming().replay_engine == "vectorized"
    with pytest.raises(ValueError, match="replay engine"):
        TraceReplayTiming(_timing(replay_engine="bogus"))
    rt = TraceReplayTiming()
    _, trace = compile_trace("relu", 8)
    with pytest.raises(ValueError, match="replay engine"):
        rt.replay(trace, engine="nope")


# ---------------------------------------------------------------------------
# TraceCache replay memo: counters, key sensitivity, LRU bound
# ---------------------------------------------------------------------------


def test_replay_memo_counters_and_key_sensitivity():
    _, trace = compile_trace("addition", 8)
    rt = TraceReplayTiming()
    memo = TraceCache()
    r1 = rt.replay(trace, banks=4, cache=memo)
    st = memo.stats()
    assert (st["replay_misses"], st["replay_hits"]) == (1, 0)
    assert st["replay_entries"] == 1
    r2 = rt.replay(trace, banks=4, cache=memo)
    assert r2 is r1                  # warm hit is the memoized object
    assert memo.stats()["replay_hits"] == 1
    # every key dimension misses independently
    rt.replay(trace, banks=8, cache=memo)
    rt.replay(trace, banks=4, cache=memo, engine="stepped")
    rt.replay(trace, banks=4, cache=memo, refresh_phase_ns=500.0)
    rt.replay(trace, banks=4, cache=memo, policy="lockstep")
    st = memo.stats()
    assert st["replay_misses"] == 5
    assert st["replay_entries"] == 5
    # a different timing signature cannot share entries either
    TraceReplayTiming(_timing(tFAW_ns=0.0)).replay(trace, banks=4,
                                                   cache=memo)
    assert memo.stats()["replay_misses"] == 6
    # and the memoized results all agree with a fresh uncached replay
    assert rt.replay(trace, banks=4) == r1


def test_replay_memo_lru_bound_and_validation():
    with pytest.raises(ValueError, match="replay_capacity"):
        TraceCache(replay_capacity=0)
    _, trace = compile_trace("relu", 8)
    memo = TraceCache(replay_capacity=3)
    rt = TraceReplayTiming()
    for banks in (1, 2, 3, 4):
        rt.replay(trace, banks=banks, cache=memo)
    st = memo.stats()
    assert st["replay_entries"] == 3     # bounded
    assert st["replay_misses"] == 4
    rt.replay(trace, banks=4, cache=memo)          # most recent: still hot
    assert memo.stats()["replay_hits"] == 1
    rt.replay(trace, banks=1, cache=memo)          # oldest: evicted
    assert memo.stats()["replay_misses"] == 5


# ---------------------------------------------------------------------------
# Scheduler "defer" anchor is engine-independent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["vectorized", "stepped"])
def test_defer_schedule_matches_replay_under_both_engines(engine):
    """The scheduler event loop (which always steps) equals the replay
    substrate under the ``"defer"`` policy whichever engine serves the
    replay — the PR-6 acceptance anchor survives the engine swap."""
    from repro.ops import BankScheduler
    t = _timing(tREFI_ns=150.0, tRFC_ns=50.0)
    rt = TraceReplayTiming(t)
    _, trace = compile_trace("addition", 8)
    sched = BankScheduler(timing=t, n_banks=4, refresh_policy="defer")
    sched.enqueue(trace, banks=4)
    got = sched.run()
    want = rt.replay(trace, banks=4, engine=engine)
    assert got.ns == pytest.approx(want.ns)
    assert got.cycles == want.cycles
    assert got.n_acts == want.n_acts
    assert got.tfaw_stall_ns == pytest.approx(want.tfaw_stall_ns)
    assert got.refresh_stall_ns == pytest.approx(want.refresh_stall_ns)
    assert got.n_refresh_stalls == want.n_refresh_stalls
