"""Plane-resident pipelines: transposition-unit accounting, BitplaneArray
semantics, multi-bank batching, and the PuM serving-layer argmax."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.ops import (BitplaneArray, bbop_add, bbop_greater, bbop_if_else,
                       bbop_mul, bbop_relu, bbop_sub, simdram_pipeline)
from repro.simdram.layout import reset_transpose_stats, transpose_counts

RNG = np.random.default_rng(11)
N = 100
A = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
B = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
C = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
An, Bn, Cn = map(np.asarray, (A, B, C))
CHAIN_EXP = np.where((((An * Bn) & 255) + Cn & 255) & 0x80, 0,
                     ((An * Bn) & 255) + Cn & 255)


def test_chained_pipeline_single_transpose_pair():
    """relu(add(mul(a, b), c)) fused: exactly ONE to_bitplanes pass and ONE
    from_bitplanes pass end-to-end (the acceptance-criterion chain)."""
    reset_transpose_stats()
    with simdram_pipeline() as p:
        pa, pb, pc = p.load([A, B, C], 8)
        out = bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8)
        res = p.store(out)
    assert transpose_counts() == (1, 1)
    np.testing.assert_array_equal(np.asarray(res), CHAIN_EXP)


def test_unfused_chain_pays_per_op_transposes():
    reset_transpose_stats()
    res = bbop_relu(bbop_add(bbop_mul(A, B, 8), C, 8), 8)
    to_n, from_n = transpose_counts()
    assert to_n >= 3 and from_n >= 3          # one round-trip per op
    np.testing.assert_array_equal(np.asarray(res), CHAIN_EXP)


def test_mixed_operands_promote_to_planes():
    """A BitplaneArray anywhere in the op keeps the result vertical."""
    pa = BitplaneArray.from_values(A, 8)
    out = bbop_add(pa, B, 8)                  # horizontal b auto-coerces
    assert isinstance(out, BitplaneArray)
    np.testing.assert_array_equal(np.asarray(out.to_values()),
                                  (An + Bn) & 255)


def test_bitplane_roundtrip_and_signed():
    vals = jnp.asarray(RNG.integers(-128, 128, 77), jnp.int32)
    bpa = BitplaneArray.from_values(vals, 8, signed=True)
    np.testing.assert_array_equal(np.asarray(bpa.to_values()),
                                  np.asarray(vals))


def test_banked_pipeline_matches_per_bank():
    banks, n = 4, 64
    ab = jnp.asarray(RNG.integers(0, 256, (banks, n)), jnp.int32)
    bb = jnp.asarray(RNG.integers(0, 256, (banks, n)), jnp.int32)
    reset_transpose_stats()
    with simdram_pipeline(banks=banks) as p:
        pa, pb = p.load([ab, bb], 8)
        res = p.store(bbop_add(pa, pb, 8))
    assert transpose_counts() == (1, 1)       # banks ride the same pass
    assert res.shape == (banks, n)
    np.testing.assert_array_equal(
        np.asarray(res), (np.asarray(ab) + np.asarray(bb)) & 255)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_banked_pipeline_other_backends(backend):
    banks, n = 2, 64
    ab = jnp.asarray(RNG.integers(0, 256, (banks, n)), jnp.int32)
    bb = jnp.asarray(RNG.integers(0, 256, (banks, n)), jnp.int32)
    with simdram_pipeline(banks=banks, backend=backend) as p:
        pa, pb = p.load([ab, bb], 8)
        res = p.store(bbop_sub(pa, pb, 8))
    np.testing.assert_array_equal(
        np.asarray(res), (np.asarray(ab) - np.asarray(bb)) & 255)


def test_predicated_chain_stays_vertical():
    """Paper Listing 1 fused: C = (A > pred) ? A+B : A−B, one pair."""
    pred = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    reset_transpose_stats()
    with simdram_pipeline() as p:
        pa, pb, pp = p.load([A, B, pred], 8)
        d = bbop_add(pa, pb, 8)
        e = bbop_sub(pa, pb, 8)
        f = bbop_greater(pa, pp, 8)
        res = p.store(bbop_if_else(f, d, e, 8))
    assert transpose_counts() == (1, 1)
    exp = np.where(An > np.asarray(pred), (An + Bn) & 255, (An - Bn) & 255)
    np.testing.assert_array_equal(np.asarray(res), exp)


def test_signed_compare_on_planes_flips_msb_in_place():
    a = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    with simdram_pipeline() as p:
        pa, pb = p.load([a, b], 8)
        res = bbop_greater(pa, pb, 8, signed=True)
    exp = (np.asarray(a).astype(np.int8) >
           np.asarray(b).astype(np.int8)).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(res.to_values()), exp)


def test_store_multiple_results_single_pass():
    reset_transpose_stats()
    with simdram_pipeline() as p:
        pa, pb = p.load([A, B], 8)
        s, d = p.store(bbop_add(pa, pb, 8), bbop_sub(pa, pb, 8))
    assert transpose_counts() == (1, 1)
    np.testing.assert_array_equal(np.asarray(s), (An + Bn) & 255)
    np.testing.assert_array_equal(np.asarray(d), (An - Bn) & 255)


def test_store_mixed_layout_results_decode_independently():
    """Results with different lengths/signedness must not inherit the first
    result's metadata through the merged reverse pass."""
    short = jnp.asarray(RNG.integers(0, 256, 40), jnp.int32)
    long_ = jnp.asarray(RNG.integers(0, 256, 60), jnp.int32)
    with simdram_pipeline() as p:
        ps = p.load(short, 8)
        pl = p.load(long_, 8)
        rs, rl = p.store(bbop_add(ps, ps, 8), bbop_add(pl, pl, 8))
    assert rs.shape == (40,) and rl.shape == (60,)
    np.testing.assert_array_equal(np.asarray(rl),
                                  (2 * np.asarray(long_)) & 255)


def test_length_mismatch_rejected_not_padded():
    """Same padded width, different logical lengths: must error, not
    silently add the shorter operand's zero padding."""
    long_ = BitplaneArray.from_values(jnp.full(60, 7, jnp.int32), 8)
    short = BitplaneArray.from_values(jnp.full(40, 5, jnp.int32), 8)
    with pytest.raises(ValueError, match="length"):
        bbop_add(long_, short, 8)


def test_banked_load_rejects_wrong_bank_shapes():
    with simdram_pipeline(banks=4) as p:
        with pytest.raises(ValueError, match="banks"):
            p.load(jnp.zeros(64, jnp.int32), 8)          # 1-D into banked
        with pytest.raises(ValueError, match="banks"):
            p.load(jnp.zeros((2, 64), jnp.int32), 8)     # wrong bank count


def test_split_lanes_is_free():
    vals = jnp.asarray(RNG.integers(0, 256, 128), jnp.int32)
    bpa = BitplaneArray.from_values(vals, 8)
    reset_transpose_stats()
    lo, hi = bpa.split_lanes()
    assert transpose_counts() == (0, 0)
    np.testing.assert_array_equal(np.asarray(lo.to_values()),
                                  np.asarray(vals)[:64])
    np.testing.assert_array_equal(np.asarray(hi.to_values()),
                                  np.asarray(vals)[64:])


# ---------------------------------------------------------------------------
# Serving layer: bank-batched PuM argmax
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,v", [(4, 100), (2, 257), (1, 33)])
def test_simdram_argmax_matches_host(b, v):
    from repro.serve.decode import simdram_argmax
    vals = np.stack([RNG.permutation(4 * v)[:v] for _ in range(b)])
    n_bits = int(vals.max()).bit_length()
    got = np.asarray(simdram_argmax(jnp.asarray(vals), n_bits=n_bits))
    picked = vals[np.arange(b), got]
    np.testing.assert_array_equal(picked, vals.max(-1))   # a maximal index
    np.testing.assert_array_equal(got, vals.argmax(-1))   # unique ⇒ exact


def test_simdram_greedy_token_matches_float_argmax():
    from repro.serve.decode import simdram_greedy_token
    logits = jnp.asarray(RNG.normal(size=(3, 256)).astype(np.float32))
    # well-separated maxima survive 8-bit quantization exactly
    logits = logits.at[0, 17].set(9.0).at[1, 200].set(9.0).at[2, 3].set(9.0)
    np.testing.assert_array_equal(
        np.asarray(simdram_greedy_token(logits)), np.array([17, 200, 3]))


def test_simdram_greedy_token_survives_vocab_masking():
    """-inf masked logits must map to bin 0, not poison the row scale."""
    from repro.serve.decode import simdram_greedy_token
    logits = jnp.asarray(RNG.normal(size=(2, 128)).astype(np.float32))
    logits = logits.at[0, 64:].set(-jnp.inf).at[1, :32].set(-jnp.inf)
    logits = logits.at[0, 11].set(9.0).at[1, 77].set(9.0)
    np.testing.assert_array_equal(
        np.asarray(simdram_greedy_token(logits)), np.array([11, 77]))
