"""Property-based tests (hypothesis) on the lowered command-trace IR.

Skipped (not errored) when hypothesis isn't installed — CI installs it via
the pyproject dev extra; minimal environments still collect cleanly.  The
deterministic full op × width round-trip sweep runs unconditionally in
test_trace_ir.py; these properties re-derive the same invariants from
randomly sampled compiles, including fresh (cache-bypassing) ones.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.circuits import ALL_OPS, compile_operation
from repro.core.trace import (canonical_uops, compile_trace, lower_program)

WIDTHS = (4, 8, 16, 32)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(ALL_OPS), st.sampled_from(WIDTHS))
def test_decode_lower_roundtrip(op, n_bits):
    """decode(lower(prog)) reproduces the original μOp sequence, and the
    trace's command accounting matches the μProgram's, for every Table-5
    op at 4/8/16/32 bits."""
    prog, trace = compile_trace(op, n_bits)
    assert trace.decode() == canonical_uops(prog)
    assert trace.command_mix() == prog.command_mix()
    assert trace.n_commands == prog.command_count()


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(ALL_OPS), st.sampled_from((4, 8, 16)))
def test_cached_vs_fresh_compiles_identical(op, n_bits):
    """Cache hits return exactly the trace a fresh synthesis + allocation +
    lowering run would produce (32-bit class-3 compiles are covered by the
    deterministic sweep; re-synthesizing them per example is too slow)."""
    _, cached = compile_trace(op, n_bits)
    fresh = lower_program(compile_operation(op, n_bits))
    np.testing.assert_array_equal(cached.cmds, fresh.cmds)
    np.testing.assert_array_equal(cached.seqs, fresh.seqs)
    assert cached.row_index == fresh.row_index
