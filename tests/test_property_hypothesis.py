"""Property-based tests (hypothesis) on system invariants.

Skipped (not errored) when hypothesis isn't installed — CI installs it via
the pyproject dev extra; minimal environments still collect cleanly.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.circuits import compile_operation
from repro.core.executor import from_planes, run_program, to_planes
from repro.core.graph import LogicGraph

ints8 = st.lists(st.integers(0, 255), min_size=1, max_size=80)


@settings(max_examples=20, deadline=None)
@given(ints8, ints8)
def test_addition_is_exact_everywhere(xs, ys):
    n = min(len(xs), len(ys))
    a = np.array(xs[:n]); b = np.array(ys[:n])
    prog = compile_operation("addition", 8)
    outs, _ = run_program(prog, {"a": a, "b": b})
    got = from_planes(outs["out"], n)
    np.testing.assert_array_equal(got, (a + b) & 255)


@settings(max_examples=20, deadline=None)
@given(ints8, ints8)
def test_comparison_trichotomy(xs, ys):
    n = min(len(xs), len(ys))
    a = np.array(xs[:n]); b = np.array(ys[:n])
    gt, _ = run_program(compile_operation("greater", 8), {"a": a, "b": b})
    ge, _ = run_program(compile_operation("greater_equal", 8),
                        {"a": a, "b": b})
    eq, _ = run_program(compile_operation("equal", 8), {"a": a, "b": b})
    gtv = from_planes(gt["out"][:1], n)
    gev = from_planes(ge["out"][:1], n)
    eqv = from_planes(eq["out"][:1], n)
    # ge == gt | eq  and  gt & eq == 0
    np.testing.assert_array_equal(gev, np.maximum(gtv, eqv))
    assert not np.any((gtv == 1) & (eqv == 1))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=1, max_size=200),
       st.integers(1, 32))
def test_plane_roundtrip(xs, n_bits):
    vals = np.array(xs, np.int64) & ((1 << n_bits) - 1)
    planes = to_planes(vals, n_bits)
    back = from_planes(planes, len(vals))
    np.testing.assert_array_equal(back, vals)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 7), st.integers(0, 7), st.integers(0, 7))
def test_maj_axioms(x, y, z):
    """MIG axioms (paper Table 4) hold under bit-parallel evaluation."""
    g = LogicGraph()
    a, b, c = g.input("a"), g.input("b"), g.input("c")
    g.add_output("m1", g.gate_maj(a, b, c))
    g.add_output("m2", g.gate_maj(b, a, c))      # commutativity
    r = g.evaluate({"a": x, "b": y, "c": z}, mask=7)
    assert r["m1"] == r["m2"]
    exp = (x & y) | (x & z) | (y & z)
    assert r["m1"] == exp


@settings(max_examples=15, deadline=None)
@given(ints8)
def test_executor_matches_unrolled_backend(xs):
    """The numpy reference subarray and the trace-time jnp backend must agree
    command-for-command."""
    import jax.numpy as jnp
    from repro.core.unrolled import run_unrolled
    from repro.ops.bbops import planes_of
    n = len(xs)
    a = np.array(xs)
    prog = compile_operation("abs", 8)
    ref_outs, _ = run_program(prog, {"a": a})
    ref = from_planes(ref_outs["out"], n)
    pa, _ = planes_of(jnp.array(a, jnp.int32), 8)
    jx = run_unrolled(prog, {"a": pa})
    from repro.ops.bbops import values_of
    got = np.array(values_of(jx["out"], n))
    np.testing.assert_array_equal(got, ref)
