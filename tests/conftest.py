"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see exactly
one CPU device; only the dry-run forces 512 host devices (in its own
process)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


def oracle_inputs(rng, n_bits, n=96):
    hi = min(2 ** n_bits, 2 ** 62)
    a = rng.integers(0, hi, n).astype(np.int64)
    b = rng.integers(0, hi, n).astype(np.int64)
    return a, b
