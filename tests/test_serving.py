"""The async serving layer: continuous-batching decode over bank-sharded
machine pools (PR 10's tentpole) and the satellites that rode along.

* **percentile math** — golden tests for the deterministic
  linear-interpolation percentile the SLO metrics use;
* **request profiles** — every model-zoo config maps to a valid,
  deterministic per-token μProgram profile;
* **decode semantics** — a served session's value recurrence matches the
  numpy oracle, solo or continuously batched;
* **churn** — sessions of different lengths joining at staggered modeled
  arrivals all retire, with admission at step boundaries only;
* **pool isolation** — sessions shard across machines with no
  cross-machine PerfStats leakage (disjoint tenant sets);
* **concurrency** — a 2-thread submission stress and the asyncio surface
  (``run_async`` / ``wait_async``);
* **batched drain** — ``drain(batch=True)`` stacks compatible
  submissions into one banked request with oracle-exact values, honors
  ``priority=`` in packing order (the PR-6 bugfix), keeps tenant-summed
  meters exactly equal to the machine totals, and under ``"defer"``
  matches the property-tested replay equivalence;
* **schedule memo** — a repeated busy period is served from the
  μProgram Memory's schedule table cycle-exactly, relabeled to the live
  request set.

Deterministic throughout: every asserted latency is modeled ns on a rank
clock; wall clock appears only as thread-join guard timeouts.
"""
import asyncio
import json
import threading

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.circuits import list_operations
from repro.ops import SimdramMachine
from repro.serve import (ContinuousBatcher, DecodeSession, SimdramServer,
                         percentile, profile_for)
from repro.simdram.timing import TraceReplayTiming

RNG = np.random.default_rng(0x5E12)

MIX = ["qwen1_5_0_5b", "mamba2_130m", "whisper_large_v3", "olmoe_1b_7b"]


def _oracle_decode(session: DecodeSession, op: str, n_tokens: int):
    """Replay a session's value recurrence in numpy."""
    mask = (1 << session.profile.n_bits) - 1
    a, b = session.a.copy(), session.b.copy()
    fns = {"addition": np.add, "multiplication": np.multiply,
           "subtraction": np.subtract, "maximum": np.maximum,
           "minimum": np.minimum}
    for _ in range(n_tokens):
        a = fns[op](a, b) & mask
    return a


# ---------------------------------------------------------------------------
# percentile math (golden)
# ---------------------------------------------------------------------------

def test_percentile_golden_values():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 100.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([1, 2, 3, 4], 25) == pytest.approx(1.75)
    assert percentile([4, 3, 2, 1], 25) == pytest.approx(1.75)  # unsorted


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)
    with pytest.raises(ValueError):
        percentile([1.0], -1)


# ---------------------------------------------------------------------------
# request profiles from the model zoo
# ---------------------------------------------------------------------------

def test_profile_for_covers_the_zoo():
    ops = set(list_operations())
    for arch in ARCHS:
        p = profile_for(arch)
        assert p.op in ops, arch
        assert 32 <= p.lanes <= 128 and p.lanes % 32 == 0, arch
        assert p.n_bits == 8 and p.config == arch
        assert p == profile_for(arch)            # deterministic
        assert p.batch_key == (p.op, p.n_bits, p.lanes)


# ---------------------------------------------------------------------------
# decode semantics
# ---------------------------------------------------------------------------

def test_single_session_matches_oracle():
    server = SimdramServer(n_machines=1, n_banks=4)
    h = server.submit_session("qwen1_5_0_5b", n_tokens=5)   # addition
    stats = server.run()
    assert h.done()
    want = _oracle_decode(DecodeSession(0, profile_for("qwen1_5_0_5b"), 5),
                          "addition", 5)
    np.testing.assert_array_equal(np.asarray(h.result()), want)
    s = h.session
    assert s.tokens_done == 5 and len(s.token_ns) == 5
    assert s.ttft_ns is not None and s.ttft_ns > 0
    assert s.finish_ns >= s.first_token_ns
    assert all(t > 0 for t in s.token_ns)
    assert stats.total_tokens == 5 and stats.n_sessions == 1


def test_batched_sessions_match_solo_values():
    # 4 compatible sessions continuously batched on one machine must
    # produce exactly the values each would produce served alone
    batched = SimdramServer(n_machines=1, n_banks=8)
    hs = [batched.submit_session("mamba2_130m", n_tokens=4)
          for _ in range(4)]
    batched.run()
    for i, h in enumerate(hs):
        solo = SimdramServer(n_machines=1, n_banks=8)
        # seed pins the operand state to the batched session's (sids
        # differ across servers otherwise)
        hsolo = solo.submit_session("mamba2_130m", n_tokens=4, seed=i)
        solo.run()
        np.testing.assert_array_equal(np.asarray(h.result()),
                                      np.asarray(hsolo.result()))


# ---------------------------------------------------------------------------
# churn: admission / retirement at step boundaries
# ---------------------------------------------------------------------------

def test_churn_mixed_lengths_and_staggered_arrivals():
    server = SimdramServer(n_machines=2, n_banks=8)
    hs = [server.submit_session(MIX[i % len(MIX)], n_tokens=2 + i % 4,
                                arrival_ns=i * 700.0)
          for i in range(8)]
    stats = server.run()
    assert all(h.done() for h in hs)
    assert stats.n_sessions == 8
    assert stats.total_tokens == sum(2 + i % 4 for i in range(8))
    for h in hs:
        s = h.session
        # no token can complete before the session existed
        assert s.first_token_ns >= s.arrival_ns
        assert s.finish_ns is not None and s.finish_ns >= s.first_token_ns
    # a second run on the same server serves new sessions cleanly
    h2 = server.submit_session("qwen1_5_0_5b", n_tokens=2)
    server.run()
    assert h2.done() and stats.n_sessions == 8  # old stats unaffected


def test_admission_only_at_step_boundaries():
    # a session arriving mid-flight joins a busy machine only once the
    # modeled clock reaches its arrival — its first token cannot predate
    # the arrival, and the machine clock at admission covers it
    server = SimdramServer(n_machines=1, n_banks=8)
    server.submit_session("qwen1_5_0_5b", n_tokens=6, arrival_ns=0.0)
    late = server.submit_session("qwen1_5_0_5b", n_tokens=2,
                                 arrival_ns=1.0)
    server.run()
    assert late.done()
    assert late.session.first_token_ns >= late.session.arrival_ns


# ---------------------------------------------------------------------------
# machine-pool sharding and isolation
# ---------------------------------------------------------------------------

def test_pool_shards_and_isolates_perfstats():
    server = SimdramServer(n_machines=2, n_banks=8)
    hs = [server.submit_session("qwen1_5_0_5b", n_tokens=3)
          for _ in range(8)]
    stats = server.run()
    assert all(h.done() for h in hs)
    assert stats.users == 8
    per_machine = [{s.tenant for s in server.completed
                    if s.machine_index == i} for i in range(2)]
    # least-active sharding balances 8 sessions 4/4
    assert sorted(len(g) for g in per_machine) == [4, 4]
    # isolation: each machine's PerfStats tenants are exactly its own
    # sessions — no cross-session leakage between pool members
    for i, b in enumerate(server.batchers):
        assert set(b.machine.stats.tenants) == per_machine[i]
        assert b.machine.stats.total_ns > 0
    assert per_machine[0].isdisjoint(per_machine[1])


# ---------------------------------------------------------------------------
# ServingStats: SLO metrics on top of PerfStats.snapshot()
# ---------------------------------------------------------------------------

def test_serving_stats_snapshot_structure():
    server = SimdramServer(n_machines=2, n_banks=8)
    for i in range(8):
        server.submit_session(MIX[i % len(MIX)], n_tokens=3,
                              arrival_ns=i * 100.0)
    stats = server.run()
    snap = stats.snapshot()
    json.dumps(snap)                               # JSON-safe throughout
    assert snap["users"] == 8 and snap["n_sessions"] == 8
    assert snap["total_tokens"] == 24
    assert 0 < snap["p50_token_ns"] <= snap["p99_token_ns"]
    assert 0 < snap["p50_ttft_ns"] <= snap["p99_ttft_ns"]
    assert snap["tokens_per_s"] > 0 and snap["span_ns"] > 0
    assert len(snap["machines"]) == 2
    for m in snap["machines"]:
        # the per-machine section embeds the existing PerfStats snapshot
        assert m["perf"]["execute"]["n_programs"] > 0
        assert "schedule_hits" in m["cache"]
    text = stats.report()
    assert "ns/token" in text and "tokens/s" in text


def test_batched_throughput_beats_sequential():
    # the serve/batched gate's logic at test scale: continuous batching
    # across the bank axis must not lower aggregate modeled throughput
    # vs serving the same sessions one at a time
    n, toks = 6, 3
    batched = SimdramServer(n_machines=1, n_banks=8)
    for i in range(n):
        batched.submit_session("qwen1_5_0_5b", n_tokens=toks, seed=i)
    bstats = batched.run()
    seq_span = 0.0
    for i in range(n):
        solo = SimdramServer(n_machines=1, n_banks=8)
        solo.submit_session("qwen1_5_0_5b", n_tokens=toks, seed=i)
        seq_span += solo.run().span_ns
    seq_tps = n * toks / seq_span * 1e9
    assert bstats.tokens_per_s >= seq_tps


# ---------------------------------------------------------------------------
# concurrency: threads and asyncio
# ---------------------------------------------------------------------------

def test_two_thread_submission_stress():
    server = SimdramServer(n_machines=2, n_banks=8)
    handles: dict[int, list] = {0: [], 1: []}

    def submit(tid):
        for i in range(4):
            handles[tid].append(server.submit_session(
                MIX[(tid * 4 + i) % len(MIX)], n_tokens=2 + i % 2,
                seed=tid * 4 + i))

    threads = [threading.Thread(target=submit, args=(t,)) for t in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    all_handles = handles[0] + handles[1]
    assert len(all_handles) == 8
    stats = server.run()
    assert all(h.done() for h in all_handles)
    assert stats.n_sessions == 8
    assert stats.total_tokens == 2 * sum(2 + i % 2 for i in range(4))
    # values stay oracle-exact under concurrent submission
    for tid in (0, 1):
        for i, h in enumerate(handles[tid]):
            s = h.session
            if s.profile.op in ("addition", "maximum"):
                want = _oracle_decode(
                    DecodeSession(0, s.profile, s.n_tokens,
                                  seed=tid * 4 + i),
                    s.profile.op, s.n_tokens)
                np.testing.assert_array_equal(np.asarray(h.result()), want)


def test_async_surface():
    server = SimdramServer(n_machines=2, n_banks=4)
    hs = [server.submit_session("qwen1_5_0_5b", n_tokens=2)
          for _ in range(4)]

    async def go():
        stats = await server.run_async()
        waited = await hs[0].wait_async()
        return stats, waited

    stats, waited = asyncio.run(go())
    assert waited is hs[0] and all(h.done() for h in hs)
    assert stats.n_sessions == 4


# ---------------------------------------------------------------------------
# satellite: drain() honors priority in packing order
# ---------------------------------------------------------------------------

def test_drain_priority_orders_packing():
    m = SimdramMachine()
    a = RNG.integers(0, 100, 64)
    b = RNG.integers(0, 100, 64)
    low = m.submit("addition", a, b, tenant="low", priority=0)
    high = m.submit("multiplication", a, b, tenant="high", priority=5)
    m.drain(n_banks=1)
    # on one bank, the higher latency class issues first despite
    # arriving second
    assert high.timing.start_ns < low.timing.start_ns
    np.testing.assert_array_equal(np.asarray(low.result()), (a + b) & 0xFF)
    # equal priority keeps FIFO order (the pre-fix behavior is the tie
    # default, not the override)
    m2 = SimdramMachine()
    f1 = m2.submit("addition", a, b, tenant="x")
    f2 = m2.submit("multiplication", a, b, tenant="y")
    m2.drain(n_banks=1)
    assert f1.timing.start_ns < f2.timing.start_ns


def test_drain_priority_takes_least_loaded_banks_first():
    # two banks, three requests: the high-priority latecomer packs first,
    # getting a bank to itself rather than queueing behind the others
    m = SimdramMachine()
    a = RNG.integers(0, 100, 64)
    b = RNG.integers(0, 100, 64)
    fs = [m.submit("multiplication", a, b, tenant=f"t{i}") for i in range(2)]
    hi = m.submit("addition", a, b, tenant="hi", priority=9)
    m.drain(n_banks=2)
    assert hi.timing.queue_ns == 0.0
    assert max(f.timing.start_ns for f in fs) > 0.0


# ---------------------------------------------------------------------------
# satellite: batched drain (stacked banked dispatch before arbitration)
# ---------------------------------------------------------------------------

def test_drain_batched_values_and_single_request():
    a = [RNG.integers(0, 100, 64) for _ in range(4)]
    b = [RNG.integers(0, 100, 64) for _ in range(4)]
    m = SimdramMachine()
    futs = [m.submit("addition", a[i], b[i], tenant=f"s{i}")
            for i in range(4)]
    res = m.drain(n_banks=8, batch=True)
    # 4 compatible submissions collapse into ONE bank-parallel request
    assert res.n_requests == 1
    assert len(res.requests[0].bank_ids) == 4
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      (a[i] + b[i]) & 0xFF)
        assert f.timing is res.requests[0]          # riders share timing


def test_drain_batched_chunks_to_bank_capacity():
    a = [RNG.integers(0, 50, 32) for _ in range(6)]
    m = SimdramMachine()
    futs = [m.submit("addition", a[i], a[i], tenant=f"s{i}")
            for i in range(6)]
    res = m.drain(n_banks=4, batch=True)
    assert res.n_requests == 2                       # 4 + 2
    widths = sorted(len(r.bank_ids) for r in res.requests)
    assert widths == [2, 4]
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      (a[i] * 2) & 0xFF)


def test_drain_batched_groups_by_compatibility():
    a = RNG.integers(0, 100, 64)
    b = RNG.integers(0, 100, 64)
    m = SimdramMachine()
    adds = [m.submit("addition", a, b, tenant=f"a{i}") for i in range(2)]
    muls = [m.submit("multiplication", a, b, tenant=f"m{i}")
            for i in range(2)]
    res = m.drain(n_banks=8, batch=True)
    assert res.n_requests == 2                       # one per trace group
    assert {r.name for r in res.requests} \
        == {"addition/8b", "multiplication/8b"}
    for f in adds:
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      (a + b) & 0xFF)
    for f in muls:
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      (a * b) & 0xFF)


def test_drain_batched_defer_matches_replay_equivalence():
    # no-regression gate: the stacked dispatch under "defer" must still
    # satisfy the property-tested anchor — identical traces on N banks
    # equal TraceReplayTiming.replay cycle-for-cycle
    a = [RNG.integers(0, 100, 64) for _ in range(4)]
    m = SimdramMachine()
    futs = [m.submit("addition", a[i], a[i], tenant=f"s{i}")
            for i in range(4)]
    res = m.drain(n_banks=4, refresh_policy="defer", batch=True)
    _, trace = m.memory.get("addition", 8, True)
    want = TraceReplayTiming(m.timing).replay(trace, banks=4)
    got = futs[0].replay
    assert res.ns == pytest.approx(want.ns)
    assert got.ns == pytest.approx(want.ns)
    assert got.n_acts == want.n_acts
    assert got.n_seqs == want.n_seqs
    assert got.tfaw_stall_ns == pytest.approx(want.tfaw_stall_ns)
    assert got.refresh_stall_ns == pytest.approx(want.refresh_stall_ns)


def test_drain_batched_tenant_meters_sum_to_machine():
    a = [RNG.integers(0, 100, 64) for _ in range(4)]
    b = [RNG.integers(0, 100, 64) for _ in range(4)]
    m = SimdramMachine(mode="replay")
    futs = [m.submit("addition", a[i], b[i], tenant=f"s{i}")
            for i in range(4)]
    m.drain(n_banks=8, batch=True)
    [f.result() for f in futs]
    tenants = list(m.stats.tenants.values())
    assert len(tenants) == 4
    for meter in ("exec_ns", "exec_nj", "elem_ops", "replay_ns",
                  "total_ns", "transpose_ns"):
        total = sum(getattr(st, meter) for st in tenants)
        assert total == pytest.approx(getattr(m.stats, meter)), meter
    # counters count per rider by design: 4 riders, 1 machine dispatch
    assert sum(st.n_programs for st in tenants) == 4
    assert m.stats.n_programs == 1


def test_submit_arrival_ns_reaches_request_timing():
    m = SimdramMachine()
    a = RNG.integers(0, 100, 32)
    fut = m.submit("addition", a, a, arrival_ns=1000.0)
    m.drain(n_banks=2)
    t = fut.timing
    assert t.arrival_ns >= 1000.0                    # cycle-quantized
    assert t.start_ns >= t.arrival_ns and t.queue_ns >= 0.0


# ---------------------------------------------------------------------------
# satellite: the whole-schedule memo
# ---------------------------------------------------------------------------

def test_schedule_memo_serves_repeated_steps():
    a = [RNG.integers(0, 100, 64) for _ in range(4)]
    m = SimdramMachine()
    results = []
    for _ in range(3):
        futs = [m.submit("addition", a[i], a[i], tenant=f"s{i}")
                for i in range(4)]
        results.append((m.drain(n_banks=8, batch=True),
                        [f.timing for f in futs]))
    cs = m.memory.stats()
    assert cs["schedule_misses"] == 1 and cs["schedule_hits"] == 2
    first, later = results[0][0], results[2][0]
    assert later.ns == first.ns and later.cycles == first.cycles
    assert later.n_acts == first.n_acts
    for rt0, rt2 in zip(results[0][1], results[2][1]):
        assert rt2.start_ns == rt0.start_ns
        assert rt2.finish_ns == rt0.finish_ns
        assert rt2.stream_finish_ns == rt0.stream_finish_ns


def test_schedule_memo_hit_is_stepped_loop_exact():
    # a memo-served busy period must equal a freshly stepped one — run
    # the same request set on a memo-less scheduler as the oracle
    from repro.ops import BankScheduler
    a = RNG.integers(0, 100, 64)
    m = SimdramMachine()
    shapes = []
    for _ in range(2):
        futs = [m.submit("addition", a, a, tenant=f"s{i}")
                for i in range(3)]
        m.drain(n_banks=4, batch=True)
        shapes.append([f.timing for f in futs])
    _, trace = m.memory.get("addition", 8, True)
    fresh = BankScheduler(timing=m.timing, n_banks=4)
    fresh.enqueue(trace, banks=3, tenant="s0", name="addition/8b")
    want = fresh.run()
    hit = shapes[1][0]
    assert hit.finish_ns == pytest.approx(want.requests[0].finish_ns)
    assert hit.n_acts == want.requests[0].n_acts


def test_schedule_memo_relabels_live_requests():
    # the memo key is content-only; a hit re-labels names/tenants/lanes
    # from the live request set instead of echoing the cached ones
    a = RNG.integers(0, 100, 64)
    m = SimdramMachine()
    f1 = m.submit("addition", a, a, tenant="alice")
    m.drain(n_banks=2)
    f2 = m.submit("addition", a, a, tenant="bob")
    m.drain(n_banks=2)
    assert m.memory.stats()["schedule_hits"] == 1
    assert f2.timing.tenant == "bob" and f1.timing.tenant == "alice"
    assert f2.timing.finish_ns == f1.timing.finish_ns


def test_continuous_batcher_clock_advances_by_makespan():
    m = SimdramMachine()
    batcher = ContinuousBatcher(m, n_banks=4)
    s = DecodeSession(0, profile_for("qwen1_5_0_5b"), 2)
    batcher.admit(s)
    assert batcher.clock_ns == 0.0
    batcher.step()
    t1 = batcher.clock_ns
    assert t1 > 0 and not s.done
    finished = batcher.step()
    assert finished == [s] and s.done and batcher.active == []
    assert batcher.clock_ns > t1
    assert s.finish_ns == pytest.approx(batcher.clock_ns)
