"""Backend registry parity matrix: every executable backend must agree with
the faithful numpy ``Subarray`` oracle (the ``reference`` backend) op-for-op.

The matrix is (op × element width × backend); operands are random.  This is
the contract that lets new substrates plug into ``repro.core.backends`` —
pass this matrix and every ``bbop_*`` / pipeline / serving path works.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.backends import (execute_program, get_backend, list_backends,
                                 set_default_backend, use_backend)
from repro.ops import compile_bbop
from repro.ops.bbops import planes_of, values_of

N = 96
RNG = np.random.default_rng(0xBEEF)

# op name → (n_inputs, out_bits fn, numpy oracle-of-oracles for sanity)
BINARY_EXPECT = {
    "addition": lambda a, b, m: (a + b) & m,
    "subtraction": lambda a, b, m: (a - b) & m,
    "multiplication": lambda a, b, m: (a * b) & m,
    "greater": lambda a, b, m: (a > b).astype(np.int64),
    "maximum": lambda a, b, m: np.maximum(a, b),
}
UNARY_EXPECT = {
    "relu": lambda a, n: np.where(a >> (n - 1), 0, a),
}
EXEC_BACKENDS = ("unrolled", "pallas")


def _operands(n_bits):
    hi = 1 << n_bits
    a = RNG.integers(0, hi, N).astype(np.int64)
    b = RNG.integers(0, hi, N).astype(np.int64)
    return a, b


def _run(op, n_bits, backend, operands):
    planes = {}
    n = None
    for name, vals in operands.items():
        planes[name], n = planes_of(jnp.asarray(vals, jnp.int32), n_bits)
    prog = compile_bbop(op, n_bits)
    ob = {prog.outputs[0]: 1} if op == "greater" else None
    outs = execute_program(prog, planes, out_bits=ob, backend=backend)
    return np.asarray(values_of(outs[prog.outputs[0]], n))


@pytest.mark.parametrize("backend", EXEC_BACKENDS)
@pytest.mark.parametrize("n_bits", [8, 16])
@pytest.mark.parametrize("op", sorted(BINARY_EXPECT))
def test_binary_parity_vs_reference(op, n_bits, backend):
    a, b = _operands(n_bits)
    ops = {"a": a, "b": b}
    got = _run(op, n_bits, backend, ops)
    oracle = _run(op, n_bits, "reference", ops)
    np.testing.assert_array_equal(got, oracle)
    np.testing.assert_array_equal(
        got, BINARY_EXPECT[op](a, b, (1 << n_bits) - 1))


@pytest.mark.parametrize("backend", EXEC_BACKENDS)
@pytest.mark.parametrize("n_bits", [8, 16])
@pytest.mark.parametrize("op", sorted(UNARY_EXPECT))
def test_unary_parity_vs_reference(op, n_bits, backend):
    a, _ = _operands(n_bits)
    ops = {"a": a}
    got = _run(op, n_bits, backend, ops)
    oracle = _run(op, n_bits, "reference", ops)
    np.testing.assert_array_equal(got, oracle)
    np.testing.assert_array_equal(got, UNARY_EXPECT[op](a, n_bits))


def test_registry_surface():
    assert {"reference", "unrolled", "pallas"} <= set(list_backends())
    assert callable(get_backend("pallas"))
    with pytest.raises(KeyError):
        get_backend("no-such-substrate")
    with pytest.raises(KeyError):
        set_default_backend("no-such-substrate")


def test_use_backend_scopes_default():
    from repro.core import backends
    before = backends.default_backend()
    with use_backend("reference"):
        assert backends.default_backend() == "reference"
        with use_backend("pallas"):
            assert backends.default_backend() == "pallas"
        assert backends.default_backend() == "reference"
    assert backends.default_backend() == before


def test_use_backend_keeps_inner_set_default():
    """A set_default_backend made *inside* the scope must survive the exit,
    not be silently rolled back to the at-entry default."""
    from repro.core import backends
    before = backends.default_backend()
    try:
        with use_backend("pallas"):
            set_default_backend("reference")
            assert backends.default_backend() == "reference"
        assert backends.default_backend() == "reference"
        # but an untouched scope still restores as before
        with use_backend("pallas"):
            pass
        assert backends.default_backend() == "reference"
    finally:
        set_default_backend(before)


def test_use_backend_keeps_pin_of_own_name():
    """set_default_backend(<the scope's own backend>) — "make the current
    scope's backend the process default" — must survive too; a name
    comparison on exit cannot distinguish this from an untouched scope."""
    from repro.core import backends
    before = backends.default_backend()
    try:
        with use_backend("pallas"):
            set_default_backend("pallas")
        assert backends.default_backend() == "pallas"
        # a set inside a *nested* scope also wins over every level
        with use_backend("pallas"):
            with use_backend("reference"):
                set_default_backend("pallas")
        assert backends.default_backend() == "pallas"
    finally:
        set_default_backend(before)


def test_bbop_backend_kwarg():
    from repro.ops import bbop_add
    a = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    exp = (np.asarray(a) + np.asarray(b)) & 255
    for be in ("reference", "unrolled", "pallas"):
        np.testing.assert_array_equal(
            np.asarray(bbop_add(a, b, 8, backend=be)), exp)
