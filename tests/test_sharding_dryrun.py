"""Sharding resolution + dry-run plumbing that runs on ONE device (the full
512-device dry-run is exercised via repro.launch.dryrun in its own process;
a reduced-scale lowering is validated in-subprocess here)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES, shape_applicable

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_resolve_pspec_divisibility_fallback():
    from repro.distributed.sharding import resolve_pspec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # model axis of size 1 → everything replicates
    assert resolve_pspec(("embed", "mlp"), (64, 128), mesh) == PS()


def test_cell_grid_is_complete():
    """10 archs × 4 shapes with exactly the documented long_500k skips."""
    cells = [(a, s) for a in ARCHS for s in SHAPES
             if shape_applicable(get_config(a), s)]
    assert len(cells) == 10 * 4 - 8


def test_input_specs_cover_all_model_inputs():
    from repro.launch.dryrun import input_specs
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            if not shape_applicable(cfg, name):
                continue
            spec = input_specs(cfg, shape)
            assert "tokens" in spec
            if cfg.enc_dec:
                assert "encoder_frames" in spec
            if cfg.rope == "mrope":
                assert "mrope_positions" in spec
            for sds in jax.tree.leaves(spec):
                assert isinstance(sds, jax.ShapeDtypeStruct)


@pytest.mark.slow
def test_dryrun_cell_compiles_on_16_devices():
    """Scaled-down end-to-end dry-run (16 host devices, 4×4 mesh) — proves
    the lowering path without the 512-device cost."""
    code = """
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=16'
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_reduced
        from repro.launch import dryrun
        mesh = jax.make_mesh((4, 4), ('data', 'model'))
        cfg = dataclasses.replace(get_reduced('qwen1_5_0_5b'))
        shape = dataclasses.replace(dryrun.SHAPES['train_4k'],
                                    seq_len=256, global_batch=8)
        out = dryrun._lower_with(cfg, 'qwen1.5-0.5b', shape, mesh, 'train_4k')
        c = out['compiled']
        assert out['flops_per_device'] > 0
        txt = c.as_text()
        stats = dryrun.collective_bytes(txt)
        assert stats['total_bytes'] > 0, 'expected gradient collectives'
        print('OK', stats['counts'])
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = '''
      %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
      %ag = bf16[512]{0} all-gather(bf16[256]{0} %y), dimensions={0}
      %z = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
    '''
    stats = collective_bytes(hlo)
    assert stats["bytes"]["all-reduce"] == 128 * 256 * 4
    assert stats["bytes"]["all-gather"] == 512 * 2
    assert stats["counts"]["all-reduce"] == 1
