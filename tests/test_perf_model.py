"""DRAM perf model golden values + the timed execution layer.

Three layers of coverage:

* golden-value tests for ``SimdramPerfModel.latency_ns`` / ``energy_nj`` /
  ``throughput_gops`` on μPrograms with known command mixes (synthetic
  streams with hand-counted AAP/AP/TRA, and Table-5 compiled ops);
* the fixed edge cases: sub-byte baseline precisions (``n_bits < 8`` used
  to raise ZeroDivisionError) and narrow-lane transposition (``lanes <
  512`` used to report zero cost);
* parity between a ``simdram_pipeline`` chain's PerfStats and a hand-summed
  model of the same chain (μPrograms + movement + transposition) on every
  backend, banked and unbanked — the acceptance criterion.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.backends import PerfStats, timed
from repro.core.circuits import PAPER_COUNTS, compile_operation
from repro.core.uprogram import AAP, AP, DRow, P_T0, P_T1, P_T2, P_T3, UProgram
from repro.ops import (bbop_add, bbop_mul, bbop_relu, compile_bbop,
                       simdram_pipeline)
from repro.simdram.timing import (BaselineModel, SimdramPerfModel,
                                  TranspositionModel)

# hand-computed DDR4-2400 command-sequence latencies (paper Table 2 timing):
#   AP  = tRAS + tRP        = 32.0 + 14.16
#   AAP = 2·tRAS + tRP      = 64.0 + 14.16
T_AP = 46.16
T_AAP = 78.16
# Micron-model activation energies: AAP = 2 activations, AP = triple-row
E_AAP = 5.8 * 2
E_AP = 5.8 * (1 + 2 * 0.22)
ROW_LANES = 8 * 1024 * 8


def _toy(n_aap: int, n_ap: int) -> UProgram:
    """A μProgram whose command mix is exactly (n_aap AAPs, n_ap APs)."""
    ops = [AAP(DRow("a", 0), (P_T0,))] * n_aap \
        + [AP((P_T0, P_T1, P_T2))] * n_ap
    return UProgram(name="toy", n_bits=4, prologue=ops, body=[],
                    body_reps=0, inputs=("a",), outputs=("a",))


# ---------------------------------------------------------------------------
# Golden values: latency / energy / throughput
# ---------------------------------------------------------------------------


def test_latency_golden_synthetic():
    m = SimdramPerfModel()
    assert m.latency_ns(_toy(3, 2)) == pytest.approx(3 * T_AAP + 2 * T_AP)
    assert m.latency_ns(_toy(0, 1)) == pytest.approx(T_AP)
    assert m.latency_ns(_toy(1, 0)) == pytest.approx(T_AAP)


def test_energy_golden_synthetic():
    m = SimdramPerfModel()
    # every AP is a TRA → extra_tra = 0
    assert m.energy_nj(_toy(3, 2)) == pytest.approx(3 * E_AAP + 2 * E_AP)
    # an AAP sourced from a triple performs the TRA on its first ACTIVATE:
    # one AAP's energy plus the +22%-per-extra-row penalty for two rows
    fused = UProgram(name="fused", n_bits=4,
                     prologue=[AAP((P_T0, P_T1, P_T2), (P_T3,))],
                     body=[], body_reps=0)
    assert m.energy_nj(fused) == pytest.approx(E_AAP + 5.8 * 2 * 0.22)


def test_throughput_golden_synthetic():
    m = SimdramPerfModel()
    prog = _toy(3, 2)
    lat = 3 * T_AAP + 2 * T_AP
    assert m.throughput_gops(prog, 1) == pytest.approx(ROW_LANES / lat)
    assert m.throughput_gops(prog, 16) == pytest.approx(16 * ROW_LANES / lat)


@pytest.mark.parametrize("op,n_bits", [
    ("addition", 8), ("addition", 16), ("multiplication", 8),
    ("relu", 8), ("greater", 8), ("if_else", 8), ("xor_reduction", 16),
])
def test_latency_matches_command_mix(op, n_bits):
    """Compiled ops: latency = the command mix's summed AAP/AP sequence
    latencies (the paper's §7 methodology), with mix ≡ Table-5 count."""
    m = SimdramPerfModel()
    prog = compile_operation(op, n_bits)
    mix = prog.command_mix()
    assert mix["AAP"] + mix["AP"] == prog.command_count()
    assert m.latency_ns(prog) == pytest.approx(
        mix["AAP"] * T_AAP + mix["AP"] * T_AP)
    assert m.energy_nj(prog) > 0
    assert m.power_w(prog) > 0


def test_latency_golden_greater_closed_form():
    """'greater' compiles to exactly the Table-5 count (3n+2, all AAPs or
    APs) — its modeled latency is a fully closed-form golden value."""
    m = SimdramPerfModel()
    prog = compile_operation("greater", 8)
    assert prog.command_count() == PAPER_COUNTS["greater"](8) == 26
    mix = prog.command_mix()
    assert m.latency_ns(prog) == pytest.approx(
        mix["AAP"] * T_AAP + mix["AP"] * T_AP)
    assert m.throughput_gops(prog, 16) == pytest.approx(
        16 * ROW_LANES / m.latency_ns(prog))


# ---------------------------------------------------------------------------
# Fixed edge cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [1, 2, 4, 8, 32])
def test_baseline_subbyte_precisions(n_bits):
    """n_bits < 8 used to floor bytes_per_elem to 0 → ZeroDivisionError."""
    b = BaselineModel()
    cpu = b.throughput_gops("default", n_bits)
    gpu = b.throughput_gops("default", n_bits, gpu=True)
    assert cpu == pytest.approx(76.8 / (3 * n_bits / 8))
    assert gpu == pytest.approx(652.8 / (3 * n_bits / 8))


def test_baseline_stream_profile_in_bits():
    b = BaselineModel()
    # relu streams (1 in, 1 out): 2 × 4 bits = 1 byte per element
    assert b.throughput_gops("relu", 4) == pytest.approx(76.8)
    # byte multiples unchanged by the fix
    assert b.throughput_gops("default", 32) == pytest.approx(76.8 / 12)


def test_transposition_narrow_lanes_nonzero():
    """lanes < cacheline_bits used to floor n_lines to 0 → zero cost."""
    t = TranspositionModel()
    # 8 planes × ⌈64/512⌉ = 8 lines; 512 B through buffer + channel
    assert t.first_subarray_ns(8, 64) == pytest.approx(
        8 * 0.25 + 8 * 64 / 19.2)
    assert t.first_subarray_ns(1, 32) > 0


def test_transposition_ceil_on_non_multiples():
    t = TranspositionModel()
    exact = t.first_subarray_ns(8, 512)
    assert exact == pytest.approx(8 * 0.25 + 8 * 64 / 19.2)
    # one extra lane ⇒ a whole extra line per plane
    assert t.first_subarray_ns(8, 513) == pytest.approx(2 * exact)
    assert t.first_subarray_ns(8, 1024) == pytest.approx(2 * exact)


# ---------------------------------------------------------------------------
# Timed execution layer: PerfStats vs a hand-summed model (acceptance)
# ---------------------------------------------------------------------------

RNG = np.random.default_rng(0xD1)
N = 64
CHAIN_OPS = ("multiplication", "addition", "relu")


def _hand_chain_cost(banks: int):
    """Model the relu(add(mul(a,b),c)) pipeline by hand: 3 μPrograms, 2
    inter-op relocations of 8 result rows, 1 load pass (3 stacked operands)
    + 1 store pass."""
    m = SimdramPerfModel()
    progs = [compile_bbop(op, 8) for op in CHAIN_OPS]
    ns = sum(m.latency_ns(p) for p in progs)
    nj = sum(m.energy_nj(p) for p in progs) * banks
    ns += 2 * m.movement.intra_bank_ns(8)
    ns += m.transposition.first_subarray_ns(8, 3 * banks * N)
    ns += m.transposition.first_subarray_ns(8, banks * N)
    return ns, nj


@pytest.mark.parametrize("banks", [None, 2])
@pytest.mark.parametrize("backend", ["unrolled", "pallas", "reference"])
def test_pipeline_stats_match_hand_summed_model(backend, banks):
    shape = (N,) if banks is None else (banks, N)
    a, b, c = (jnp.asarray(RNG.integers(0, 256, shape), jnp.int32)
               for _ in range(3))
    with simdram_pipeline(backend=backend, banks=banks, timed=True) as p:
        pa, pb, pc = p.load([a, b, c], 8)
        res = p.store(bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8))
    st = p.stats
    exp_ns, exp_nj = _hand_chain_cost(banks or 1)
    assert st.total_ns == pytest.approx(exp_ns, rel=1e-6)
    assert st.total_nj == pytest.approx(exp_nj, rel=1e-6)
    assert st.n_programs == 3 and st.n_moves == 2 and st.n_transposes == 2
    assert st.max_banks == (banks or 1)
    assert st.elem_ops == 3 * N * (banks or 1)
    assert st.gops() == pytest.approx(st.elem_ops / exp_ns, rel=1e-6)
    # the modeled cost must not perturb correctness
    exp = ((np.asarray(a) * np.asarray(b)) & 255) + np.asarray(c) & 255
    exp = np.where(exp & 0x80, 0, exp)
    np.testing.assert_array_equal(np.asarray(res), exp)


def test_per_op_breakdown_and_report():
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with simdram_pipeline(timed=True) as p:
        pa = p.load(a, 8)
        p.store(bbop_add(bbop_add(pa, pa, 8), pa, 8))
    st = p.stats
    assert st.per_op["addition/8b"]["calls"] == 2
    m = SimdramPerfModel()
    assert st.per_op["addition/8b"]["ns"] == pytest.approx(
        2 * m.latency_ns(compile_bbop("addition", 8)))
    rep = p.perf_report()
    assert "modeled DRAM cost" in rep and "addition/8b" in rep
    assert f"{st.n_commands} command sequences" in rep


def test_untimed_pipeline_has_no_stats():
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with simdram_pipeline() as p:
        pa = p.load(a, 8)
        p.store(bbop_add(pa, pa, 8))
    assert p.stats is None
    with pytest.raises(ValueError, match="timed"):
        p.perf_report()


def test_timed_scope_unfused_roundtrips():
    """Horizontal bbops inside a timed scope pay per-op transposition: two
    operand coercions + one result store = 3 passes for one op."""
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with timed() as st:
        bbop_add(a, b, 8)
    assert st.n_programs == 1 and st.n_transposes == 3 and st.n_moves == 0
    m = SimdramPerfModel()
    assert st.transpose_ns == pytest.approx(
        3 * m.transposition.first_subarray_ns(8, N))


def test_shared_stats_nested_scopes_charge_once():
    """The same accumulator registered by nested scopes (the documented
    decode-loop pattern) must charge once per event, not once per scope —
    and the inner exit must not wipe the outer scope's movement tracking."""
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    st = PerfStats()
    with timed(stats=st):
        with simdram_pipeline(perf_stats=st) as p:
            out = bbop_add(p.load(a, 8), a, 8)
        assert st.n_programs == 1          # not 2
        bbop_add(out, a, 8)                # out is still resident out here
    assert st.n_programs == 2 and st.n_moves == 1
    m = SimdramPerfModel()
    assert st.exec_ns == pytest.approx(
        2 * m.latency_ns(compile_bbop("addition", 8)))


def test_resident_tracking_is_bounded():
    from repro.core.backends import _RESIDENT_CAP
    from repro.simdram.layout import BitplaneArray
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with timed() as st:
        pa = BitplaneArray.from_values(a, 8)
        for _ in range(_RESIDENT_CAP + 10):
            pa = bbop_add(pa, pa, 8)
        assert len(st._resident) <= _RESIDENT_CAP


def test_nested_timed_scopes_both_observe():
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with timed() as outer:
        with timed() as inner:
            bbop_add(a, a, 8)
        assert inner.n_programs == 1
        bbop_add(a, a, 8)
    assert outer.n_programs == 2 and inner.n_programs == 1


def test_shared_stats_accumulate_and_movement_is_scoped():
    """One accumulator across scopes keeps summing, but op outputs are only
    'resident' (movement-charged) within their own scope."""
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    st = PerfStats()
    with simdram_pipeline(perf_stats=st) as p:
        out = bbop_add(p.load(a, 8), a, 8)
    n1 = st.total_ns
    assert n1 > 0
    with simdram_pipeline(perf_stats=st) as p:
        bbop_add(out, out, 8)          # prior scope's output: no relocation
    assert st.total_ns > n1 and st.n_moves == 0 and st.n_programs == 2


def test_timed_rejects_conflicting_stats_and_model():
    """A shared accumulator charges with its own model; silently dropping a
    different model= would report costs under the wrong timing."""
    st = PerfStats()
    with pytest.raises(ValueError, match="not both"):
        with timed(stats=st, model=SimdramPerfModel()):
            pass
    # same model object is fine (no ambiguity)
    with timed(stats=st, model=st.model):
        pass
    # a failing pipeline __enter__ must unwind its backend override too
    from repro.core import backends
    before = backends.default_backend()
    with pytest.raises(ValueError, match="not both"):
        with simdram_pipeline(backend="pallas", perf_stats=st,
                              perf_model=SimdramPerfModel()):
            pass
    assert backends.default_backend() == before


def test_stats_reset():
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with timed() as st:
        bbop_add(a, a, 8)
    model = st.model
    st.reset()
    assert st.total_ns == 0 and st.n_programs == 0 and st.per_op == {}
    assert st.model is model


# ---------------------------------------------------------------------------
# Serving layer: modeled cost per decoded token
# ---------------------------------------------------------------------------


def test_simdram_argmax_charges_perf_stats():
    from repro.serve.decode import simdram_argmax
    vals = np.stack([RNG.permutation(256)[:100] for _ in range(2)])
    st = PerfStats()
    got = np.asarray(simdram_argmax(jnp.asarray(vals), n_bits=8,
                                    perf_stats=st))
    np.testing.assert_array_equal(got, vals.argmax(-1))
    # V=100 → 128 lanes → 2 halving rounds + 5 SWAR strides, each a
    # (greater + 2 if_else) triple
    assert st.n_programs == 21
    assert st.n_transposes == 3          # 2 loads + 1 store (indices only)
    assert st.max_banks == 2
    m = SimdramPerfModel()
    exp_exec = 7 * (m.latency_ns(compile_bbop("greater", 8))
                    + m.latency_ns(compile_bbop("if_else", 8))
                    + m.latency_ns(compile_bbop("if_else", 7)))
    assert st.exec_ns == pytest.approx(exp_exec, rel=1e-6)


def test_greedy_token_accumulates_across_calls():
    from repro.serve.decode import simdram_greedy_token
    logits = jnp.asarray(RNG.normal(size=(2, 64)).astype(np.float32))
    logits = logits.at[0, 7].set(9.0).at[1, 42].set(9.0)
    st = PerfStats()
    for _ in range(3):
        tok = simdram_greedy_token(logits, perf_stats=st)
    np.testing.assert_array_equal(np.asarray(tok), [7, 42])
    assert st.n_programs % 3 == 0 and st.n_programs > 0
    per_token_ns = st.total_ns / 3
    assert per_token_ns == pytest.approx(st.total_ns / 3)
    assert per_token_ns > 0


# ---------------------------------------------------------------------------
# The --smoke gate helper
# ---------------------------------------------------------------------------


def _load_bench_common():
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).parent.parent / "benchmarks" / "common.py"
    spec = importlib.util.spec_from_file_location("_bench_common", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_smoke_gate_flags_bad_rows():
    bad_perf_values = _load_bench_common().bad_perf_values
    good = "fig9live/add/8b,1.0,modeled_gops=0.1234 cpu_gops=25.60\n"
    assert bad_perf_values(good) == []
    assert bad_perf_values("x,0,modeled_gops=0.0000\n")
    assert bad_perf_values("x,0,modeled_gops=nan\n")
    assert bad_perf_values("x,0,rowscale16_gops=inf\n")
    assert bad_perf_values("x,0,gops_per_w=oops\n")
    # non-model keys are not gated
    assert bad_perf_values("x,0,melems_per_s=0.00 speedup=0.00x\n") == []


def test_smoke_gate_cache_and_replay_rows():
    """The cache / replay gates: a zero hit rate or a replayed latency
    below the analytic one must fail the --smoke run."""
    bad_gate_rows = _load_bench_common().bad_gate_rows
    good = ("cache/chain8/n512,1.0,compile_speedup=9.61x cache_hits=27 "
            "cache_misses=5 cache_hit_rate=0.844\n"
            "replay/addition/8b,0,replay_ns=7058.01 lockstep_ns=4623.98 "
            "analytic_ns=4568.40\n"
            "replay/refresh_ab/mul/8b,0,refresh_on_ns=45902.5 "
            "refresh_off_ns=44166.5\n")
    assert bad_gate_rows(good) == []
    assert bad_gate_rows("x,0,cache_hit_rate=0.000\n")
    assert bad_gate_rows("x,0,cache_hit_rate=nan\n")
    assert bad_gate_rows("x,0,replay_ns=10.0 analytic_ns=11.0\n")
    assert bad_gate_rows("x,0,replay_ns=0.0 analytic_ns=0.0\n")
    assert bad_gate_rows("x,0,replay_ns=inf analytic_ns=1.0\n")
    assert bad_gate_rows("x,0,replay_ns=oops analytic_ns=1.0\n")
    # a garbage *analytic* value must fail too, not slip past the ordering
    assert bad_gate_rows("x,0,replay_ns=10.0 analytic_ns=nan\n")
    assert bad_gate_rows("x,0,replay_ns=10.0 analytic_ns=0.0\n")
    # analytic alone (e.g. a modeled row) is not gated
    assert bad_gate_rows("x,0,analytic_ns=5.0\n") == []
    # desync-vs-lockstep and refresh on-vs-off orderings are gated too
    assert bad_gate_rows("x,0,replay_ns=10.0 lockstep_ns=11.0\n")
    assert bad_gate_rows("x,0,lockstep_ns=10.0 analytic_ns=11.0\n")
    assert bad_gate_rows("x,0,lockstep_ns=0.0 analytic_ns=0.0\n")
    assert bad_gate_rows("x,0,refresh_on_ns=10.0 refresh_off_ns=11.0\n")
    assert bad_gate_rows("x,0,refresh_on_ns=nan refresh_off_ns=1.0\n")
    assert bad_gate_rows("x,0,refresh_on_ns=12.0 refresh_off_ns=oops\n")
    assert bad_gate_rows("x,0,refresh_on_ns=12.0 refresh_off_ns=11.0\n") == []
    assert bad_gate_rows("x,0,lockstep_ns=11.0 analytic_ns=10.0\n") == []
