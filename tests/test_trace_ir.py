"""The lowered command-trace IR + compile/lower cache + trace-replay timing.

Four layers of coverage:

* **round-trip**: ``decode(lower(prog))`` reproduces the original μOp
  sequence (modulo the ``fixed`` mark that flattening consumes) for every
  Table-5 op at 4/8/16/32 bits, and the trace's command accounting is
  bit-identical to the μProgram's;
* **cache**: cached vs fresh compiles return identical traces, repeated
  ``bbop_*`` calls hit the process-wide compile/lower cache;
* **replay**: the per-bank FSM's replayed latency dominates the analytic
  command sum on every op (cycle quantization + ACT/PRE hazards only add
  stalls), with golden values for synthetic command streams, and
  ``simdram_pipeline(timed=True, model="replay")`` reports finite non-zero
  replayed ns/nJ ≥ analytic for every Table-5 op;
* **movement**: ``BitplaneArray.rebank`` fires the inter-bank RowClone-PSM
  movement hook and the report breaks movement/transposition out per kind.
"""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.circuits import ALL_OPS, compile_operation
from repro.core.trace import (canonical_uops, compile_trace, lower_program,
                              trace_cache_stats)
from repro.core.uprogram import (AAP, AP, DRow, P_T0, P_T1, P_T2, UProgram,
                                 normalize_uop)
from repro.ops import bbop_add, simdram_pipeline
from repro.ops.bbops import planes_of
from repro.core.backends import PerfStats, execute_program, timed
from repro.simdram.timing import SimdramPerfModel, TraceReplayTiming

RNG = np.random.default_rng(0xACE)
WIDTHS = (4, 8, 16, 32)


# ---------------------------------------------------------------------------
# Round-trip: decode(lower(prog)) ≡ prog.flatten()
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ALL_OPS)
def test_decode_lower_roundtrip_all_widths(op):
    for n in WIDTHS:
        prog, trace = compile_trace(op, n)
        assert trace.decode() == canonical_uops(prog), (op, n)
        assert trace.command_mix() == prog.command_mix(), (op, n)
        assert trace.n_commands == prog.command_count(), (op, n)
        # re-lowering the decoded form is a fixpoint
        relowered = lower_program(trace.to_uprogram())
        np.testing.assert_array_equal(relowered.cmds, trace.cmds)
        np.testing.assert_array_equal(relowered.seqs, trace.seqs)


def test_lowering_rejects_tra_over_d_rows():
    """TRA addresses decode B-group μRegisters only (paper §3.1); a
    hand-written AP over a D row must fail loudly at lowering, not with a
    KeyError mid-encode."""
    bad = UProgram(name="bad", n_bits=1, prologue=[
        AP((DRow("a", 0), P_T0, P_T1))], body=[], body_reps=0,
        inputs=("a",), outputs=("a",))
    with pytest.raises(TypeError, match="B-group ports"):
        lower_program(bad)


def test_roundtrip_preserves_multi_dst_and_fused_aaps():
    prog = UProgram(name="synthetic", n_bits=2, prologue=[
        AAP(DRow("a", 0), (P_T0, P_T1)),              # multi-row pair copy
        AP((P_T0, P_T1, P_T2)),                       # plain TRA
        AAP((P_T0, P_T1, P_T2), (DRow("out", 0),)),   # Case-2 fused
    ], body=[], body_reps=0, inputs=("a",), outputs=("out",))
    trace = lower_program(prog)
    assert trace.decode() == [normalize_uop(u) for u in prog.flatten()]
    # 3 sequences but 5 command rows (the pair AAP splits into 2 copies,
    # the fused AAP into MAJ + copy)
    assert trace.n_commands == 3 and trace.cmds.shape[0] == 5
    assert trace.command_mix() == {"AAP": 2, "AP": 1, "TRA": 2}


# ---------------------------------------------------------------------------
# Compile/lower cache
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["addition", "multiplication", "greater",
                                "xor_reduction", "abs"])
def test_cached_vs_fresh_compiles_identical(op, n_bits=8):
    """A cache hit must return exactly the trace a fresh synthesis +
    allocation + lowering run would produce.  (The hypothesis sweep over
    all ops × widths lives in test_trace_property.py.)"""
    _, cached = compile_trace(op, n_bits)
    fresh_prog = compile_operation(op, n_bits)       # bypasses the cache
    fresh = lower_program(fresh_prog)
    np.testing.assert_array_equal(cached.cmds, fresh.cmds)
    np.testing.assert_array_equal(cached.seqs, fresh.seqs)
    assert cached.row_index == fresh.row_index
    assert (cached.name, cached.n_bits) == (fresh.name, fresh.n_bits)
    assert fresh.decode() == canonical_uops(fresh_prog)


def test_compile_cache_returns_same_objects_and_counts_hits():
    before = trace_cache_stats()
    p1, t1 = compile_trace("addition", 8)
    p2, t2 = compile_trace("addition", 8)
    assert p1 is p2 and t1 is t2
    after = trace_cache_stats()
    assert after["hits"] >= before["hits"] + 1
    assert 0.0 <= after["hit_rate"] <= 1.0


def test_bbop_calls_share_compile_cache():
    a = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    bbop_add(a, a, 8)                  # ensure compiled once
    before = trace_cache_stats()
    for _ in range(3):
        bbop_add(a, a, 8)
    after = trace_cache_stats()
    assert after["hits"] >= before["hits"] + 3
    assert after["misses"] == before["misses"]


# ---------------------------------------------------------------------------
# Trace-replay timing substrate
# ---------------------------------------------------------------------------


def _toy(n_aap: int, n_ap: int) -> UProgram:
    ops = [AAP(DRow("a", 0), (P_T0,))] * n_aap \
        + [AP((P_T0, P_T1, P_T2))] * n_ap
    return UProgram(name="toy", n_bits=4, prologue=ops, body=[],
                    body_reps=0, inputs=("a",), outputs=("a",))


def test_replay_golden_synthetic():
    """DDR4-2400 cycle counts: tRAS → 39 cycles, tRP → 17, tCK = 0.833.
    An AAP occupies 2·39+17 = 95 cycles, an AP 39+17 = 56."""
    rt = TraceReplayTiming()
    assert (rt.c_ras, rt.c_rp, rt.c_rc) == (39, 17, 56)
    aap = rt.replay(lower_program(_toy(3, 0)))
    assert aap.cycles == 3 * 95 and aap.n_seqs == 3 and aap.n_acts == 6
    assert aap.ns == pytest.approx(3 * 95 * 0.833)
    ap = rt.replay(lower_program(_toy(0, 2)))
    assert ap.cycles == 2 * 56 and ap.n_acts == 2
    mixed = rt.replay(lower_program(_toy(1, 1)))
    assert mixed.cycles == 95 + 56
    # quantization stall vs the analytic ns sum is small and non-negative
    assert 0 <= aap.stall_ns < 3 * rt.timing.tCK_ns * 3


def test_replay_empty_trace_is_zero():
    rt = TraceReplayTiming()
    res = rt.replay(lower_program(_toy(0, 0)))
    assert res.ns == 0 and res.cycles == 0 and res.stall_ns == 0


@pytest.mark.parametrize("n_bits", [8, 16])
def test_replay_dominates_analytic_every_op(n_bits):
    m = SimdramPerfModel()
    for op in ALL_OPS:
        prog, trace = compile_trace(op, n_bits)
        rep = m.replay_result(trace)
        ana = m.latency_ns(prog)
        assert math.isfinite(rep.ns) and rep.ns > 0, op
        assert rep.ns >= ana, (op, rep.ns, ana)
        assert rep.stall_ns == pytest.approx(rep.ns - ana)
        assert m.replay_energy_nj(prog, trace) >= m.energy_nj(prog)


def test_timed_replay_pipeline_reports_side_by_side():
    """Acceptance: simdram_pipeline(timed=True, model="replay") produces
    finite, non-zero replayed ns/nJ ≥ the analytic model's, for every
    Table-5 op."""
    for op in ALL_OPS:
        prog, trace = compile_trace(op, 8)
        operands = {}
        for name in dict.fromkeys(prog.inputs):
            nb = 1 if name == "sel" else 8
            vals = jnp.asarray(RNG.integers(0, 1 << nb, 64), jnp.int32)
            operands[name], _ = planes_of(vals, nb)
        with timed(mode="replay") as st:
            execute_program(prog, operands)
        assert st.mode == "replay"
        assert math.isfinite(st.replay_ns) and st.replay_ns > 0, op
        assert st.replay_ns >= st.exec_ns > 0, op
        assert math.isfinite(st.replay_nj) and st.replay_nj > 0, op
        assert st.replay_nj >= st.exec_nj > 0, op
        assert st.per_op[f"{prog.name}/8b"]["replay_ns"] == pytest.approx(
            st.replay_ns)


def test_replay_mode_report_and_totals():
    a = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    with simdram_pipeline(timed=True, model="replay") as p:
        pa = p.load(a, 8)
        p.store(bbop_add(bbop_add(pa, pa, 8), pa, 8))
    st = p.stats
    assert st.replay_total_ns >= st.total_ns
    assert st.replay_total_ns == pytest.approx(
        st.replay_ns + st.movement_ns + st.transpose_ns)
    rep = p.perf_report()
    assert "replayed" in rep and "stall vs analytic" in rep
    assert "intra-bank LISA" in rep and "inter-bank PSM" in rep
    assert "to_bitplanes" in rep and "from_bitplanes" in rep
    assert "ns replayed" in rep          # per-op attribution line


def test_timed_mode_conflicts_rejected():
    with pytest.raises(ValueError, match="unknown timing mode"):
        PerfStats(mode="warp-speed")
    st = PerfStats()                      # analytic
    with pytest.raises(ValueError, match="mid-flight"):
        with timed(stats=st, mode="replay"):
            pass
    with pytest.raises(TypeError, match="timing mode"):
        simdram_pipeline(timed=True, model=SimdramPerfModel())


def test_analytic_mode_skips_replay_meters():
    a = jnp.asarray(RNG.integers(0, 256, 64), jnp.int32)
    with timed() as st:
        bbop_add(a, a, 8)
    assert st.replay_ns == 0 and st.replay_nj == 0
    assert "replayed" not in st.report()


# ---------------------------------------------------------------------------
# Inter-bank movement (RowClone PSM) via the layout hooks
# ---------------------------------------------------------------------------


def test_rebank_roundtrip_and_psm_charging():
    from repro.simdram.layout import BitplaneArray
    vals = jnp.asarray(RNG.integers(0, 256, 128), jnp.int32)
    pa = BitplaneArray.from_values(vals, 8)
    with timed() as st:
        banked = pa.rebank(2)
        assert banked.banked and banked.n_banks == 2
        back = banked.rebank(None)
    np.testing.assert_array_equal(np.asarray(back.to_values()),
                                  np.asarray(vals))
    m = SimdramPerfModel()
    # scatter: 8 planes × 2 banks; gather: the same rows ride the bus back
    assert st.n_moves_inter == 2 and st.n_moves_intra == 0
    assert st.movement_inter_ns == pytest.approx(
        2 * m.movement.inter_bank_ns(8 * 2))
    assert st.movement_ns == st.movement_inter_ns


def test_rebank_noop_and_validation():
    from repro.simdram.layout import BitplaneArray
    vals = jnp.asarray(RNG.integers(0, 256, 96), jnp.int32)
    pa = BitplaneArray.from_values(vals, 8)
    with timed() as st:
        assert pa.rebank(None) is pa and pa.rebank(1) is pa
    assert st.n_moves == 0
    with pytest.raises(ValueError, match="split"):
        pa.rebank(2)                      # 3 words don't split over 2 banks
    short = BitplaneArray.from_values(vals[:90], 8)
    with pytest.raises(ValueError, match="fully padded"):
        short.rebank(3)


def test_banked_execution_after_rebank_matches_unbanked():
    from repro.simdram.layout import BitplaneArray
    vals = jnp.asarray(RNG.integers(0, 256, 128), jnp.int32)
    pa = BitplaneArray.from_values(vals, 8)
    banked = pa.rebank(2)
    from repro.ops import bbop_add as add
    flat = np.asarray(add(pa, pa, 8).to_values())
    split = np.asarray(add(banked, banked, 8).to_values()).reshape(-1)
    np.testing.assert_array_equal(split, flat)
