"""Distributed behavior on 8 host devices — run in subprocesses so the main
test process keeps a single CPU device (the dry-run rule).

Whole module is tier-2 (``slow``): every test compiles a multi-device
training/pipeline step in a fresh subprocess (~10–20 s each).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pjit_train_step_on_4x2_mesh():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.distributed.sharding import tree_shardings, batch_shardings
        from repro.models.params import init_params
        from repro.models.transformer import model_defs
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import init_train_state, make_train_step
        from repro.train.data import DataConfig, synthetic_batch
        cfg = get_reduced('qwen1_5_0_5b')
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        defs = model_defs(cfg)
        sh = tree_shardings(defs, mesh)
        params = jax.tree.map(jax.device_put,
                              init_params(defs, jax.random.key(0)), sh)
        state = init_train_state(params)
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
        d = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        losses = []
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, 'use_mesh') else mesh:
            for s in range(8):
                state, m = step(state, synthetic_batch(d, s))
                losses.append(float(m['loss']))
        assert losses[-1] < losses[0], losses
        print('OK', losses[0], losses[-1])
    """)
    assert "OK" in out


@pytest.mark.parametrize("exchange", ["packed", "psum"])
def test_compressed_majority_vote_training(exchange):
    """Both vote collectives (bit-packed all-gather and the Σ±1 psum
    control) must train; their majority semantics are identical."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch.train import setup, build_mesh
        from repro.train.optimizer import AdamWConfig
        from repro.train.data import DataConfig, synthetic_batch
        cfg = get_reduced('qwen1_5_0_5b')
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        state, _, step = setup(cfg, mesh, AdamWConfig(lr=5e-3),
                               compressed=True, exchange={exchange!r})
        d = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        batch = synthetic_batch(d, 0)    # fixed batch: optimization signal
        losses = []
        for s in range(20):
            state, m = step(state, batch)
            losses.append(float(m['loss']))
        assert losses[-1] < losses[0] - 0.05, losses
        print('OK', losses[0], losses[-1])
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply, stack_stage_params
        mesh = jax.make_mesh((4, 2), ('pipe', 'model'))
        P, M, mb, d = 4, 6, 8, 16
        keys = jax.random.split(jax.random.key(0), P)
        stage_params = [ {'w': jax.random.normal(k, (d, d)) * 0.3} for k in keys ]
        def stage_fn(p, x):
            return jnp.tanh(x @ p['w'])
        x = jax.random.normal(jax.random.key(1), (M, mb, d))
        stacked = stack_stage_params(stage_params)
        y = pipeline_apply(stage_fn, stacked, x, mesh=mesh, axis='pipe')
        # sequential reference
        ref = x
        for p in stage_params:
            ref = stage_fn(p, ref)
        err = float(jnp.max(jnp.abs(y - ref)))
        assert err < 1e-5, err
        print('OK', err)
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_smaller_mesh(tmp_path):
    ck = str(tmp_path / "ck")
    run_with_devices(f"""
        import jax
        from repro.configs import get_reduced
        from repro.distributed.sharding import tree_shardings
        from repro.distributed.checkpoint import CheckpointManager
        from repro.models.params import init_params
        from repro.models.transformer import model_defs
        from repro.train.train_step import init_train_state
        cfg = get_reduced('qwen1_5_0_5b')
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        defs = model_defs(cfg)
        params = jax.tree.map(jax.device_put,
                              init_params(defs, jax.random.key(0)),
                              tree_shardings(defs, mesh))
        state = init_train_state(params)
        CheckpointManager({ck!r}).save(11, state, mesh, blocking=True)
        print('SAVED')
    """, n=8)
    out = run_with_devices(f"""
        import jax, numpy as np
        from repro.configs import get_reduced
        from repro.distributed.sharding import tree_shardings
        from repro.distributed.checkpoint import CheckpointManager
        from repro.models.params import init_params
        from repro.models.transformer import model_defs
        from repro.train.train_step import init_train_state
        cfg = get_reduced('qwen1_5_0_5b')
        mesh = jax.make_mesh((2, 2), ('data', 'model'))   # downscaled!
        defs = model_defs(cfg)
        like = init_train_state(init_params(defs, jax.random.key(1)))
        mgr = CheckpointManager({ck!r})
        sh = tree_shardings(defs, mesh)
        from repro.train.train_step import TrainState
        from repro.train.optimizer import AdamWState
        from repro.distributed.sharding import replicated
        st_sh = TrainState(params=sh, opt=AdamWState(
            step=replicated(mesh), m=sh, v=sh), error_fb=None)
        restored = mgr.restore(11, like, st_sh)
        ref = init_params(defs, jax.random.key(0))
        for a, b in zip(jax.tree.leaves(restored.params), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK restored on 2x2 from 4x2')
    """, n=4)
    assert "OK" in out


def test_two_phase_majority_vote_training():
    """H7 collective (all-to-all slice → vote → gather) trains correctly."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as PS
        from repro.configs import get_reduced
        from repro.distributed.compat import shard_map_compat
        from repro.distributed.sharding import tree_shardings
        from repro.models.params import init_params
        from repro.models.transformer import model_defs
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import (init_train_state,
                                            make_compressed_train_step)
        from repro.train.data import DataConfig, synthetic_batch
        cfg = get_reduced('qwen1_5_0_5b')
        mesh = jax.make_mesh((8, 1), ('data', 'model'))
        defs = model_defs(cfg)
        params = jax.tree.map(jax.device_put,
                              init_params(defs, jax.random.key(0)),
                              tree_shardings(defs, mesh))
        state = init_train_state(params, compressed=True)
        inner, da = make_compressed_train_step(cfg, AdamWConfig(lr=5e-3),
                                               mesh, two_phase=True)
        step = jax.jit(shard_map_compat(
            inner, mesh=mesh, axis_names={'data'},
            in_specs=(jax.tree.map(lambda _: PS(), state),
                      {'tokens': PS('data'), 'labels': PS('data')}),
            out_specs=(jax.tree.map(lambda _: PS(), state),
                       {'loss': PS(), 'aux': PS(), 'grad_norm': PS(),
                        'lr': PS()}),
            check_vma=False), donate_argnums=(0,))
        d = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        batch = synthetic_batch(d, 0)
        losses = []
        for s in range(18):
            state, m = step(state, batch)
            losses.append(float(m['loss']))
        assert losses[-1] < losses[0] - 0.03, losses
        print('OK', losses[0], losses[-1])
    """)
    assert "OK" in out


def test_pipeline_parallel_is_differentiable():
    """GPipe schedule must be trainable: jax.grad through the pipelined
    forward matches grads of the sequential composition."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import (pipeline_apply,
                                                stack_stage_params)
        mesh = jax.make_mesh((4, 2), ('pipe', 'model'))
        P, M, mb, d = 4, 4, 4, 8
        keys = jax.random.split(jax.random.key(0), P)
        stages = [{'w': jax.random.normal(k, (d, d)) * 0.3} for k in keys]
        stacked = stack_stage_params(stages)
        x = jax.random.normal(jax.random.key(1), (M, mb, d))

        def stage_fn(p, h):
            return jnp.tanh(h @ p['w'])

        def loss_pipe(params):
            y = pipeline_apply(stage_fn, params, x, mesh=mesh, axis='pipe')
            return jnp.sum(y ** 2)

        def loss_seq(stages):
            h = x
            for p in stages:
                h = stage_fn(p, h)
            return jnp.sum(h ** 2)

        g_pipe = jax.grad(loss_pipe)(stacked)['w']
        g_seq = jnp.stack([g['w'] for g in jax.grad(loss_seq)(stages)])
        err = float(jnp.max(jnp.abs(g_pipe - g_seq)))
        assert err < 1e-4, err
        print('OK', err)
    """)
    assert "OK" in out
