"""SimdramMachine: the session-scoped end-to-end API.

Covers the tentpole acceptance criteria:

* user-defined operations (never named in ``circuits.py``) registered via
  ``machine.define_op`` pass the full tri-backend parity matrix — 3
  backends × banked/unbanked × 4/8/16 bits — against both the ``reference``
  oracle and a numpy oracle-of-oracles, plus the lowered-IR round-trip
  (deterministic and, when hypothesis is present, randomly sampled);
* replay timing works for user ops out of the box (replay ≥ analytic);
* two machines with different ``DRAMTiming``/backend/bank configs run
  interleaved without sharing μProgram Memories, hooks, or PerfStats;
* the cross-op refresh phase threads through ``PerfStats`` and can only
  add stall over the per-op-anchored baseline.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.circuits import (compile_bitcount, list_operations, rebase,
                                 register_operation, spec_greater_equal,
                                 unregister_operation)
from repro.core.compiler import compile_slice
from repro.core.graph import lit_not
from repro.core.trace import TraceCache, canonical_uops
from repro.core.uprogram import DRow, concat_programs
from repro.ops import SimdramMachine, bbop_add, current_machine
from repro.simdram.machine import default_machine
from repro.simdram.timing import DRAMTiming

N = 64
RNG = np.random.default_rng(0xD1CE)


# ---------------------------------------------------------------------------
# The two user-defined operations (paper Step 1 inputs)
# ---------------------------------------------------------------------------


def build_gated_sub(g):
    """out = a − b·gate (borrow-chained), predicated per element."""
    a, b, gate, w = (g.input(n) for n in ("a", "b", "gate", "borrow"))
    bg = g.gate_and(b, gate)
    axb = g.gate_xor(a, bg)
    g.add_output("out", g.gate_xor(axb, w))
    g.add_output("borrow", g.gate_or_node(g.gate_and(lit_not(a), bg),
                                          g.gate_and(w, lit_not(axb))))


def compile_popcount_ge(n_bits, optimize=True):
    """popcount(a) >= popcount(b): two CSA-tree bitcounts feeding a
    borrow-scan compare — the full-control ``compile_fn`` entry point."""
    ob = max(1, n_bits.bit_length())
    pa = rebase(compile_bitcount(n_bits, optimize=optimize), {},
                {"out": "_pa"})
    pb = rebase(compile_bitcount(n_bits, optimize=optimize), {},
                {"a": "b", "out": "_pb"})
    ge = rebase(compile_slice(spec_greater_equal(), ob, optimize=optimize),
                {}, {"a": "_pa", "b": "_pb"})
    return concat_programs("popcount_ge", [pa, pb, ge], n_bits,
                           inputs=("a", "b"), outputs=("out",),
                           scratch=("_pa", "_pb"))


def _machine(**kw):
    m = SimdramMachine(**kw)
    m.define_op("gated_sub", build_gated_sub,
                invariants={"gate": DRow("gate", 0, fixed=True)},
                states={"borrow": 0})
    m.define_op("popcount_ge", compile_fn=compile_popcount_ge)
    return m


def _popcount(x):
    return np.vectorize(lambda v: bin(int(v)).count("1"))(x)


def _operands(n_bits, banked):
    shape = (3, N) if banked else (N,)
    hi = 1 << n_bits
    a = RNG.integers(0, hi, shape)
    b = RNG.integers(0, hi, shape)
    gate = RNG.integers(0, 2, shape)
    return a, b, gate


# ---------------------------------------------------------------------------
# Tri-backend parity matrix for user-defined ops
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("banked", [False, True], ids=["unbanked", "banked"])
@pytest.mark.parametrize("n_bits", [4, 8, 16])
@pytest.mark.parametrize("backend", ["reference", "unrolled", "pallas"])
def test_gated_sub_parity(backend, n_bits, banked):
    m = _machine(backend=backend)
    a, b, gate = _operands(n_bits, banked)
    got = np.asarray(m.op("gated_sub")(
        jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
        jnp.asarray(gate, jnp.int32), n_bits=n_bits))
    exp = np.where(gate, (a - b) & ((1 << n_bits) - 1), a)
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("banked", [False, True], ids=["unbanked", "banked"])
@pytest.mark.parametrize("n_bits", [4, 8, 16])
@pytest.mark.parametrize("backend", ["reference", "unrolled", "pallas"])
def test_popcount_ge_parity(backend, n_bits, banked):
    m = _machine(backend=backend)
    a, b, _ = _operands(n_bits, banked)
    got = np.asarray(m.op("popcount_ge")(
        jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
        n_bits=n_bits, out_bits=1))
    exp = (_popcount(a) >= _popcount(b)).astype(np.int64)
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("op,widths", [
    ("gated_sub", (4, 8, 16)), ("popcount_ge", (4, 8))])
def test_user_op_trace_roundtrip(op, widths):
    """decode(lower(prog)) ≡ canonical μOps for user-defined ops too —
    the IR invariant the reference backend leans on."""
    m = _machine()
    for n_bits in widths:
        prog, trace = m.memory.get(op, n_bits)
        assert trace.decode() == canonical_uops(prog)
        assert trace.command_mix() == prog.command_mix()
        assert trace.n_commands == prog.command_count()


def test_user_op_replay_at_least_analytic():
    """User ops get replay timing for free: FSM replay ≥ analytic sum."""
    m = _machine(backend="unrolled")
    a, b, gate = _operands(8, banked=False)
    with m.timed(mode="replay") as st:
        m.op("gated_sub")(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
                          jnp.asarray(gate, jnp.int32), n_bits=8)
        m.op("popcount_ge")(jnp.asarray(a, jnp.int32),
                            jnp.asarray(b, jnp.int32), n_bits=8, out_bits=1)
    assert st.n_programs == 2
    assert st.exec_ns > 0
    assert st.replay_ns >= st.exec_ns


# ---------------------------------------------------------------------------
# Machine isolation
# ---------------------------------------------------------------------------


def test_machines_isolate_caches_stats_and_timing():
    """Two machines with different timings/backends, run interleaved:
    independent μProgram Memories, independent PerfStats, and modeled
    latencies that reflect each machine's own DRAMTiming."""
    slow = DRAMTiming(tRAS_ns=64.0, tRP_ns=28.32)
    m1 = _machine(backend="unrolled")
    m2 = SimdramMachine(timing=slow, backend="reference")
    a, b, gate = _operands(8, banked=False)
    aj, bj, gj = (jnp.asarray(x, jnp.int32) for x in (a, b, gate))
    with m1.timed() as s1, m2.timed() as s2:
        r1 = m1.op("gated_sub")(aj, bj, gj, n_bits=8)
        r2 = m2.op("addition")(aj, bj, n_bits=8)
        r1b = m1.op("gated_sub")(aj, bj, gj, n_bits=8)
        r2b = m2.op("addition")(aj, bj, n_bits=8)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r1b))
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(r2b))
    # caches are private: each machine compiled only its own ops
    c1, c2 = m1.cache_stats(), m2.cache_stats()
    assert c1["entries"] == 1 and c1 == m1.memory.stats()
    assert c2["entries"] == 1
    assert c1["hits"] >= 1 and c2["hits"] >= 1
    # m2 never learned gated_sub; m1's registry never leaked process-wide
    with pytest.raises(KeyError):
        m2.op("gated_sub")
    assert "gated_sub" not in list_operations()
    # stats are private and charged with each machine's own model
    assert s1 is m1.stats and s2 is m2.stats
    assert s1.n_programs == 2 and s2.n_programs == 2
    # same command mix ⇒ latency scales with the slower timing
    m1_add = m1.model.latency_ns(m1.memory.get("gated_sub", 8)[0])
    m2_add = m2.model.latency_ns(m2.memory.get("addition", 8)[0])
    assert s1.exec_ns == pytest.approx(2 * m1_add)
    assert s2.exec_ns == pytest.approx(2 * m2_add)
    assert slow.t_aap_ns > DRAMTiming().t_aap_ns  # the knob actually moved


def test_machine_session_scopes_bbops_and_hooks():
    """Inside ``machine.session()`` the ambient bbop surface routes through
    the machine's μProgram Memory, and scoped hooks observe only work done
    under that machine's scope."""
    m1 = SimdramMachine(cache_capacity=8)
    m2 = SimdramMachine(cache_capacity=8)
    seen1, seen2 = [], []
    m1.register_transpose_hook(lambda kind, nb, lanes: seen1.append(kind))
    m2.register_transpose_hook(lambda kind, nb, lanes: seen2.append(kind))
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    assert current_machine() is None
    with m1.session():
        assert current_machine() is m1
        bbop_add(a, b, 8)
    assert current_machine() is None
    # the op compiled into m1's memory, not m2's, and not the global cache
    assert m1.cache_stats()["misses"] == 1
    assert m2.cache_stats()["misses"] == 0
    assert seen1 and not seen2      # to+from passes observed by m1 only
    bbop_add(a, b, 8)               # outside any session: default machine
    assert m1.cache_stats()["misses"] == 1
    assert not seen2


def test_machine_pipeline_binds_cache_backend_and_stats():
    m = SimdramMachine(banks=2, backend="unrolled", cache_capacity=16)
    av = RNG.integers(0, 256, (2, N))
    bv = RNG.integers(0, 256, (2, N))
    with m.pipeline(timed=True) as p:
        pa, pb = p.load([jnp.asarray(av, jnp.int32),
                         jnp.asarray(bv, jnp.int32)], 8)
        out = p.store(bbop_add(pa, pb, 8))
    np.testing.assert_array_equal(np.asarray(out), (av + bv) & 255)
    assert p.stats is m.stats               # the machine's own accumulator
    assert m.stats.n_programs == 1
    assert m.stats.max_banks == 2
    assert m.stats.transpose_ns > 0
    assert m.cache_stats()["misses"] == 1


def test_machine_cache_capacity_evicts_lru():
    m = SimdramMachine(cache_capacity=2)
    m.op("addition")(jnp.zeros(32, jnp.int32), jnp.zeros(32, jnp.int32),
                     n_bits=4)
    m.op("addition")(jnp.zeros(32, jnp.int32), jnp.zeros(32, jnp.int32),
                     n_bits=8)
    m.op("subtraction")(jnp.zeros(32, jnp.int32), jnp.zeros(32, jnp.int32),
                        n_bits=4)
    st = m.cache_stats()
    assert st["entries"] == 2
    assert st["evictions"] == 1
    assert ("addition", 4, True) not in m.memory      # LRU victim
    assert ("subtraction", 4, True) in m.memory


def test_session_scope_is_thread_local():
    """An open session on one thread must not leak into another thread's
    ops — that would cross-contaminate caches/backends between concurrent
    services (the exact isolation this API provides)."""
    import threading
    m = SimdramMachine()
    observed = []

    def other_thread():
        observed.append(current_machine())

    with m.session():
        t = threading.Thread(target=other_thread)
        t.start()
        t.join(timeout=30)
        assert current_machine() is m
    assert observed == [None]


def test_scoped_hooks_fire_once_per_pass_and_see_inputs():
    """Re-entered sessions (timed scope + bound op) must not double-fire
    scoped hooks, and a bound op's *input* layout conversions are observed
    too — one 'to' per operand pass, one 'from' for the result."""
    m = SimdramMachine(backend="unrolled")
    events = []
    m.register_transpose_hook(lambda kind, nb, lanes: events.append(kind))
    x = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    y = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with m.timed():                      # session already open...
        m.op("addition")(x, y, n_bits=8)  # ...bound op re-enters it
    assert events == ["to", "to", "from"]
    # standalone bound-op call: same counts
    events.clear()
    m.op("addition")(x, y, n_bits=8)
    assert events == ["to", "to", "from"]


def test_bound_op_counts_one_cache_access_per_call():
    m = SimdramMachine()
    x = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    m.op("addition")(x, x, n_bits=8)
    m.op("addition")(x, x, n_bits=8)
    st = m.cache_stats()
    assert (st["hits"], st["misses"]) == (1, 1)
    assert st["hit_rate"] == pytest.approx(0.5)


def test_machine_timed_rejects_mode_mismatch_with_explicit_stats():
    from repro.core.backends import PerfStats
    m = SimdramMachine()
    st = PerfStats(model=m.model, mode="analytic")
    with pytest.raises(ValueError, match="mid-flight"):
        with m.timed(mode="replay", stats=st):
            pass


# ---------------------------------------------------------------------------
# define_op validation + registry semantics
# ---------------------------------------------------------------------------


def test_define_op_rejects_bad_graphs_and_duplicates():
    m = SimdramMachine()
    with pytest.raises(TypeError):
        m.define_op("nothing")                        # no entry point
    with pytest.raises(ValueError, match="no outputs"):
        m.define_op("empty", lambda g: g.input("a"))
    with pytest.raises(ValueError, match="unknown inputs"):
        m.define_op("badstate", build_gated_sub,
                    states={"nosuch": 0})
    m.define_op("gated_sub", build_gated_sub,
                invariants={"gate": DRow("gate", 0, fixed=True)},
                states={"borrow": 0})
    with pytest.raises(ValueError, match="already defined"):
        m.define_op("gated_sub", build_gated_sub,
                    invariants={"gate": DRow("gate", 0, fixed=True)},
                    states={"borrow": 0})
    # override replaces, and unknown ops stay unknown
    m.define_op("gated_sub", build_gated_sub,
                invariants={"gate": DRow("gate", 0, fixed=True)},
                states={"borrow": 0}, override=True)
    with pytest.raises(KeyError):
        m.op("no_such_op")


def test_redefining_an_op_invalidates_cached_compiles():
    """override=True must evict the old definition's compiled traces —
    machine-scoped and process-wide — or the old op keeps executing."""
    m = SimdramMachine(backend="unrolled")

    def build_xor(g):
        g.add_output("out", g.gate_xor(g.input("a"), g.input("b")))

    def build_and(g):
        g.add_output("out", g.gate_and(g.input("a"), g.input("b")))

    a = jnp.full((32,), 6, jnp.int32)
    b = jnp.full((32,), 3, jnp.int32)
    op = m.define_op("bitop", build_xor)
    assert int(np.asarray(op(a, b, n_bits=4))[0]) == 6 ^ 3
    op = m.define_op("bitop", build_and, override=True)
    assert int(np.asarray(op(a, b, n_bits=4))[0]) == 6 & 3
    # process registry: unregister drops the global cache entries too
    from repro.core.trace import GLOBAL_TRACE_CACHE, compile_trace
    name = "_test_stale_op"
    register_operation(name, compile_popcount_ge)
    try:
        compile_trace(name, 4)
        assert (name, 4, True) in GLOBAL_TRACE_CACHE
    finally:
        unregister_operation(name)
    assert (name, 4, True) not in GLOBAL_TRACE_CACHE


def test_process_override_invalidates_private_machine_caches():
    """A process-wide re-registration must evict stale compiles from
    *every* live machine memory, not just the global cache — private
    memories resolve registry names through the process op table."""
    name = "_test_global_swap"

    def add_fn(n, opt=True):
        from repro.core.circuits import compile_operation
        return dataclasses_replace_name(compile_operation("addition", n, opt))

    def sub_fn(n, opt=True):
        from repro.core.circuits import compile_operation
        return dataclasses_replace_name(compile_operation("subtraction",
                                                          n, opt))

    import dataclasses as _dc

    def dataclasses_replace_name(prog):
        return _dc.replace(prog, name=name)

    m = SimdramMachine(backend="unrolled")
    a = jnp.full((32,), 9, jnp.int32)
    b = jnp.full((32,), 4, jnp.int32)
    register_operation(name, add_fn)
    try:
        assert int(np.asarray(m.op(name)(a, b, n_bits=8))[0]) == 13
        register_operation(name, sub_fn, override=True)
        assert int(np.asarray(m.op(name)(a, b, n_bits=8))[0]) == 5
    finally:
        unregister_operation(name)


def test_machine_adopting_a_cache_still_resolves_its_own_ops():
    """SimdramMachine(memory=<raw TraceCache>) wires the cache's compile
    hook to the machine registry, so define_op'd ops execute instead of
    raising KeyError at call time."""
    m = SimdramMachine(memory=TraceCache(capacity=4), backend="unrolled")
    op = m.define_op("gated_sub", build_gated_sub,
                     invariants={"gate": DRow("gate", 0, fixed=True)},
                     states={"borrow": 0})
    a = jnp.full((32,), 7, jnp.int32)
    g = jnp.full((32,), 1, jnp.int32)
    assert int(np.asarray(op(a, a, g, n_bits=8))[0]) == 0
    assert m.memory.capacity == 4


def test_pipeline_refresh_phase_alone_implies_replay_timing():
    """refresh_phase= is a timing knob: passing it without timed=/model=
    must yield a replay-mode timed pipeline, not a silent no-op."""
    from repro.ops import simdram_pipeline
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    with simdram_pipeline(refresh_phase=True) as p:
        x = p.load(a, 8)
        p.store(bbop_add(x, x, 8))
    assert p.stats is not None
    assert p.stats.mode == "replay"
    assert p.stats.refresh_phase is True
    assert p.stats.replay_ns >= p.stats.exec_ns > 0


def test_process_registry_protects_builtins():
    with pytest.raises(ValueError, match="built-in"):
        register_operation("addition", lambda n, opt=True: None)
    name = "_test_tmp_op"
    register_operation(name, compile_popcount_ge)
    try:
        assert name in list_operations()
        with pytest.raises(ValueError, match="already registered"):
            register_operation(name, compile_popcount_ge)
    finally:
        unregister_operation(name)
    assert name not in list_operations()


def test_default_machine_memory_is_process_cache():
    from repro.core.trace import GLOBAL_TRACE_CACHE
    dm = default_machine()
    assert dm.memory is GLOBAL_TRACE_CACHE
    assert isinstance(dm.memory, TraceCache)


# ---------------------------------------------------------------------------
# Cross-op refresh phase (the ROADMAP remainder)
# ---------------------------------------------------------------------------


def test_refresh_phase_accrues_stall_across_short_ops():
    """Ops individually shorter than tREFI accrue zero refresh stall with
    per-op anchoring, but a chain of them crosses refresh windows once the
    accumulated replay clock is threaded through — and phase threading can
    only add stall."""
    a = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 256, N), jnp.int32)

    from repro.ops import bbop_sub

    def chain(refresh_phase):
        m = SimdramMachine(mode="replay", refresh_phase=refresh_phase,
                           backend="unrolled")
        with m.pipeline(timed=True) as p:
            x, y = p.load([a, b], 8)
            t = bbop_add(x, y, 8)
            t = bbop_sub(t, x, 8)
            t = bbop_add(t, y, 8)
            p.store(t)
        return m.stats

    anchored = chain(False)
    phased = chain(True)
    assert anchored.exec_ns == pytest.approx(phased.exec_ns)
    assert anchored.replay_refresh_ns == 0.0       # every op < tREFI
    assert phased.replay_refresh_ns > 0.0          # the chain crosses windows
    assert phased.replay_ns >= anchored.replay_ns
    assert phased.replay_ns >= phased.exec_ns


def test_refresh_phase_shifts_window_grid():
    """Direct replay: a phase just under tREFI pulls the first refresh
    window into an op that would otherwise finish before it."""
    from repro.core.trace import compile_trace
    from repro.simdram.timing import TraceReplayTiming
    _, trace = compile_trace("addition", 8)
    rt = TraceReplayTiming(DRAMTiming())
    base = rt.replay(trace)
    assert base.refresh_stall_ns == 0.0            # add8 fits inside tREFI
    shifted = rt.replay(trace, refresh_phase_ns=7500.0)
    assert shifted.refresh_stall_ns > 0.0
    assert shifted.ns >= base.ns
    # phase is modular in tREFI: a full period is a no-op
    wrapped = rt.replay(trace, refresh_phase_ns=DRAMTiming().tREFI_ns * 3)
    assert wrapped.ns == pytest.approx(base.ns)
    # an op whose clock lands just PAST an epoch boundary starts inside
    # that epoch's refresh window and must stall out of it (the k>=1
    # freshly-refreshed-bank guard only applies to standalone replays)
    inside = rt.replay(trace,
                       refresh_phase_ns=DRAMTiming().tREFI_ns + 50.0)
    assert inside.refresh_stall_ns > 0.0
    assert inside.refresh_stall_ns == pytest.approx(
        DRAMTiming().tRFC_ns - 50.0, abs=2 * DRAMTiming().tCK_ns)
    assert inside.ns >= base.ns


# ---------------------------------------------------------------------------
# Hypothesis: randomly sampled user-op compiles round-trip through the IR
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.sampled_from(["gated_sub", "popcount_ge"]),
           st.sampled_from((4, 8)),
           st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1),
           st.booleans())
    def test_user_op_roundtrip_and_value_sweep(op, n_bits, av, bv, gv):
        """Sampled operand sweep on the unrolled backend + IR round-trip,
        against the python-int oracle."""
        m = _machine(backend="unrolled")
        prog, trace = m.memory.get(op, n_bits)
        assert trace.decode() == canonical_uops(prog)
        mask = (1 << n_bits) - 1
        a, b = av & mask, bv & mask
        aj = jnp.full((32,), a, jnp.int32)
        bj = jnp.full((32,), b, jnp.int32)
        if op == "gated_sub":
            gj = jnp.full((32,), int(gv), jnp.int32)
            got = int(np.asarray(m.op(op)(aj, bj, gj, n_bits=n_bits))[0])
            exp = (a - b) & mask if gv else a
        else:
            got = int(np.asarray(m.op(op)(aj, bj, n_bits=n_bits,
                                          out_bits=1))[0])
            exp = int(bin(a).count("1") >= bin(b).count("1"))
        assert got == exp
