"""Substrate models: timing/energy, reliability Monte-Carlo, layout."""
import numpy as np
import pytest

from repro.core.circuits import compile_operation
from repro.simdram.reliability import (NODES, qra_margin_collapsed,
                                       reliability_table,
                                       simulate_multi_row_activation)
from repro.simdram.timing import (BaselineModel, DRAMTiming, SimdramPerfModel)


def test_throughput_scales_with_banks():
    m = SimdramPerfModel()
    p = compile_operation("addition", 32)
    t1 = m.throughput_gops(p, banks=1)
    t16 = m.throughput_gops(p, banks=16)
    assert abs(t16 / t1 - 16) < 1e-9


def test_simdram_beats_ambit_on_throughput():
    """Paper: 2.0× average over 16 ops at one bank."""
    m = SimdramPerfModel()
    s = m.throughput_gops(compile_operation("addition", 32))
    a = m.throughput_gops(compile_operation("addition", 32, optimize=False))
    assert s / a > 1.8


def test_energy_efficiency_ordering():
    """Paper Fig. 10: SIMDRAM > Ambit on Throughput/Watt."""
    m = SimdramPerfModel()
    s = m.throughput_per_watt(compile_operation("addition", 32))
    a = m.throughput_per_watt(compile_operation("addition", 32,
                                                optimize=False))
    assert s > a


def test_throughput_drops_with_element_size():
    """Paper Fig. 9 right: larger elements → lower throughput."""
    m = SimdramPerfModel()
    ts = [m.throughput_gops(compile_operation("addition", n))
          for n in (8, 16, 32, 64)]
    assert ts == sorted(ts, reverse=True)


def test_tra_reliable_at_low_variation():
    """Paper Table 3: TRA has zero failures at ≤5% variation, all nodes."""
    for node in NODES.values():
        assert simulate_multi_row_activation(node, 3, 0.05, 4000) == 0.0


def test_qra_worse_than_tra():
    node = NODES["32nm"]
    tra = simulate_multi_row_activation(node, 3, 0.20, 4000)
    qra = simulate_multi_row_activation(node, 5, 0.20, 4000)
    assert qra > tra


def test_qra_collapses_at_22nm():
    """Paper: 'QRA does not perform correctly in the projected 22nm DRAM'."""
    assert qra_margin_collapsed(NODES["22nm"])
    assert not qra_margin_collapsed(NODES["45nm"])


def test_failure_rate_grows_with_scaling():
    rates = [simulate_multi_row_activation(NODES[n], 3, 0.20, 6000)
             for n in ("45nm", "32nm", "22nm")]
    assert rates[0] <= rates[1] <= rates[2] + 0.01


def test_jnp_layout_roundtrip():
    import jax.numpy as jnp
    from repro.simdram.layout import from_bitplanes, to_bitplanes
    x = jnp.arange(256, dtype=jnp.int32) * 7 % 61
    planes = to_bitplanes(x, 8)
    back = from_bitplanes(planes)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
