"""Per-architecture smoke tests (deliverable f): reduced config of each
family, one forward + one decode step on CPU, shape + finiteness asserts.

Whole module is tier-2 (``slow``): ~2 min of per-arch forwards/decodes.  The
CI fast tier keeps model-adjacent coverage via test_pum_layers and
test_train_infra; nightly runs everything.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import ARCHS, get_config, get_reduced
from repro.configs.base import SHAPES, shape_applicable
from repro.models.params import init_params
from repro.models.transformer import (forward, init_cache_shapes, model_defs,
                                      prime_encdec_caches)


def _batch(cfg, b, s, key=0):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (b, s), 0,
                                          cfg.vocab)}
    if cfg.enc_dec:
        batch["encoder_frames"] = jax.random.normal(
            jax.random.key(key + 1), (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.rope == "mrope":
        batch["mrope_positions"] = jnp.tile(
            jnp.arange(s)[None, :, None], (b, 1, 3))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(model_defs(cfg), jax.random.key(0))
    b, s = 2, 64
    logits, aux, _ = forward(params, cfg, _batch(cfg, b, s))
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step_smoke(arch):
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_reduced(arch)
    params = init_params(model_defs(cfg), jax.random.key(0))
    state = init_train_state(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    batch["labels"] = batch["tokens"]
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.opt.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(state.params),
                         jax.tree.leaves(new_state.params)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "mamba2_130m",
                                  "zamba2_7b", "whisper_large_v3",
                                  "granite_34b"])
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(get_reduced(arch), remat="none",
                              compute_dtype="float32", capacity_factor=8.0)
    params = init_params(model_defs(cfg), jax.random.key(1))
    b, s = 2, 12
    batch = _batch(cfg, b, s, key=5)
    full, _, _ = forward(params, cfg, batch)
    cs = init_cache_shapes(cfg, b, s, dtype=jnp.float32)
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cs)
    if cfg.enc_dec:
        caches = prime_encdec_caches(params, cfg, batch, caches)
    outs = []
    for t in range(s):
        db = {"tokens": batch["tokens"][:, t:t + 1]}
        if cfg.rope == "mrope":
            db["mrope_positions"] = jnp.full((b, 1, 3), t)
        if cfg.enc_dec:
            db["encoder_frames"] = batch["encoder_frames"]
        dl, _, caches = forward(params, cfg, db, caches)
        outs.append(dl)
    err = float(jnp.max(jnp.abs(jnp.concatenate(outs, 1) - full)))
    assert err < 2e-3, err


def test_multi_token_prefill_into_cache():
    """Cache-populating prefill (serving path) matches no-cache forward."""
    cfg = dataclasses.replace(get_reduced("qwen1_5_0_5b"), remat="none",
                              compute_dtype="float32")
    params = init_params(model_defs(cfg), jax.random.key(1))
    b, s = 2, 16
    batch = _batch(cfg, b, s, key=9)
    full, _, _ = forward(params, cfg, batch)
    cs = init_cache_shapes(cfg, b, 32, dtype=jnp.float32)
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cs)
    logits, _, caches = forward(params, cfg, batch, caches)
    err = float(jnp.max(jnp.abs(logits - full)))
    assert err < 2e-3, err
    assert int(caches["pos"]) == s


def test_flash_attention_equals_naive():
    from repro.models.layers import _flash_attn, _sdpa_naive
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (2, 96, 8, 16))
    k = jax.random.normal(k2, (2, 96, 4, 16))
    v = jax.random.normal(k3, (2, 96, 4, 16))
    a = _sdpa_naive(q, k, v, causal=True)
    f = _flash_attn(q, k, v, causal=True, block_q=32, block_k=40)
    assert float(jnp.max(jnp.abs(a - f))) < 1e-4


def test_long_context_applicability_matrix():
    """long_500k runs only for SSM/hybrid archs (DESIGN.md skip note)."""
    runs = {a: shape_applicable(get_config(a), "long_500k") for a in ARCHS}
    assert runs["mamba2_130m"] and runs["zamba2_7b"]
    assert sum(runs.values()) == 2


def test_param_counts_match_scale():
    """Full configs land in the right parameter-count ballpark."""
    expected = {"codeqwen1_5_7b": (6e9, 9e9),
                "qwen1_5_0_5b": (0.4e9, 0.8e9),
                "granite_34b": (30e9, 50e9),  # SwiGLU MLP (uniform stack) vs 2-mat GPT-BigCode
                "deepseek_v2_236b": (200e9, 260e9),
                "olmoe_1b_7b": (5e9, 9e9),
                "mamba2_130m": (0.1e9, 0.2e9)}
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_int8_kv_cache_decode():
    """Quantized KV cache: greedy top-1 must agree with bf16 prefill."""
    cfg = dataclasses.replace(get_reduced("stablelm_12b"), remat="none",
                              compute_dtype="float32",
                              kv_cache_dtype="int8")
    params = init_params(model_defs(cfg), jax.random.key(1))
    b, s = 2, 12
    batch = _batch(cfg, b, s, key=7)
    full, _, _ = forward(params, cfg, batch)
    cs = init_cache_shapes(cfg, b, s, dtype=jnp.float32)
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cs)
    assert caches["layers"]["k"].dtype == jnp.int8
    outs = []
    for t in range(s):
        dl, _, caches = forward(params, cfg,
                                {"tokens": batch["tokens"][:, t:t + 1]},
                                caches)
        outs.append(dl)
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - full)) / jnp.max(jnp.abs(full)))
    assert rel < 0.1, rel
    agree = float(jnp.mean(
        (jnp.argmax(dec, -1) == jnp.argmax(full, -1)).astype(jnp.float32)))
    assert agree > 0.95, agree


def test_whisper_cross_kv_cache_exact():
    """Cross-attention KV caching is mathematically exact (same projections,
    computed once)."""
    cfg = dataclasses.replace(get_reduced("whisper_large_v3"), remat="none",
                              compute_dtype="float32")
    assert cfg.cross_kv_cache
    params = init_params(model_defs(cfg), jax.random.key(1))
    b, s = 2, 8
    batch = _batch(cfg, b, s, key=3)
    full, _, _ = forward(params, cfg, batch)
    cs = init_cache_shapes(cfg, b, s, dtype=jnp.float32)
    assert "xk" in cs["layers"]
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cs)
    caches = prime_encdec_caches(params, cfg, batch, caches)
    outs = []
    for t in range(s):
        dl, _, caches = forward(params, cfg,
                                {"tokens": batch["tokens"][:, t:t + 1],
                                 "encoder_frames": batch["encoder_frames"]},
                                caches)
        outs.append(dl)
    err = float(jnp.max(jnp.abs(jnp.concatenate(outs, 1) - full)))
    assert err < 2e-3, err
