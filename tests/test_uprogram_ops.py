"""Step 2+3: every compiled operation is exact on the reference subarray,
for both the optimized (SIMDRAM) and naive (Ambit-baseline) pipelines."""
import numpy as np
import pytest

from repro.core.circuits import ALL_OPS, compile_operation
from repro.core.executor import from_planes, run_program

RNG = np.random.default_rng(42)


def oracles(n, N=96):
    hi = min(2 ** n, 2 ** 62)
    a = RNG.integers(0, hi, N).astype(np.int64)
    b = RNG.integers(0, hi, N).astype(np.int64)
    b_nz = np.where(b == 0, 1, b)
    sel = RNG.integers(0, 2, N)
    s2 = RNG.integers(0, hi, N).astype(np.int64)
    beq = np.where(RNG.random(N) < .5, a, b)
    mask = np.uint64(2 ** n - 1)
    u = lambda x: x.astype(np.uint64)
    table = {
        "addition": (dict(a=a, b=b), (u(a) + u(b)) & mask, n),
        "subtraction": (dict(a=a, b=b), (u(a) - u(b)) & mask, n),
        "greater": (dict(a=a, b=b), (u(a) > u(b)).astype(np.uint64), 1),
        "greater_equal": (dict(a=a, b=b), (u(a) >= u(b)).astype(np.uint64), 1),
        "equal": (dict(a=a, b=beq), (a == beq).astype(np.uint64), 1),
        "if_else": (dict(a=a, b=b, sel=sel), u(np.where(sel == 1, a, b)), n),
        "bitcount": (dict(a=a), np.array(
            [bin(x).count("1") for x in a.tolist()], np.uint64),
            n.bit_length()),
        "multiplication": (dict(a=a, b=b), (u(a) * u(b)) & mask, n),
        "division": (dict(a=a, b=b_nz), u(a) // u(b_nz), n),
        "and_reduction": (dict(s0=a, s1=b, s2=s2), u(a & b & s2), n),
        "or_reduction": (dict(s0=a, s1=b, s2=s2), u(a | b | s2), n),
        "xor_reduction": (dict(s0=a, s1=b, s2=s2), u(a ^ b ^ s2), n),
    }
    sg = np.where(a >= 1 << (n - 1), a - (1 << n), a)
    table["relu"] = (dict(a=a), u(np.where(sg >= 0, a, 0)), n)
    table["abs"] = (dict(a=a), u(np.abs(sg)) & mask, n)
    table["maximum"] = (dict(a=a, b=b), u(np.maximum(a, b)), n)
    table["minimum"] = (dict(a=a, b=b), u(np.minimum(a, b)), n)
    return table


@pytest.mark.parametrize("op", ALL_OPS)
@pytest.mark.parametrize("n", [8, 16])
def test_simdram_op_exact(op, n):
    ins, exp, ob = oracles(n)[op]
    prog = compile_operation(op, n)
    outs, _ = run_program(prog, ins)
    got = from_planes(outs[prog.outputs[0]][:ob], len(exp)).astype(np.uint64)
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("op", ALL_OPS)
def test_ambit_baseline_exact(op, n=8):
    ins, exp, ob = oracles(n)[op]
    prog = compile_operation(op, n, optimize=False)
    outs, _ = run_program(prog, ins)
    got = from_planes(outs[prog.outputs[0]][:ob], len(exp)).astype(np.uint64)
    np.testing.assert_array_equal(got, exp)


def test_dcc_not_semantics():
    """Dual-contact cells: writing through the n-wordline stores the
    complement; reading it back through the d-wordline yields ¬x."""
    from repro.core.executor import Subarray, to_planes
    from repro.core.uprogram import AAP, DRow, P_DCC0, P_NDCC0
    sa = Subarray(64)
    x = np.arange(64) % 2
    sa.write_operand("x", to_planes(x, 1, 64))
    sa.alloc_operand("y", 1)
    sa.execute(AAP(DRow("x"), (P_NDCC0,)))
    sa.execute(AAP(P_DCC0, (DRow("y"),)))
    got = from_planes(sa.read_operand("y", 1), 64)
    np.testing.assert_array_equal(got, 1 - x)
