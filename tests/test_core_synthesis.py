"""Step 1: AOIG → MIG synthesis (paper §4.1, App. A)."""
import itertools

import pytest

from repro.core.graph import CONST0, CONST1, LogicGraph, lit_not
from repro.core.synthesis import TEMPLATES, _tt3, aoig_to_mig_naive, synthesize


def exhaustive_equal(g1, g2, names):
    for vals in itertools.product((0, 1), repeat=len(names)):
        asg = {nm: -v for nm, v in zip(names, vals)}
        if g1.evaluate(asg, mask=1) != g2.evaluate(asg, mask=1):
            return False
    return True


def full_adder_aoig():
    g = LogicGraph()
    a, b, c = g.input("a"), g.input("b"), g.input("c")
    axb = g.gate_xor(a, b)
    g.add_output("s", g.gate_xor(axb, c))
    g.add_output("cout", g.gate_or_node(g.gate_and(a, b), g.gate_and(c, axb)))
    return g


def test_full_adder_reaches_paper_optimum():
    """The paper's App. A derives a 3-MAJ full adder (Fig. 15j)."""
    g = full_adder_aoig()
    opt = synthesize(g)
    assert opt.live_gate_count() == 3
    assert exhaustive_equal(g, opt, ["a", "b", "c"])


def test_naive_substitution_preserves_function():
    g = full_adder_aoig()
    naive = aoig_to_mig_naive(g)
    assert exhaustive_equal(g, naive, ["a", "b", "c"])
    # naive is the Ambit representation: strictly larger than optimized
    assert naive.live_gate_count() > synthesize(g).live_gate_count()


def test_mux_template():
    g = LogicGraph()
    s, x, y = g.input("s"), g.input("x"), g.input("y")
    g.add_output("m", g.gate_mux(s, x, y))
    opt = synthesize(g)
    assert opt.live_gate_count() <= 3
    assert exhaustive_equal(g, opt, ["s", "x", "y"])


@pytest.mark.parametrize("tt", sorted(TEMPLATES))
def test_template_table_is_sound(tt):
    """Every registered template must realize its truth table exactly."""
    g = LogicGraph()
    a, b, c = g.input("a"), g.input("b"), g.input("c")
    lit = TEMPLATES[tt](g, a, b, c)
    g.add_output("f", lit)
    got = 0
    for i in range(8):
        av, bv, cv = i & 1, (i >> 1) & 1, (i >> 2) & 1
        r = g.evaluate({"a": -av, "b": -bv, "c": -cv}, mask=1)["f"]
        got |= r << i
    assert got == tt


def test_maj_axioms_fold_at_construction():
    g = LogicGraph()
    a, b = g.input("a"), g.input("b")
    assert g.gate_maj(a, a, b) == a                      # Ω.M
    assert g.gate_maj(a, lit_not(a), b) == b             # Ω.M complement
    assert g.gate_maj(a, CONST0, CONST1) == a
