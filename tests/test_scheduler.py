"""Multi-tenant bank scheduler: per-bank queues, FR-FCFS issue, refresh
policies, and the ``machine.submit()`` futures surface.

Covers the tentpole acceptance criteria and the satellites that rode along:

* **property** — a single tenant enqueueing one identical trace on every
  bank under the ``"defer"`` refresh policy is cycle-for-cycle the PR-4
  desynchronized replay, on every Table-5 op, across bank counts, with and
  without refresh pressure and issue offsets;
* **bank-level parallelism** — heterogeneous requests pack across banks,
  so the mixed makespan beats the serialized sum of solo replays;
* **refresh-aware vs stall** — under refresh-heavy timing, pausing between
  sequences beats eager issue with mid-sequence abort + restart;
* **submit/drain** — futures resolve with correct values (vs the direct
  bbop oracle), scheduler timing attaches to each future, and per-tenant
  :class:`PerfStats` accumulators sum exactly to the machine totals;
* **satellites** — ``PerfStats.snapshot()`` is structured and JSON-safe,
  ``note_bank_skew`` offsets are scoped per machine session,
  ``execute_heterogeneous`` matches solo dispatch, and ``greedy_decode``
  accepts the uniform ``machine=`` kwarg.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.backends import execute_lowered
from repro.core.circuits import ALL_OPS
from repro.core.trace import compile_trace
from repro.ops import (BankScheduler, BitplaneArray, SimdramMachine,
                       bbop_add, execute_heterogeneous, timed)
from repro.simdram.timing import DRAMTiming, TraceReplayTiming

TCK = 0.833
RNG = np.random.default_rng(0x5C0)


def _timing(**kw) -> DRAMTiming:
    return dataclasses.replace(DRAMTiming(), **kw)


def _assert_matches_replay(sched: BankScheduler, trace, banks: int,
                           rt: TraceReplayTiming, offsets=None, ctx=()):
    rid = sched.enqueue(trace, banks=banks, offsets_ns=offsets)
    got = sched.run()
    want = rt.replay(trace, banks=banks, offsets_ns=offsets)
    label = (*ctx, banks)
    assert got.ns == pytest.approx(want.ns), label
    assert got.cycles == want.cycles, label
    assert got.n_acts == want.n_acts, label
    assert got.tfaw_stall_ns == pytest.approx(want.tfaw_stall_ns), label
    assert got.refresh_stall_ns == pytest.approx(want.refresh_stall_ns), label
    assert got.n_refresh_stalls == want.n_refresh_stalls, label
    req = got.requests[rid]
    assert req.n_seqs == want.n_seqs
    assert req.n_acts == want.n_acts
    return got, want


# ---------------------------------------------------------------------------
# Property: defer-policy schedule ≡ PR-4 desync replay
# ---------------------------------------------------------------------------


def test_defer_matches_replay_every_table5_op():
    """Acceptance: one tenant, one trace replicated on all banks, under the
    ``"defer"`` refresh policy — the scheduler event loop must reproduce
    :meth:`TraceReplayTiming.replay` exactly (makespan, cycle count, ACT
    count, tFAW and refresh stall attribution) on every Table-5 op."""
    rt = TraceReplayTiming()
    for op in ALL_OPS:
        _, trace = compile_trace(op, 8)
        sched = BankScheduler(n_banks=4, refresh_policy="defer")
        _assert_matches_replay(sched, trace, 4, rt, ctx=(op,))


@pytest.mark.parametrize("banks", [1, 4, 16])
def test_defer_matches_replay_under_refresh_pressure(banks):
    """The equivalence holds when tRRD/tFAW and toy refresh windows all
    bind, across bank counts."""
    t = _timing(tREFI_ns=150.0, tRFC_ns=50.0)
    rt = TraceReplayTiming(t)
    for op in ("addition", "xor_reduction", "relu"):
        _, trace = compile_trace(op, 8)
        sched = BankScheduler(timing=t, n_banks=banks,
                              refresh_policy="defer")
        _assert_matches_replay(sched, trace, banks, rt, ctx=(op,))


def test_defer_matches_replay_with_issue_offsets():
    rt = TraceReplayTiming()
    _, trace = compile_trace("addition", 8)
    offsets = (0.0, 500.0)
    sched = BankScheduler(n_banks=2, refresh_policy="defer")
    _assert_matches_replay(sched, trace, 2, rt, offsets=offsets)


# ---------------------------------------------------------------------------
# Bank-level parallelism: heterogeneous requests overlap
# ---------------------------------------------------------------------------


def test_heterogeneous_requests_overlap_across_banks():
    """Two independent single-bank requests land on distinct banks and
    overlap: the scheduled makespan beats the serialized sum of their solo
    replays (by nearly the shorter request's length)."""
    rt = TraceReplayTiming()
    _, t_add = compile_trace("addition", 8)
    _, t_mul = compile_trace("multiplication", 8)
    solo_add = rt.replay(t_add).ns
    solo_mul = rt.replay(t_mul).ns
    sched = BankScheduler(n_banks=2)
    r0 = sched.enqueue(t_add, name="add")
    r1 = sched.enqueue(t_mul, name="mul")
    res = sched.run()
    assert res.requests[r0].bank_ids != res.requests[r1].bank_ids
    # overlap is real: the makespan tracks the longer request, not the
    # serialized sum (shared tRRD/tFAW add a small coupling cost)
    assert res.ns < solo_add + solo_mul
    assert res.ns <= 1.05 * max(solo_add, solo_mul)
    # queues reset between runs (one-shot event loop)
    assert sched.n_pending == 0
    assert sched.run().n_requests == 0


def test_least_loaded_assignment_and_explicit_bank_ids():
    _, t_add = compile_trace("addition", 8)
    sched = BankScheduler(n_banks=4)
    a = sched.enqueue(t_add)                  # lightest bank: 0
    b = sched.enqueue(t_add)                  # next: 1
    c = sched.enqueue(t_add, banks=2, bank_ids=(3, 2))
    res = sched.run()
    assert res.requests[a].bank_ids == (0,)
    assert res.requests[b].bank_ids == (1,)
    # explicit placement is preserved in the given order (it pairs with
    # offsets_ns positionally)
    assert res.requests[c].bank_ids == (3, 2)


def test_enqueue_validation():
    _, trace = compile_trace("relu", 8)
    sched = BankScheduler(n_banks=2)
    with pytest.raises(ValueError, match="banks wide"):
        sched.enqueue(trace, banks=3)
    with pytest.raises(ValueError, match="bank_ids"):
        sched.enqueue(trace, banks=2, bank_ids=(0,))
    with pytest.raises(ValueError, match="out of range"):
        sched.enqueue(trace, banks=2, bank_ids=(0, 5))
    with pytest.raises(ValueError, match="offsets"):
        sched.enqueue(trace, banks=2, offsets_ns=(0.0,))
    with pytest.raises(ValueError, match="issue policy"):
        BankScheduler(policy="random")
    with pytest.raises(ValueError, match="refresh policy"):
        BankScheduler(refresh_policy="never")
    with pytest.raises(ValueError, match="n_banks"):
        BankScheduler(n_banks=0)


def test_request_timing_surface():
    """queue/service split, per-tenant rollup, and the ReplayResult view."""
    _, t_add = compile_trace("addition", 8)
    _, t_rel = compile_trace("relu", 8)
    sched = BankScheduler(n_banks=4)
    sched.enqueue(t_add, tenant="A", name="add", lanes=64)
    sched.enqueue(t_rel, tenant="B", name="relu", arrival_ns=100.0)
    res = sched.run()
    for r in res.requests:
        assert r.finish_ns == pytest.approx(r.arrival_ns + r.queue_ns
                                            + r.service_ns)
        assert r.service_ns >= r.analytic_ns > 0
        rr = r.replay_result()
        assert rr.ns == pytest.approx(r.service_ns)
        assert rr.stall_ns == pytest.approx(r.service_ns - r.analytic_ns)
    ten = res.per_tenant()
    assert set(ten) == {"A", "B"}
    assert ten["A"]["n_requests"] == ten["B"]["n_requests"] == 1
    assert ten["A"]["lanes"] == 64
    # arrivals quantize up to the next DRAM cycle
    tck = sched.timing.tCK_ns
    assert res.requests[1].arrival_ns \
        == pytest.approx(math.ceil(100.0 / tck) * tck)
    assert max(ten["A"]["finish_ns"], ten["B"]["finish_ns"]) \
        == pytest.approx(res.ns)


# ---------------------------------------------------------------------------
# Refresh policies: aware pauses beat eager abort + restart
# ---------------------------------------------------------------------------


def _refresh_heavy_mix(refresh_policy: str):
    t = _timing(tREFI_ns=100.0, tRFC_ns=30.0)
    sched = BankScheduler(timing=t, n_banks=16,
                          refresh_policy=refresh_policy)
    for i, op in enumerate(("addition", "multiplication", "relu",
                            "xor_reduction") * 2):
        _, trace = compile_trace(op, 8)
        sched.enqueue(trace, banks=2, tenant=f"t{i % 2}", name=op)
    return sched.run()


def test_refresh_aware_beats_midsequence_stall():
    """Under refresh-heavy timing the eager policy keeps losing in-flight
    sequences to mid-sequence refresh (abort + restart, wasted ACT slots);
    pausing between sequences avoids every restart and finishes sooner."""
    aware = _refresh_heavy_mix("aware")
    stall = _refresh_heavy_mix("stall")
    assert stall.n_restarts > 0 and aware.n_restarts == 0
    assert aware.ns <= stall.ns
    # the wasted activations are visible in the ACT count
    assert stall.n_acts > aware.n_acts
    # aware's pauses are metered as refresh stall on the paused requests
    assert aware.refresh_stall_ns > 0


# ---------------------------------------------------------------------------
# machine.submit() / drain(): futures, values, per-tenant attribution
# ---------------------------------------------------------------------------


def test_submit_drain_resolves_futures_with_correct_values():
    m = SimdramMachine(mode="replay")
    a = RNG.integers(0, 100, 64).astype(np.int32)
    b = RNG.integers(0, 100, 64).astype(np.int32)
    f_add = m.submit("addition", a, b, tenant="A")
    f_rel = m.submit("relu", a, tenant="B")
    f_mul = m.submit("multiplication", a, b, out_bits=16, tenant="A")
    assert not f_add.done() and "pending" in repr(f_add)
    res = m.drain()
    assert res.n_requests == 3
    assert all(f.done() for f in (f_add, f_rel, f_mul))
    np.testing.assert_array_equal(np.asarray(f_add.result()), a + b)
    np.testing.assert_array_equal(np.asarray(f_rel.result()), a)
    # oracle: the direct bbop call (same program, same out_bits semantics)
    from repro.ops import bbop_mul
    np.testing.assert_array_equal(np.asarray(f_mul.result()),
                                  np.asarray(bbop_mul(a, b, 8, out_bits=16)))
    # scheduler timing attaches to each future
    for f in (f_add, f_rel, f_mul):
        assert f.timing is not None and f.timing.tenant == f.tenant
        assert f.replay.ns == pytest.approx(f.timing.service_ns)
        assert 0 < f.finish_ns <= res.ns
    assert {f.timing.name for f in (f_add, f_rel, f_mul)} \
        == {"addition/8b", "relu/8b", "multiplication/8b"}
    assert set(res.per_tenant()) == {"A", "B"}


def test_future_result_auto_drains():
    m = SimdramMachine()
    a = RNG.integers(0, 100, 32).astype(np.int32)
    fut = m.submit("relu", a)
    np.testing.assert_array_equal(np.asarray(fut.result()), a)
    assert fut.done() and fut.timing is not None


def test_submit_banked_operands_schedule_wide():
    m = SimdramMachine()
    vals = jnp.asarray(RNG.integers(0, 100, (2, 32)), jnp.int32)
    pa = BitplaneArray.from_values(vals, 8)
    fut = m.submit("addition", pa, pa)
    m.drain(n_banks=4)
    assert fut.timing.bank_ids == (0, 1)
    assert fut.timing.lanes == 2 * 32
    out = fut.result()
    assert isinstance(out, BitplaneArray) and out.banked
    np.testing.assert_array_equal(np.asarray(out.to_values()), vals + vals)


def test_submit_validation_errors():
    m = SimdramMachine()
    with pytest.raises(KeyError, match="unknown operation"):
        m.submit("frobnicate", [1, 2])
    m.submit("addition", np.arange(4, dtype=np.int32))   # missing operand
    with pytest.raises(TypeError, match="takes 2 operands"):
        m.drain()
    vals = jnp.asarray(RNG.integers(0, 100, (2, 32)), jnp.int32)
    banked = BitplaneArray.from_values(vals, 8)
    flat = BitplaneArray.from_values(jnp.arange(64, dtype=jnp.int32), 8)
    m.submit("addition", banked, flat)
    with pytest.raises(ValueError, match="shapes disagree"):
        m.drain()


def test_tenant_stats_sum_to_machine_totals():
    """Every meter summed over ``stats.tenants`` reproduces the machine
    total exactly — transposition charged during operand prep, execution
    charged during the heterogeneous dispatch, all in replay mode."""
    m = SimdramMachine(mode="replay")
    a = RNG.integers(0, 100, 64).astype(np.int32)
    b = RNG.integers(0, 100, 64).astype(np.int32)
    for tenant, op in (("A", "addition"), ("B", "relu"), ("A", "maximum"),
                       ("B", "subtraction")):
        if op == "relu":
            m.submit(op, a, tenant=tenant)
        else:
            m.submit(op, a, b, tenant=tenant)
    m.drain()
    tenants = list(m.stats.tenants.values())
    assert set(m.stats.tenants) == {"A", "B"}
    for meter in ("exec_ns", "exec_nj", "replay_ns", "transpose_ns",
                  "movement_ns", "total_ns", "n_programs", "n_transposes",
                  "elem_ops"):
        total = getattr(m.stats, meter)
        by_tenant = sum(getattr(st, meter) for st in tenants)
        assert by_tenant == pytest.approx(total), meter
        if meter != "movement_ns":      # unbanked ops relocate no rows
            assert total > 0, meter


def test_mixed_two_tenant_drain_beats_serialized_single_stream():
    """The bench-row scenario: two heterogeneous tenant streams drained
    through one scheduler overlap across banks, beating the sum of
    serialized solo replays of the same requests."""
    rt = TraceReplayTiming()
    jobs = [("A", "addition"), ("B", "multiplication"), ("A", "maximum"),
            ("B", "minimum"), ("A", "subtraction"), ("B", "relu")]
    serial = 0.0
    m = SimdramMachine()
    a = RNG.integers(0, 100, 64).astype(np.int32)
    b = RNG.integers(0, 100, 64).astype(np.int32)
    for tenant, op in jobs:
        _, trace = compile_trace(op, 8)
        serial += rt.replay(trace).ns
        args = (a,) if op == "relu" else (a, b)
        m.submit(op, *args, tenant=tenant)
    res = m.drain(n_banks=8)
    assert res.ns < serial
    ten = res.per_tenant()
    assert ten["A"]["n_requests"] == 3 and ten["B"]["n_requests"] == 3


def test_empty_drain_returns_empty_schedule():
    m = SimdramMachine()
    res = m.drain()
    assert res.n_requests == 0 and res.ns == 0.0 and res.requests == ()


# ---------------------------------------------------------------------------
# Satellite: PerfStats.snapshot() — structured, JSON-safe, feeds report()
# ---------------------------------------------------------------------------


def test_snapshot_is_structured_and_json_safe():
    m = SimdramMachine(mode="replay")
    a = RNG.integers(0, 100, 64).astype(np.int32)
    m.submit("addition", a, a, tenant="svc")
    m.drain()
    snap = m.stats.snapshot()
    json.dumps(snap)                       # plain floats/ints/dicts only
    assert set(snap) == {"mode", "refresh_phase", "totals", "execute",
                         "replay", "movement", "transposition", "per_op",
                         "tenants"}
    assert snap["totals"]["ns"] == pytest.approx(m.stats.total_ns)
    assert snap["execute"]["n_programs"] == 1
    assert snap["movement"]["per_kind"].keys() == {"intra", "inter",
                                                   "elided"}
    assert snap["transposition"]["per_kind"].keys() == {"to", "from"}
    assert snap["replay"]["ns"] == pytest.approx(m.stats.replay_ns)
    assert "addition/8b" in snap["per_op"]
    # tenants nest recursively with the same shape
    sub = snap["tenants"]["svc"]
    assert set(sub) == set(snap) and sub["tenants"] == {}
    assert sub["execute"]["n_programs"] == 1
    # report() renders from the snapshot, including the tenant rollup
    rep = m.stats.report()
    assert "tenant svc" in rep and "addition/8b" in rep


# ---------------------------------------------------------------------------
# Satellite bugfix: bank-skew offsets are scoped per machine session
# ---------------------------------------------------------------------------


def test_bank_skew_scoped_per_machine():
    """A scatter recorded under machine A's session must not feed replay
    offsets to machine B replaying the same planes — and must still feed
    A's next op after B's interleaved use."""
    m1 = SimdramMachine(mode="replay")
    m2 = SimdramMachine(mode="replay")
    vals = jnp.asarray(RNG.integers(0, 256, 128), jnp.int32)
    with timed(mode="replay") as st:
        with m1.session():
            banked = BitplaneArray.from_values(vals, 8).rebank(2)
        assert len(st._bank_skew) == 1
        bbop_add(banked, banked, 8, machine=m2)
        # foreign machine: the pending skew is left for its rightful owner
        assert len(st._bank_skew) == 1
        spread_foreign = st.replay_bank_spread_ns
        bbop_add(banked, banked, 8, machine=m1)
        assert len(st._bank_skew) == 0
        spread_owner = st.replay_bank_spread_ns - spread_foreign
    skew = m1.model.movement.inter_bank_ns(16) / 2
    assert spread_owner >= skew > spread_foreign


# ---------------------------------------------------------------------------
# Satellite: execute_heterogeneous ≡ solo dispatch
# ---------------------------------------------------------------------------


def test_execute_heterogeneous_matches_solo_dispatch():
    prog_a, trace_a = compile_trace("addition", 8)
    prog_r, trace_r = compile_trace("relu", 8)

    def planes(shape):
        v = jnp.asarray(RNG.integers(0, 100, shape), jnp.int32)
        return BitplaneArray.from_values(v, 8).planes

    items = [
        (prog_a, trace_a, {"a": planes(32), "b": planes(32)}, None, None),
        (prog_a, trace_a, {"a": planes(32), "b": planes(32)}, None, None),
        (prog_r, trace_r, {"a": planes(32)}, None, None),
        (prog_a, trace_a, {"a": planes((2, 32)), "b": planes((2, 32))},
         {"out": 9}, None),                      # banked: dispatches solo
    ]
    got = execute_heterogeneous(items)
    assert len(got) == len(items)
    for item, outs in zip(items, got):
        prog, trace, ops, ob, be = item
        want = execute_lowered(prog, trace, ops, out_bits=ob, backend=be)
        assert outs.keys() == want.keys()
        for k in want:
            np.testing.assert_array_equal(np.asarray(outs[k]),
                                          np.asarray(want[k]))


# ---------------------------------------------------------------------------
# Satellite: greedy_decode takes the uniform machine= kwarg
# ---------------------------------------------------------------------------


def _tiny_decode(machine=None):
    from repro.configs import get_reduced
    from repro.models.params import init_params
    from repro.models.transformer import model_defs
    from repro.serve.decode import greedy_decode
    cfg = dataclasses.replace(get_reduced("qwen1_5_0_5b"), remat="none")
    params = init_params(model_defs(cfg), jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 3), 0, cfg.vocab)
    return greedy_decode(params, cfg, prompt, steps=2, sampler="simdram",
                         machine=machine)


def test_greedy_decode_machine_kwarg():
    m1 = SimdramMachine()
    m2 = SimdramMachine()
    out1 = _tiny_decode(machine=m1)
    out2 = _tiny_decode(machine=m2)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # the kwarg drove its machine: the tournament charged its stats, and
    # isolated machines charged identically
    assert m1.stats.n_programs > 0
    assert m2.stats.n_programs == m1.stats.n_programs


def test_greedy_decode_rejects_removed_sampler_machine_kwarg():
    from repro.serve.decode import greedy_decode
    with pytest.raises(TypeError, match="sampler_machine"):
        greedy_decode(None, None, jnp.zeros((1, 1), jnp.int32), 1,
                      sampler_machine=SimdramMachine())
