"""Desynchronized per-bank trace replay: tFAW/refresh windows, issue skew.

Covers the per-bank FSM array (tentpole) and the accounting bugfixes that
rode along:

* **tFAW**: the rank admits at most four ACTs per sliding window — a 5th
  ACT in the window stalls by exactly the window remainder;
* **refresh**: a periodic tREFI/tRFC window stalls the in-flight sequence,
  and the stall propagates 1:1 through a serial single-bank stream;
* **ordering**: desynchronized replay ≥ lockstep replay ≥ analytic on
  every Table-5 op (each modeling layer only adds stalls);
* **skew**: per-bank issue offsets (hand-passed or fed by
  ``BitplaneArray.rebank`` through the layout movement hooks) desynchronize
  bank finish times;
* **regressions**: the PerfStats cost memos are FIFO-bounded, the replayed
  energy formula lives in one place (``SimdramPerfModel.replay_energy_nj``
  ≡ ``charge_program``), and the lowering memo is LRU and dropped by
  ``clear_trace_cache``.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.backends import _COST_CAP, PerfStats, timed
from repro.core.circuits import ALL_OPS
from repro.core.trace import compile_trace, lower_program
from repro.core.uprogram import AAP, AP, DRow, P_T0, P_T1, P_T2, UProgram
from repro.simdram.timing import (DRAMTiming, SimdramPerfModel,
                                  TraceReplayTiming)

TCK = 0.833
RNG = np.random.default_rng(0xFA)


def _toy(n_aap: int, n_ap: int) -> UProgram:
    ops = [AAP(DRow("a", 0), (P_T0,))] * n_aap \
        + [AP((P_T0, P_T1, P_T2))] * n_ap
    return UProgram(name="toy", n_bits=4, prologue=ops, body=[],
                    body_reps=0, inputs=("a",), outputs=("a",))


def _timing(**kw) -> DRAMTiming:
    return dataclasses.replace(DRAMTiming(), **kw)


# ---------------------------------------------------------------------------
# FSM array: tFAW window
# ---------------------------------------------------------------------------


def test_default_timing_cycle_constants():
    rt = TraceReplayTiming()
    assert (rt.c_ras, rt.c_rp, rt.c_rc) == (39, 17, 56)
    # ceil(4.9/.833), ceil(30/.833), ceil(7812.5/.833), ceil(350/.833)
    assert (rt.c_rrd, rt.c_faw, rt.c_refi, rt.c_rfc) == (6, 37, 9379, 421)


def test_tfaw_stalls_fifth_activation_in_window():
    """Five banks issue one AP each: ACTs land at 0/6/12/18 (tRRD), and the
    5th must wait for the four-activate window — 0 + c_faw = cycle 37, a
    13-cycle stall over its tRRD slot at 24."""
    rt = TraceReplayTiming(_timing(tREFI_ns=0.0))
    trace = lower_program(_toy(0, 1))
    res = rt.replay(trace, banks=5)
    assert res.tfaw_stall_ns == pytest.approx(13 * TCK)
    # bank 0 finishes first (ACT 0 + tRAS + 2·tRP), bank 4 last (ACT 37)
    assert res.min_bank_ns == pytest.approx(56 * TCK)
    assert res.max_bank_ns == pytest.approx(93 * TCK) == res.ns
    # four ACTs fit the window exactly: no stall at four banks
    assert rt.replay(trace, banks=4).tfaw_stall_ns == 0.0


def test_tfaw_disabled_removes_the_stall():
    t_on = _timing(tREFI_ns=0.0)
    t_off = _timing(tREFI_ns=0.0, tFAW_ns=0.0)
    trace = lower_program(_toy(2, 2))
    on = TraceReplayTiming(t_on).replay(trace, banks=8)
    off = TraceReplayTiming(t_off).replay(trace, banks=8)
    assert on.tfaw_stall_ns > 0 and off.tfaw_stall_ns == 0.0
    assert on.ns >= off.ns


# ---------------------------------------------------------------------------
# FSM array: refresh windows
# ---------------------------------------------------------------------------


def test_refresh_window_delays_next_sequence():
    """With tREFI=150 ns (181 cycles) and tRFC=50 ns (61 cycles), the third
    AAP of a 3-AAP stream would ACT at cycle 190 — inside the [181, 242)
    refresh window — and is pushed to the window end, a 52-cycle stall that
    propagates 1:1 to the finish of the serial single-bank stream."""
    t_on = _timing(tRRD_ns=0.0, tFAW_ns=0.0, tREFI_ns=150.0, tRFC_ns=50.0)
    t_off = _timing(tRRD_ns=0.0, tFAW_ns=0.0, tREFI_ns=0.0)
    trace = lower_program(_toy(3, 0))
    on = TraceReplayTiming(t_on).replay(trace)
    off = TraceReplayTiming(t_off).replay(trace)
    assert on.n_refresh_stalls == 1
    assert on.refresh_stall_ns == pytest.approx(52 * TCK)
    assert on.ns == pytest.approx(off.ns + on.refresh_stall_ns)


def test_refresh_applies_to_lockstep_policy_too():
    t = _timing(tRRD_ns=0.0, tFAW_ns=0.0, tREFI_ns=150.0, tRFC_ns=50.0)
    trace = lower_program(_toy(3, 0))
    res = TraceReplayTiming(t).replay(trace, banks=4, policy="lockstep")
    assert res.n_refresh_stalls == 1 and res.refresh_stall_ns > 0
    assert res.tfaw_stall_ns == 0.0          # lockstep: no rank coupling


def test_rfc_longer_than_refi_rejected():
    with pytest.raises(ValueError, match="tRFC"):
        TraceReplayTiming(_timing(tREFI_ns=100.0, tRFC_ns=100.0))


# ---------------------------------------------------------------------------
# Ordering invariant: desync ≥ lockstep ≥ analytic, every Table-5 op
# ---------------------------------------------------------------------------


def test_desync_ge_lockstep_ge_analytic_every_op():
    """Acceptance: through the ``timed(mode="replay")`` charging path, the
    full model (tFAW + refresh, desynchronized banks) dominates the
    lockstep/no-refresh model, which dominates the analytic sum, on every
    Table-5 op."""
    m_full = SimdramPerfModel()       # desync + tRRD/tFAW + refresh
    m_lock = SimdramPerfModel(timing=_timing(desync_policy="lockstep",
                                             tREFI_ns=0.0))
    for op in ALL_OPS:
        prog, trace = compile_trace(op, 8)
        ana = m_full.latency_ns(prog)
        lock = m_lock.replay_result(trace, banks=4)
        full = m_full.replay_result(trace, banks=4)
        assert full.ns >= lock.ns >= ana, (op, full.ns, lock.ns, ana)
        assert full.stall_ns == pytest.approx(full.ns - ana)
        assert full.min_bank_ns <= full.max_bank_ns == full.ns
        # same ordering through the accumulator surface timed() charges
        st_full = PerfStats(model=m_full, mode="replay")
        st_lock = PerfStats(model=m_lock, mode="replay")
        for st in (st_full, st_lock):
            st.charge_program(prog, 4, 128, trace=trace)
        assert st_full.replay_ns >= st_lock.replay_ns >= st_full.exec_ns
        assert st_full.replay_nj >= st_lock.replay_nj >= st_full.exec_nj


def test_lockstep_replicates_one_timeline():
    rt = TraceReplayTiming(_timing(desync_policy="lockstep"))
    trace = lower_program(_toy(3, 2))
    one = rt.replay(trace, banks=1)
    many = rt.replay(trace, banks=8)
    assert many.ns == one.ns and many.cycles == one.cycles
    assert many.min_bank_ns == many.max_bank_ns == many.ns
    assert many.n_seqs == one.n_seqs * 8 and many.n_acts == one.n_acts * 8


def test_desync_single_bank_matches_legacy_goldens():
    """banks=1 under the desync policy reproduces the PR-3 single-FSM cycle
    counts (tRRD/tFAW cannot bind a lone bank, short traces never refresh):
    an AAP occupies 2·39+17 = 95 cycles, an AP 39+17 = 56."""
    rt = TraceReplayTiming()
    assert rt.replay(lower_program(_toy(3, 0))).cycles == 3 * 95
    assert rt.replay(lower_program(_toy(0, 2))).cycles == 2 * 56
    assert rt.replay(lower_program(_toy(1, 1))).cycles == 95 + 56


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="desync policy"):
        TraceReplayTiming(_timing(desync_policy="warp"))
    with pytest.raises(ValueError, match="desync policy"):
        TraceReplayTiming().replay(lower_program(_toy(1, 0)), policy="warp")


# ---------------------------------------------------------------------------
# Per-bank issue offsets (desynchronized streams)
# ---------------------------------------------------------------------------


def test_issue_offsets_spread_bank_finish_times():
    rt = TraceReplayTiming(_timing(tREFI_ns=0.0))
    trace = lower_program(_toy(2, 1))
    base = rt.replay(trace, banks=2)
    skewed = rt.replay(trace, banks=2, offsets_ns=(0.0, 500.0))
    assert skewed.ns >= base.ns
    assert skewed.bank_spread_ns > base.bank_spread_ns
    assert skewed.bank_spread_ns >= 400.0


def test_offsets_must_match_bank_count():
    rt = TraceReplayTiming()
    with pytest.raises(ValueError, match="offsets"):
        rt.replay(lower_program(_toy(1, 0)), banks=3, offsets_ns=(0.0, 1.0))


def test_rebank_skew_feeds_replay_offsets():
    """An inter-bank scatter serializes each bank's planes over the bus, so
    the op *consuming the scattered planes* replays with per-bank arrival
    offsets — visible as a large bank finish spread — while unrelated ops
    charged in between are untouched, and the skew is consumed once: the
    next op on the same planes replays nearly in step again."""
    from repro.core.trace import compile_trace as _ct
    from repro.ops import bbop_add, bbop_relu
    from repro.simdram.layout import BitplaneArray
    m = SimdramPerfModel()
    vals = jnp.asarray(RNG.integers(0, 256, 128), jnp.int32)
    other = BitplaneArray.from_values(
        jnp.asarray(RNG.integers(0, 256, (2, 64)), jnp.int32), 8)
    with timed(mode="replay") as st:
        banked = BitplaneArray.from_values(vals, 8).rebank(2)
        bbop_relu(other, 8)        # unrelated banked op must NOT take skew
        bbop_add(banked, banked, 8)
        spread_skewed = st.replay_bank_spread_ns
        bbop_add(banked, banked, 8)          # skew already consumed
        spread_inc = st.replay_bank_spread_ns - spread_skewed
    # the skew belongs to the scattered planes' consumer, exactly:
    # bank 1's 8 planes arrive one half of the scatter transfer later
    skew = (0.0, m.movement.inter_bank_ns(16) / 2)
    assert st.per_op["relu/8b"]["replay_ns"] == pytest.approx(
        m.replay_result(_ct("relu", 8)[1], banks=2).ns)
    assert st.per_op["addition/8b"]["replay_ns"] == pytest.approx(
        m.replay_result(_ct("addition", 8)[1], banks=2, offsets_ns=skew).ns
        + m.replay_result(_ct("addition", 8)[1], banks=2).ns)
    assert spread_skewed >= skew[1]
    assert spread_inc < spread_skewed


# ---------------------------------------------------------------------------
# Regression: bounded cost memos (PerfStats leak fix)
# ---------------------------------------------------------------------------


def test_cost_memos_are_fifo_bounded():
    """A long-lived accumulator fed a stream of ad-hoc programs/traces must
    not pin them all forever — the per-accumulator memos are capped."""
    st = PerfStats(mode="replay")
    for _ in range(_COST_CAP + 16):
        prog = _toy(1, 0)
        st.charge_program(prog, 1, 32, trace=lower_program(prog))
    assert len(st._prog_costs) <= _COST_CAP
    assert len(st._replay_costs) <= _COST_CAP
    assert st.n_programs == _COST_CAP + 16      # charging itself unbounded


# ---------------------------------------------------------------------------
# Regression: one replayed-energy formula (model ≡ charge_program)
# ---------------------------------------------------------------------------


def test_replay_energy_formula_parity():
    m = SimdramPerfModel()
    prog, trace = compile_trace("addition", 8)
    for banks in (1, 3):
        st = PerfStats(model=m, mode="replay")
        st.charge_program(prog, banks, 32 * banks, trace=trace)
        assert st.replay_nj == pytest.approx(
            m.replay_energy_nj(prog, trace, banks=banks))
    # banks=1 keeps the legacy single-bank closed form
    res = m.replay_result(trace)
    assert m.replay_energy_nj(prog, trace) == pytest.approx(
        m.energy_nj(prog) + m.energy.background_w * res.stall_ns)


# ---------------------------------------------------------------------------
# Regression: LRU lowering memo, dropped by clear_trace_cache
# ---------------------------------------------------------------------------


def test_lower_memo_is_lru_and_cleared():
    from repro.core import trace as trace_mod
    trace_mod._LOWER_MEMO.clear()
    progs = [_toy(1, 0) for _ in range(trace_mod._LOWER_MEMO_CAP)]
    traces = [lower_program(p) for p in progs]
    assert len(trace_mod._LOWER_MEMO) == trace_mod._LOWER_MEMO_CAP
    # a hit refreshes recency: the hottest program survives the next insert
    assert lower_program(progs[0]) is traces[0]
    lower_program(_toy(1, 0))                    # evicts the true LRU
    assert id(progs[0]) in trace_mod._LOWER_MEMO
    assert id(progs[1]) not in trace_mod._LOWER_MEMO
    # clear_trace_cache drops the lowering memo too, so the benchmark's
    # "cold compile" row measures a genuinely cold lower path
    lowered_before = len(trace_mod._LOWER_MEMO)
    assert lowered_before > 0
    trace_mod.clear_trace_cache()
    assert len(trace_mod._LOWER_MEMO) == 0
    assert len(trace_mod._COMPILE_CACHE) == 0
