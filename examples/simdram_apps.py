"""The paper's application kernels (Fig. 11) on a `SimdramMachine` session:
brightness (predication), BitWeaving scan (relational), an XNOR-NET binary
layer via the Pallas bit-serial matmul kernel — plus a kernel built on a
**user-defined operation** (`define_op`), the paper's Step-1-to-3 path for
ops the framework never shipped.

    PYTHONPATH=src python examples/simdram_apps.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.graph import lit_not
from repro.core.uprogram import DRow
from repro.kernels.bitserial_matmul import bitserial_matmul, pack_signs
from repro.ops import (SimdramMachine, bbop_add, bbop_greater,
                       bbop_greater_equal, bbop_if_else)

# one session machine for every kernel below: its μProgram Memory caches
# each compiled op across calls, and `machine.session()` routes the plain
# bbop_* surface through it
MACHINE = SimdramMachine(backend="unrolled", cache_capacity=32)


def brightness(image, delta):
    """image + delta, clamped to 255 (paper §D brightness kernel)."""
    x = jnp.asarray(image.ravel(), jnp.int32)
    with MACHINE.session():
        raw = bbop_add(x, jnp.full_like(x, delta), 8)
        ovf = bbop_greater(x, raw, 8)               # wraparound ⇒ clamp
        out = bbop_if_else(ovf, jnp.full_like(x, 255), raw, 8)
    return np.asarray(out).reshape(image.shape)


def bitweaving_scan(values, lo, hi):
    """SELECT COUNT(*) WHERE lo <= v <= hi (paper's BitWeaving kernel)."""
    v = jnp.asarray(values, jnp.int32)
    with MACHINE.session():
        ge = bbop_greater_equal(v, jnp.full_like(v, lo), 8)
        le = bbop_greater_equal(jnp.full_like(v, hi), v, 8)
    return int((np.asarray(ge) & np.asarray(le)).sum())


def xnor_layer(x, w):
    """Binary fully-connected layer: sign inputs × sign weights via the
    packed XNOR-popcount Pallas kernel (VGG/LeNet building block)."""
    xp, wp = pack_signs(jnp.asarray(x)), pack_signs(jnp.asarray(w))
    return np.asarray(bitserial_matmul(xp, wp, x.shape[1], interpret=True))


# --- a kernel on a user-defined operation ------------------------------------
# masked darken: pixel - delta wherever mask, untouched elsewhere — one
# in-DRAM pass of a `gated_sub` op the framework never shipped (Step 1: the
# AOIG below; Steps 2-3 happen inside define_op / the machine backends)

def _build_gated_sub(g):
    a, b, gate, w = (g.input(n) for n in ("a", "b", "gate", "borrow"))
    bg = g.gate_and(b, gate)
    axb = g.gate_xor(a, bg)
    g.add_output("out", g.gate_xor(axb, w))
    g.add_output("borrow", g.gate_or_node(
        g.gate_and(lit_not(a), bg), g.gate_and(w, lit_not(axb))))


GATED_SUB = MACHINE.define_op(
    "gated_sub", _build_gated_sub,
    invariants={"gate": DRow("gate", 0, fixed=True)}, states={"borrow": 0})


def masked_darken(image, mask, delta):
    """image - delta where mask (single fused user-op pass)."""
    x = jnp.asarray(image.ravel(), jnp.int32)
    m = jnp.asarray(mask.ravel().astype(np.int32))
    d = jnp.full_like(x, delta)
    return np.asarray(GATED_SUB(x, d, m, n_bits=8)).reshape(image.shape)


def main():
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (16, 16))
    bright = brightness(img, 64)
    assert np.array_equal(bright, np.minimum(img + 64, 255))
    print(f"brightness: {img[0, :6]} -> {bright[0, :6]}  OK")

    vals = rng.integers(0, 256, 4096)
    cnt = bitweaving_scan(vals, 50, 180)
    assert cnt == int(((vals >= 50) & (vals <= 180)).sum())
    print(f"bitweaving scan: {cnt}/4096 rows matched  OK")

    x = rng.choice([-1.0, 1.0], (128, 256)).astype(np.float32)
    w = rng.choice([-1.0, 1.0], (128, 256)).astype(np.float32)
    y = xnor_layer(x, w)
    assert np.array_equal(y, (x @ w.T).astype(np.int32))
    print(f"xnor layer 128x256·256x128: max activation {y.max()}  OK")

    dark = masked_darken(np.minimum(img, 255 - 0), img > 128, 40)
    exp = np.where(img > 128, (img - 40) & 255, img)
    assert np.array_equal(dark, exp)
    print(f"masked darken via user-defined gated_sub: "
          f"{img[0, :6]} -> {dark[0, :6]}  OK")
    st = MACHINE.cache_stats()
    print(f"machine μProgram Memory after all kernels: {st['entries']} "
          f"entries, hit rate {st['hit_rate']:.2f}")


if __name__ == "__main__":
    main()
