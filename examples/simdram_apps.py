"""The paper's application kernels (Fig. 11) on the bbop engine:
brightness (predication), BitWeaving scan (relational), and an XNOR-NET
binary layer via the Pallas bit-serial matmul kernel.

    PYTHONPATH=src python examples/simdram_apps.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels.bitserial_matmul import bitserial_matmul, pack_signs
from repro.ops import (bbop_add, bbop_greater, bbop_greater_equal,
                       bbop_if_else)


def brightness(image, delta):
    """image + delta, clamped to 255 (paper §D brightness kernel)."""
    x = jnp.asarray(image.ravel(), jnp.int32)
    raw = bbop_add(x, jnp.full_like(x, delta), 8)
    ovf = bbop_greater(x, raw, 8)               # wraparound ⇒ clamp
    out = bbop_if_else(ovf, jnp.full_like(x, 255), raw, 8)
    return np.asarray(out).reshape(image.shape)


def bitweaving_scan(values, lo, hi):
    """SELECT COUNT(*) WHERE lo <= v <= hi (paper's BitWeaving kernel)."""
    v = jnp.asarray(values, jnp.int32)
    ge = bbop_greater_equal(v, jnp.full_like(v, lo), 8)
    le = bbop_greater_equal(jnp.full_like(v, hi), v, 8)
    return int((np.asarray(ge) & np.asarray(le)).sum())


def xnor_layer(x, w):
    """Binary fully-connected layer: sign inputs × sign weights via the
    packed XNOR-popcount Pallas kernel (VGG/LeNet building block)."""
    xp, wp = pack_signs(jnp.asarray(x)), pack_signs(jnp.asarray(w))
    return np.asarray(bitserial_matmul(xp, wp, x.shape[1], interpret=True))


def main():
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (16, 16))
    bright = brightness(img, 64)
    assert np.array_equal(bright, np.minimum(img + 64, 255))
    print(f"brightness: {img[0, :6]} -> {bright[0, :6]}  OK")

    vals = rng.integers(0, 256, 4096)
    cnt = bitweaving_scan(vals, 50, 180)
    assert cnt == int(((vals >= 50) & (vals <= 180)).sum())
    print(f"bitweaving scan: {cnt}/4096 rows matched  OK")

    x = rng.choice([-1.0, 1.0], (128, 256)).astype(np.float32)
    w = rng.choice([-1.0, 1.0], (128, 256)).astype(np.float32)
    y = xnor_layer(x, w)
    assert np.array_equal(y, (x @ w.T).astype(np.int32))
    print(f"xnor layer 128x256·256x128: max activation {y.max()}  OK")


if __name__ == "__main__":
    main()
