"""End-to-end driver: train a ~100M-parameter LM with the full framework
stack — sharded params, fault-tolerant loop, checkpointing, synthetic data.

    PYTHONPATH=src python examples/train_100m.py --steps 300
(defaults to a short smoke run; pass --steps 300 for the full example)
"""
import argparse

import jax

from repro.configs.base import ModelConfig
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.failover import FailoverConfig, FailoverRunner
from repro.launch.train import build_mesh, setup
from repro.train.data import DataConfig, synthetic_batch
from repro.train.optimizer import AdamWConfig

# ~105M params: 10 layers × d=640 + 50k vocab (untied)
CONFIG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=10, d_model=640,
    n_heads=10, n_kv_heads=5, d_ff=1792, vocab=50304, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CONFIG_100M
    mesh = build_mesh()
    opt = AdamWConfig(lr=6e-4, total_steps=args.steps,
                      warmup_steps=max(5, args.steps // 20))
    state, _, step = setup(cfg, mesh, opt)
    n = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh {dict(mesh.shape)}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    ckpt = CheckpointManager(args.ckpt)
    runner = FailoverRunner(step, ckpt, FailoverConfig(checkpoint_every=100))
    state, history = runner.run(state, lambda s: synthetic_batch(dcfg, s),
                                0, args.steps, mesh=mesh)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {len(history)} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    if runner.monitor.flagged:
        print("stragglers:", runner.monitor.flagged)


if __name__ == "__main__":
    main()
