"""Quickstart: the SIMDRAM framework end-to-end in 60 seconds.

1. Step 1-2: compile an operation (AOIG → MIG → μProgram) and inspect it.
2. Step 3: execute it — faithful subarray model and the JAX fast path.
3. The paper's Listing 1: predicated vector add/sub via bbops.
4. Plane-resident pipelines: chain ops vertically, pick a backend, batch
   over banks — zero per-op transposition-unit traffic.
5. Timed execution: the same fused chain under the modeled-DRAM cost
   layer — end-to-end nanoseconds/nanojoules/GOps/s from the live run.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.circuits import PAPER_COUNTS, compile_operation
from repro.core.executor import from_planes, run_program
from repro.ops import (bbop_add, bbop_greater, bbop_if_else, bbop_mul,
                       bbop_relu, bbop_sub, simdram_pipeline)
from repro.simdram.layout import reset_transpose_stats, transpose_counts
from repro.simdram.timing import SimdramPerfModel


def main():
    # --- compile full addition for 8-bit elements ---------------------------
    prog = compile_operation("addition", 8)
    print(prog.pretty())
    print(f"\ncommand sequences: {prog.command_count()} "
          f"(paper Table 5: {PAPER_COUNTS['addition'](8)})")
    m = SimdramPerfModel()
    print(f"modeled throughput @16 banks: "
          f"{m.throughput_gops(prog, 16):.1f} GOps/s\n")

    # --- run on the faithful DRAM subarray model ----------------------------
    rng = np.random.default_rng(0)
    a, b = rng.integers(0, 256, 8), rng.integers(0, 256, 8)
    outs, sa = run_program(prog, {"a": a, "b": b})
    print("subarray executor:", from_planes(outs["out"], 8),
          "(expected", (a + b) % 256, ")")
    print("DRAM command stats:", sa.stats, "\n")

    # --- paper Listing 1: predicated execution via the bbop ISA -------------
    A = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    B = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    pred = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    D = bbop_add(A, B, 8)
    E = bbop_sub(A, B, 8)
    F = bbop_greater(A, pred, 8)
    C = bbop_if_else(F, D, E, 8)
    exp = np.where(np.asarray(A) > np.asarray(pred),
                   (np.asarray(A) + np.asarray(B)) & 255,
                   (np.asarray(A) - np.asarray(B)) & 255)
    assert np.array_equal(np.asarray(C), exp)
    print("Listing-1 predicated add/sub: OK ->", np.asarray(C)[:8], "...")

    # --- plane-resident pipeline: one transpose pair for a 3-op chain -------
    a = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
    c = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
    reset_transpose_stats()
    with simdram_pipeline(backend="unrolled") as p:
        pa, pb, pc = p.load([a, b, c], 8)
        res = p.store(bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8))
    print(f"fused relu(add(mul(a,b),c)): transposition-unit passes "
          f"(to, from) = {transpose_counts()} ->", np.asarray(res)[:8], "...")

    # --- same chain, bank-batched (the paper's 16-bank scaling) -------------
    ab = jnp.asarray(rng.integers(0, 256, (16, 64)), jnp.int32)
    bb = jnp.asarray(rng.integers(0, 256, (16, 64)), jnp.int32)
    with simdram_pipeline(banks=16) as p:
        pa, pb = p.load([ab, bb], 8)
        banked = p.store(bbop_add(pa, pb, 8))
    assert np.array_equal(np.asarray(banked),
                          (np.asarray(ab) + np.asarray(bb)) & 255)
    print("16-bank batched add: OK", banked.shape)

    # --- timed execution: modeled DRAM cost of the live fused chain ---------
    with simdram_pipeline(banks=16, timed=True) as p:
        pa, pb = p.load([ab, bb], 8)
        p.store(bbop_relu(bbop_add(pa, pb, 8), 8))
    print("\ntimed 16-bank relu(add(a,b)) — modeled DRAM cost "
          "(μProgram AAP/AP latencies + movement + transposition):")
    print(p.perf_report())


if __name__ == "__main__":
    main()
