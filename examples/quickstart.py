"""Quickstart: the SIMDRAM framework end-to-end in 60 seconds.

1. Step 1-2: compile an operation (AOIG → MIG → μProgram) and inspect it.
2. Step 3: execute it — faithful subarray model and the JAX fast path.
3. The paper's Listing 1: predicated vector add/sub via bbops.
4. `SimdramMachine`: the session-scoped end-to-end API — one object owning
   DRAM timing, banks, backend, its own μProgram Memory and PerfStats.
5. **User-defined operations**: the paper's headline feature — register an
   arbitrary AOIG with `machine.define_op` and execute it on every
   backend, with replay timing, no framework changes.
6. Plane-resident pipelines + timed execution on the machine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.circuits import PAPER_COUNTS, compile_operation
from repro.core.executor import from_planes, run_program
from repro.core.graph import lit_not
from repro.core.uprogram import DRow
from repro.ops import (SimdramMachine, bbop_add, bbop_greater, bbop_if_else,
                       bbop_mul, bbop_relu, bbop_sub)
from repro.simdram.layout import reset_transpose_stats, transpose_counts
from repro.simdram.timing import DRAMTiming, SimdramPerfModel


def main():
    # --- compile full addition for 8-bit elements ---------------------------
    prog = compile_operation("addition", 8)
    print(prog.pretty())
    print(f"\ncommand sequences: {prog.command_count()} "
          f"(paper Table 5: {PAPER_COUNTS['addition'](8)})")
    m = SimdramPerfModel()
    print(f"modeled throughput @16 banks: "
          f"{m.throughput_gops(prog, 16):.1f} GOps/s\n")

    # --- run on the faithful DRAM subarray model ----------------------------
    rng = np.random.default_rng(0)
    a, b = rng.integers(0, 256, 8), rng.integers(0, 256, 8)
    outs, sa = run_program(prog, {"a": a, "b": b})
    print("subarray executor:", from_planes(outs["out"], 8),
          "(expected", (a + b) % 256, ")")
    print("DRAM command stats:", sa.stats, "\n")

    # --- paper Listing 1: predicated execution via the bbop ISA -------------
    A = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    B = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    pred = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    D = bbop_add(A, B, 8)
    E = bbop_sub(A, B, 8)
    F = bbop_greater(A, pred, 8)
    C = bbop_if_else(F, D, E, 8)
    exp = np.where(np.asarray(A) > np.asarray(pred),
                   (np.asarray(A) + np.asarray(B)) & 255,
                   (np.asarray(A) - np.asarray(B)) & 255)
    assert np.array_equal(np.asarray(C), exp)
    print("Listing-1 predicated add/sub: OK ->", np.asarray(C)[:8], "...")

    # --- SimdramMachine: the whole configuration in one session object ------
    machine = SimdramMachine(timing=DRAMTiming(), banks=16,
                             backend="unrolled", cache_capacity=32)
    x = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
    y = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
    out = machine.op("addition")(x, y, n_bits=8)
    assert np.array_equal(np.asarray(out),
                          (np.asarray(x) + np.asarray(y)) & 255)
    print(f"\n{machine}")
    print("machine.op('addition'): OK   μProgram Memory:",
          machine.cache_stats())

    # --- user-defined operation: the paper's Steps 1-3 as API ---------------
    # Step 1 — describe the 1-bit slice as an AOIG: out = a - b·gate
    # (a borrow-chained subtract whose subtrahend is predicated per lane)
    def build_gated_sub(g):
        av, bv, gate, w = (g.input(n) for n in ("a", "b", "gate", "borrow"))
        bg = g.gate_and(bv, gate)
        axb = g.gate_xor(av, bg)
        g.add_output("out", g.gate_xor(axb, w))
        g.add_output("borrow", g.gate_or_node(
            g.gate_and(lit_not(av), bg), g.gate_and(w, lit_not(axb))))

    # Steps 1-2 — synthesize to an optimized MIG, allocate B-group rows,
    # generate + lower the μProgram (cached in the machine's memory)
    gated_sub = machine.define_op(
        "gated_sub", build_gated_sub,
        invariants={"gate": DRow("gate", 0, fixed=True)},
        states={"borrow": 0})
    gprog, gtrace = gated_sub.program(n_bits=8)
    print(f"\nuser-defined gated_sub: {gprog.command_count()} command "
          f"sequences at 8 bits, {gtrace.n_commands} in the lowered trace")

    # Step 3 — execute on every registered backend, no other changes
    gmask = jnp.asarray(rng.integers(0, 2, 64), jnp.int32)
    expect = np.where(np.asarray(gmask),
                      (np.asarray(x) - np.asarray(y)) & 255, np.asarray(x))
    for be in ("reference", "unrolled", "pallas"):
        got = gated_sub(x, y, gmask, n_bits=8, backend=be)
        assert np.array_equal(np.asarray(got), expect), be
    print("gated_sub on reference/unrolled/pallas: OK ->", expect[:8], "...")

    # ... and with cycle-accurate replay timing, also for free
    with machine.timed(mode="replay") as st:
        gated_sub(x, y, gmask, n_bits=8)
    print(f"gated_sub replay timing: {st.replay_ns:.0f} ns replayed >= "
          f"{st.exec_ns:.0f} ns analytic")

    # --- plane-resident pipeline on the machine (one transpose pair) --------
    av = jnp.asarray(rng.integers(0, 256, (16, 64)), jnp.int32)
    bv = jnp.asarray(rng.integers(0, 256, (16, 64)), jnp.int32)
    cv = jnp.asarray(rng.integers(0, 256, (16, 64)), jnp.int32)
    reset_transpose_stats()
    with machine.pipeline(timed=True) as p:
        pa, pb, pc = p.load([av, bv, cv], 8)
        res = p.store(bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8))
    raw = (np.asarray(av) * np.asarray(bv) + np.asarray(cv)) & 255
    assert np.array_equal(np.asarray(res), np.where(raw >> 7, 0, raw))
    assert transpose_counts() == (1, 1)     # one fused pass each way
    print(f"\nfused 16-bank relu(add(mul(a,b),c)): transposition-unit "
          f"passes (to, from) = {transpose_counts()} ->",
          np.asarray(res)[0, :8], "...")
    print("\ntimed chain on the machine's own accumulator "
          "(μProgram AAP/AP latencies + movement + transposition):")
    print(machine.perf_report())

    # --- machines are isolated: a second session, different substrate -------
    other = SimdramMachine(timing=DRAMTiming(tRAS_ns=64.0),
                           backend="reference")
    other.op("addition")(x, y, n_bits=8)
    print(f"\nsecond machine (slow tRAS, reference backend) kept its own "
          f"cache {other.cache_stats()['entries']} entries; first machine "
          f"unchanged: {machine.cache_stats()['entries']} entries")


if __name__ == "__main__":
    main()
