"""Quickstart: the SIMDRAM framework end-to-end in 60 seconds.

1. Step 1-2: compile an operation (AOIG → MIG → μProgram) and inspect it.
2. Step 3: execute it — faithful subarray model and the JAX fast path.
3. The paper's Listing 1: predicated vector add/sub via bbops.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.circuits import PAPER_COUNTS, compile_operation
from repro.core.executor import from_planes, run_program
from repro.ops import (bbop_add, bbop_greater, bbop_if_else, bbop_sub)
from repro.simdram.timing import SimdramPerfModel


def main():
    # --- compile full addition for 8-bit elements ---------------------------
    prog = compile_operation("addition", 8)
    print(prog.pretty())
    print(f"\ncommand sequences: {prog.command_count()} "
          f"(paper Table 5: {PAPER_COUNTS['addition'](8)})")
    m = SimdramPerfModel()
    print(f"modeled throughput @16 banks: "
          f"{m.throughput_gops(prog, 16):.1f} GOps/s\n")

    # --- run on the faithful DRAM subarray model ----------------------------
    rng = np.random.default_rng(0)
    a, b = rng.integers(0, 256, 8), rng.integers(0, 256, 8)
    outs, sa = run_program(prog, {"a": a, "b": b})
    print("subarray executor:", from_planes(outs["out"], 8),
          "(expected", (a + b) % 256, ")")
    print("DRAM command stats:", sa.stats, "\n")

    # --- paper Listing 1: predicated execution via the bbop ISA -------------
    A = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    B = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    pred = jnp.asarray(rng.integers(0, 128, 16), jnp.int32)
    D = bbop_add(A, B, 8)
    E = bbop_sub(A, B, 8)
    F = bbop_greater(A, pred, 8)
    C = bbop_if_else(F, D, E, 8)
    exp = np.where(np.asarray(A) > np.asarray(pred),
                   (np.asarray(A) + np.asarray(B)) & 255,
                   (np.asarray(A) - np.asarray(B)) & 255)
    assert np.array_equal(np.asarray(C), exp)
    print("Listing-1 predicated add/sub: OK ->", np.asarray(C)[:8], "...")


if __name__ == "__main__":
    main()
