"""Batched serving example: prefill + greedy decode with sharded KV caches
(reduced qwen config so it runs on CPU in seconds).

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.params import init_params
from repro.models.transformer import model_defs
from repro.serve.decode import greedy_decode


def main():
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_params(model_defs(cfg), jax.random.key(0))
    batch, prompt_len, gen = 4, 12, 16
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len),
                                 0, cfg.vocab)
    out = greedy_decode(params, cfg, prompts, steps=gen,
                        max_seq=prompt_len + gen)
    print(f"arch={cfg.name}  batch={batch}  prompt={prompt_len}  "
          f"generated={gen}")
    for i in range(batch):
        print(f"  seq{i}: prompt={prompts[i].tolist()} "
              f"-> {out[i].tolist()}")
    assert out.shape == (batch, gen)
    print("decode OK (greedy, KV-cached)")


if __name__ == "__main__":
    main()
