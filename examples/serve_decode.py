"""Batched serving example: prefill + greedy decode with sharded KV caches
(reduced qwen config so it runs on CPU in seconds), then the same decode
with the PuM-offloaded sampler metered by the timed execution layer —
modeled DRAM cost per decoded token.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.configs import get_reduced
from repro.models.params import init_params
from repro.models.transformer import model_defs
from repro.ops import PerfStats
from repro.serve.decode import greedy_decode


def main():
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_params(model_defs(cfg), jax.random.key(0))
    batch, prompt_len, gen = 4, 12, 16
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len),
                                 0, cfg.vocab)
    out = greedy_decode(params, cfg, prompts, steps=gen,
                        max_seq=prompt_len + gen)
    print(f"arch={cfg.name}  batch={batch}  prompt={prompt_len}  "
          f"generated={gen}")
    for i in range(batch):
        print(f"  seq{i}: prompt={prompts[i].tolist()} "
              f"-> {out[i].tolist()}")
    assert out.shape == (batch, gen)
    print("decode OK (greedy, KV-cached)")

    # same decode, sampling in-memory: each sequence's quantized logits in
    # its own DRAM bank, metered by the timed execution layer
    stats = PerfStats()
    out_pum = greedy_decode(params, cfg, prompts, steps=gen,
                            max_seq=prompt_len + gen, sampler="simdram",
                            sampler_perf=stats)
    assert out_pum.shape == (batch, gen)
    print(f"PuM sampler OK: modeled {stats.total_ns / gen:.0f} ns "
          f"/ {stats.total_nj / gen:.0f} nJ per decoded token "
          f"({stats.n_programs // gen} μPrograms/token, "
          f"banks={stats.max_banks})")


if __name__ == "__main__":
    main()
