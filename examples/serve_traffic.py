"""Serving traffic end to end: a `SimdramServer` admits concurrent decode
sessions (thread-safe, asyncio-friendly), shards them over a pool of
isolated `SimdramMachine` instances, and continuously batches compatible
sessions into the bank axis at every decode-step boundary — retiring
finished sequences, joining new arrivals, and reporting modeled SLO
metrics (p50/p99 ns-per-token, TTFT, tokens/s at N users).

    PYTHONPATH=src python examples/serve_traffic.py
"""
import asyncio

from repro.serve import SimdramServer, profile_for

# the model zoo supplies request-mix diversity: each config maps to a
# per-token μProgram profile (op family, bit width, SIMD lanes)
MIX = ["qwen1_5_0_5b", "mamba2_130m", "whisper_large_v3", "olmoe_1b_7b"]
for cfg in MIX:
    p = profile_for(cfg)
    print(f"  {cfg:18s} -> {p.op}/{p.n_bits}b x {p.lanes} lanes "
          f"[{p.family}]")

server = SimdramServer(n_machines=2, n_banks=8, refresh_policy="aware")

# 8 concurrent users, staggered arrivals on the MODELED clock, varied
# sequence lengths so sessions retire mid-flight and new arrivals join
# at step boundaries (continuous batching, not static batching)
handles = [server.submit_session(MIX[u % len(MIX)], n_tokens=3 + u % 3,
                                 arrival_ns=u * 400.0)
           for u in range(8)]


async def main():
    stats = await server.run_async()        # serving loop off the event loop
    await handles[0].wait_async()           # handles are awaitable too
    return stats


stats = asyncio.run(main())
print(stats.report())
assert all(h.done() for h in handles)
assert stats.n_sessions == 8 and stats.users == 8
assert stats.p99_token_ns >= stats.p50_token_ns > 0.0
# the pool actually sharded: every machine served tokens
assert all(m["tokens"] > 0 for m in stats.machines)
print("ok: served", stats.total_tokens, "tokens over",
      len(stats.machines), "machines")
