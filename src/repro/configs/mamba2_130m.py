"""mamba2-130m [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_chunk=64, rope="none",
    tie_embeddings=True,
    layer_pattern=("ssm",) * 24,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-130m-reduced", n_layers=2, d_model=64,
        ssm_state=16, vocab=256, layer_pattern=("ssm",) * 2)
