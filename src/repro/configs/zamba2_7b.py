"""zamba2-7b [hybrid] — Mamba2 backbone with a weight-tied shared attention
block applied every 6 SSM layers.  [arXiv:2411.15242]"""
import dataclasses

from .base import ModelConfig

_N = 81

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=_N, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_chunk=64,
    layer_pattern=("ssm",) * _N,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-7b-reduced", n_layers=12, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, ssm_state=16,
        layer_pattern=("ssm",) * 12)
