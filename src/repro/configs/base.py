"""Model/runtime configuration system.

One ``ModelConfig`` covers all ten assigned architecture families (dense,
MoE, MLA, hybrid SSM, pure SSM, enc-dec audio, VLM).  Every architecture
config file in this package exports ``CONFIG`` (full size, dry-run only) and
``reduced()`` (CPU-smoke-test size, same family/topology).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope: str = "rope"               # rope | mrope | none
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # -- MoE ------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    experts_per_tok: int = 0
    d_expert: int = 0                # expert FFN hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # -- MLA (DeepSeek-V2 multi-head latent attention) --------------------
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64          # decoupled RoPE key dim

    # -- SSM (Mamba2/SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0               # 0 → d_inner // 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    # layer pattern: per-layer 'attn' | 'ssm' | 'shared_attn'; empty → attn
    layer_pattern: tuple[str, ...] = ()

    # -- enc-dec (whisper) --------------------------------------------------
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # frames after the conv frontend (stub)

    # -- modality frontends (stubs per assignment) --------------------------
    frontend: str = "none"           # none | audio_stub | vision_stub

    # -- paper integration (SIMDRAM bit-serial layers) ----------------------
    pum_mlp: bool = False            # binarized (XNOR-popcount) MLP path
    pum_bits: int = 8

    # -- training/runtime ----------------------------------------------------
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    loss_chunk: int = 0              # >0: chunked-vocab loss (§Perf)
    ssd_f32: bool = True             # SSD scan internals in f32 (vs bf16)
    cross_kv_cache: bool = True      # enc-dec decode: cache cross-attn K/V
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (quantized KV cache)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    def pattern(self) -> tuple[str, ...]:
        if self.layer_pattern:
            assert len(self.layer_pattern) == self.n_layers
            return self.layer_pattern
        return ("attn",) * self.n_layers

    def decode_supported(self) -> bool:
        return True                  # all assigned archs have a decoder

    def long_context_supported(self) -> bool:
        """long_500k runs only for sub-quadratic (SSM/hybrid) archs; pure
        full-attention archs skip it (see DESIGN.md §Arch-applicability)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory plans)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.pattern():
            if kind == "ssm":
                di, st, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
                # in_proj (z,x,B,C,dt) + out_proj + conv (as in mamba2)
                total += d * (2 * di + 2 * st + nh) + di * d + 3 * di
            else:
                if self.mla:
                    r, rh = self.kv_lora_rank, self.rope_head_dim
                    qd = self.n_heads * (self.hd + rh)
                    total += d * (r + rh) + r * self.n_heads * 2 * self.hd
                    total += (d * self.q_lora_rank + self.q_lora_rank * qd
                              if self.q_lora_rank else d * qd)
                    total += self.n_heads * self.hd * d
                else:
                    total += d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                    total += self.n_heads * self.hd * d
                if self.moe:
                    e = self.n_experts * 3 * d * self.d_expert
                    e += self.n_shared_experts * 3 * d * self.d_expert
                    total += e + d * self.n_experts
                else:
                    total += 3 * d * self.d_ff
        if self.enc_dec:
            # encoder layers: self-attn + mlp; decoder cross-attn already in
            # n_layers accounting above? encoder counted here:
            enc = self.n_encoder_layers * (4 * d * self.hd * self.n_heads // 1
                                           + 3 * d * self.d_ff)
            total += enc
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — the MoE-aware count for MODEL_FLOPS."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_experts = self.experts_per_tok + self.n_shared_experts
        per_layer_active = dense_experts * 3 * d * self.d_expert + d * self.n_experts
        per_layer_all = ((self.n_experts + self.n_shared_experts) * 3 * d
                         * self.d_expert + d * self.n_experts)
        return self.param_count() - self.n_layers * (per_layer_all - per_layer_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.long_context_supported()
    return True
