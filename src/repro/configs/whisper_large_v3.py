"""whisper-large-v3 [audio] — enc-dec; conv frontend is a STUB
(input_specs supplies precomputed mel-frame embeddings).  [arXiv:2212.04356]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
    enc_dec=True, n_encoder_layers=32, encoder_seq=1500,
    frontend="audio_stub",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-large-v3-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        n_encoder_layers=2, encoder_seq=32)
