"""stablelm-12b [dense] — GQA kv=8.  [hf:stabilityai/stablelm-2-12b]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=13824, vocab=100352,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="stablelm-12b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
