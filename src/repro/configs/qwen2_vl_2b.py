"""qwen2-vl-2b [vlm] — M-RoPE, dynamic-resolution vision frontend (STUB:
input_specs supplies precomputed patch embeddings / M-RoPE position ids).
[arXiv:2409.12191]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936, qkv_bias=True,
    rope="mrope", frontend="vision_stub", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-2b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
