"""olmoe-1b-7b [moe] — 64 experts, top-8.  [arXiv:2409.02060]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    moe=True, n_experts=64, experts_per_tok=8, d_expert=1024,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="olmoe-1b-7b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab=256, n_experts=8,
        experts_per_tok=2, d_expert=32)
