"""deepseek-v2-236b [moe] — MLA (kv_lora=512, decoupled RoPE), 2 shared +
160 routed experts, top-6.  [arXiv:2405.04434]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=1536, vocab=102400, head_dim=128,
    moe=True, n_experts=160, experts_per_tok=6, d_expert=1536,
    n_shared_experts=2,
    mla=True, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-236b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=64, vocab=256,
        n_experts=8, experts_per_tok=2, d_expert=32, n_shared_experts=1,
        kv_lora_rank=32, q_lora_rank=32, rope_head_dim=8)
