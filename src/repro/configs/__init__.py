"""Assigned-architecture configs.  ``get_config(arch)`` / ``get_reduced(arch)``."""
from __future__ import annotations

import importlib

ARCHS = (
    "codeqwen1_5_7b", "qwen1_5_0_5b", "stablelm_12b", "granite_34b",
    "qwen2_vl_2b", "deepseek_v2_236b", "olmoe_1b_7b", "zamba2_7b",
    "whisper_large_v3", "mamba2_130m",
)

ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "codeqwen1.5-7b": "codeqwen1_5_7b", "qwen1.5-0.5b": "qwen1_5_0_5b",
    "stablelm-12b": "stablelm_12b", "granite-34b": "granite_34b",
    "qwen2-vl-2b": "qwen2_vl_2b", "deepseek-v2-236b": "deepseek_v2_236b",
    "olmoe-1b-7b": "olmoe_1b_7b", "zamba2-7b": "zamba2_7b",
    "whisper-large-v3": "whisper_large_v3", "mamba2-130m": "mamba2_130m",
})


def _module(arch: str):
    key = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_reduced(arch: str):
    return _module(arch).reduced()
