"""Batched serving driver: continuous greedy decoding over request batches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --batch 8 --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_reduced
from ..distributed.sharding import tree_shardings
from ..models.params import init_params
from ..models.transformer import model_defs
from ..serve.decode import greedy_decode
from .train import build_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--requests", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = build_mesh()
    defs = model_defs(cfg)
    params = jax.tree.map(jax.device_put, init_params(defs, jax.random.key(0)),
                          tree_shardings(defs, mesh))
    extra = None
    if cfg.enc_dec:
        extra = {"encoder_frames": jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)}
    print(f"serving {cfg.name} on mesh {dict(mesh.shape)} "
          f"(batch={args.batch}, kv={cfg.kv_cache_dtype})")
    total_toks = 0
    t0 = time.time()
    for req in range(args.requests):
        prompts = jax.random.randint(jax.random.key(req + 1),
                                     (args.batch, args.prompt_len),
                                     0, cfg.vocab)
        out = greedy_decode(params, cfg, prompts, steps=args.gen,
                            max_seq=args.prompt_len + args.gen,
                            extra_batch=extra)
        out.block_until_ready()
        total_toks += args.batch * args.gen
        print(f"  request batch {req}: generated {out.shape} "
              f"first-seq head: {out[0, :8].tolist()}")
    dt = time.time() - t0
    print(f"{total_toks} tokens in {dt:.1f}s "
          f"({total_toks / dt:.1f} tok/s on this host)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
