"""SIMDRAM serving driver: continuous-batching decode over a pool of
bank-sharded machines (:class:`~repro.serve.server.SimdramServer`).

    PYTHONPATH=src python -m repro.launch.serve --users 8 --steps 16 \
        --config qwen1_5_0_5b,mamba2_130m --machines 2 --banks 8 \
        --refresh-policy aware

Each user is one decode session: its model-zoo config sets the per-token
μProgram profile (request-mix diversity), arrivals are staggered on the
modeled clock, and the server continuously batches compatible sessions
into the bank axis at every step boundary.  All reported latencies are
modeled nanoseconds (deterministic), not wall clock.
"""
from __future__ import annotations

import argparse

from ..serve.server import SimdramServer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="continuous-batching decode over SIMDRAM machines")
    ap.add_argument("--users", type=int, default=8,
                    help="concurrent decode sessions")
    ap.add_argument("--steps", type=int, default=8,
                    help="tokens generated per session")
    ap.add_argument("--config", default="qwen1_5_0_5b,mamba2_130m",
                    help="comma-separated model-zoo configs, assigned "
                         "round-robin across users")
    ap.add_argument("--machines", type=int, default=2,
                    help="SimdramMachine pool size")
    ap.add_argument("--banks", type=int, default=8,
                    help="modeled controller banks per machine (the "
                         "continuous batch width)")
    ap.add_argument("--refresh-policy", default="aware",
                    choices=("aware", "stall", "defer"))
    ap.add_argument("--backend", default=None,
                    help="execution backend for every pooled machine")
    ap.add_argument("--mode", default="analytic",
                    choices=("analytic", "replay"),
                    help="PerfStats metering mode per machine")
    ap.add_argument("--arrival-gap-ns", type=float, default=500.0,
                    help="modeled arrival stagger between users")
    ap.add_argument("--max-steps", type=int, default=None,
                    help="serving-loop step cap (default: run to drain)")
    args = ap.parse_args(argv)

    configs = [c.strip() for c in args.config.split(",") if c.strip()]
    if not configs:
        ap.error("--config needs at least one model-zoo name")
    server = SimdramServer(n_machines=args.machines, n_banks=args.banks,
                           refresh_policy=args.refresh_policy,
                           backend=args.backend, mode=args.mode)
    print(f"serving {args.users} users x {args.steps} tokens over "
          f"{args.machines} machines ({args.banks} banks, "
          f"refresh={args.refresh_policy}, mix={configs})")
    handles = []
    for u in range(args.users):
        handles.append(server.submit_session(
            configs[u % len(configs)], n_tokens=args.steps,
            arrival_ns=u * args.arrival_gap_ns))
    stats = server.run(max_steps=args.max_steps)
    print(stats.report())
    n_done = sum(h.done() for h in handles)
    print(f"{n_done}/{len(handles)} sessions retired")
    return 0 if n_done == len(handles) else 1


if __name__ == "__main__":
    raise SystemExit(main())
