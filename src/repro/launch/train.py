"""End-to-end training driver.

Wires configs → mesh → sharded state → data pipeline → fault-tolerant loop
(checkpoint/restart, straggler monitor, optional majority-vote compressed
DP).  On one host it drives the local device mesh; on a cluster the same
code runs per-process under ``jax.distributed`` (the data pipeline already
slices per host).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 200 --batch 8 --seq 256 [--compressed]
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import PartitionSpec as PS

from ..configs import get_config, get_reduced
from ..distributed.checkpoint import CheckpointManager
from ..distributed.compat import shard_map_compat
from ..distributed.failover import FailoverConfig, FailoverRunner
from ..distributed.sharding import replicated, tree_shardings
from ..models.params import init_params
from ..models.transformer import model_defs
from ..train.data import DataConfig, synthetic_batch
from ..train.optimizer import AdamWConfig, AdamWState
from ..train.train_step import (TrainState, init_train_state,
                                make_compressed_train_step, make_train_step)


def build_mesh(n_model: int | None = None):
    n_dev = len(jax.devices())
    n_model = n_model or (2 if n_dev % 2 == 0 and n_dev > 1 else 1)
    n_data = n_dev // n_model
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def setup(cfg, mesh, opt_cfg: AdamWConfig, compressed: bool = False,
          microbatches: int = 1, seed: int = 0, exchange: str = "packed"):
    defs = model_defs(cfg)
    shardings = tree_shardings(defs, mesh)
    params = init_params(defs, jax.random.key(seed))
    params = jax.tree.map(jax.device_put, params, shardings)
    state = init_train_state(params, compressed=compressed)
    st_shard = TrainState(
        params=shardings,
        opt=AdamWState(step=replicated(mesh), m=shardings, v=shardings),
        error_fb=shardings if compressed else None)
    if compressed:
        step_inner, data_axes = make_compressed_train_step(
            cfg, opt_cfg, mesh, exchange=exchange)
        # manual over the data axes (explicit packed-sign collectives); the
        # model axis stays auto where the jax version supports partial-manual
        # (shard_map_compat replicates it on legacy jax)
        pspec = PS()
        bspec = PS(data_axes if len(data_axes) > 1 else data_axes[0])
        step = shard_map_compat(
            step_inner, mesh=mesh, axis_names=set(data_axes),
            in_specs=(jax.tree.map(lambda _: pspec, state),
                      {"tokens": bspec, "labels": bspec}),
            out_specs=(jax.tree.map(lambda _: pspec, state),
                       {"loss": PS(), "aux": PS(), "grad_norm": PS(),
                        "lr": PS()}),
            check_vma=False)
        step = jax.jit(step, donate_argnums=(0,))
    else:
        step = jax.jit(make_train_step(cfg, opt_cfg,
                                       microbatches=microbatches),
                       in_shardings=(st_shard, None),
                       donate_argnums=(0,))
    return state, st_shard, step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compressed", action="store_true",
                    help="majority-vote 1-bit gradient all-reduce")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = build_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(10, args.steps // 20))
    state, st_shard, step = setup(cfg, mesh, opt_cfg,
                                  compressed=args.compressed,
                                  microbatches=args.microbatches)
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"compressed={args.compressed}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)

    def batch_fn(s):
        return synthetic_batch(dcfg, s)

    ckpt = CheckpointManager(args.ckpt_dir)
    runner = FailoverRunner(step, ckpt,
                            FailoverConfig(checkpoint_every=args.ckpt_every))
    start = ckpt.latest_step() or 0
    if start:
        state = ckpt.restore(start, state, None)
        print(f"resumed from checkpoint step {start}")

    t0 = time.time()
    losses = []
    cur = state
    for s in range(start, args.steps):
        cur, metrics = step(cur, batch_fn(s))
        if s % args.log_every == 0 or s == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {s:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if (s + 1) % args.ckpt_every == 0:
            ckpt.save(s + 1, cur, mesh)
    ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (initial {losses[0]:.4f})")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
