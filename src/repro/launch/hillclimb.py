"""Perf hillclimb on the three selected cells (EXPERIMENTS.md §Perf).

Cells (chosen from the baseline roofline table):
  A. qwen1.5-0.5b × train_4k   — memory-dominant with the worst
     memory/compute imbalance among dense trains; also the carrier for the
     paper-technique-derived collective optimization (majority-vote DP).
  B. olmoe-1b-7b  × train_4k   — the most collective-bound train cell
     (MoE gradient all-reduces).
  C. mamba2-130m  × train_4k   — worst useful-FLOPs roofline fraction
     (SSD scan overheads).

Each variant is a (hypothesis, config change); we re-lower on the production
single-pod mesh, re-extract the three roofline terms and record
before→after.  Run AFTER the baseline sweep:

    PYTHONPATH=src python -m repro.launch.hillclimb --out results/hillclimb.json
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import dataclasses
import json

import jax

from ..configs import get_config
from ..distributed.compat import cost_analysis_dict, shard_map_compat
from .dryrun import lower_cell

CELLS = {
    "A-qwen0.5b-train": ("qwen1.5-0.5b", "train_4k"),
    "B-olmoe-train": ("olmoe-1b-7b", "train_4k"),
    "C-mamba2-train": ("mamba2-130m", "train_4k"),
}

# hypothesis → config override (None value = baseline)
VARIANTS = {
    "A-qwen0.5b-train": [
        ("baseline (paper-faithful: f32 params, full logits, remat=full)",
         {}),
        ("H1: chunked-vocab loss removes the (tokens×vocab) f32 logits "
         "materialization → memory term drops",
         {"loss_chunk": 512}),
        ("H2: bf16 parameter storage halves param-read bytes on every "
         "layer (moments stay f32) → memory term drops further",
         {"loss_chunk": 512, "param_dtype": "bfloat16"}),
        ("H3: remat=none trades memory capacity for bandwidth: no forward "
         "recompute in backward → fewer bytes+flops IF activations fit",
         {"loss_chunk": 512, "param_dtype": "bfloat16", "remat": "none"}),
        ("H4 (beyond-paper, paper-derived): majority-vote 1-bit gradient "
         "all-reduce — pack gradient signs 32×, all-gather, bit-plane "
         "majority (SIMDRAM's TRA lifted to the collective layer), "
         "per-leaf exchanges",
         {"loss_chunk": 512, "param_dtype": "bfloat16",
          "_compressed": True, "_fused": False}),
        ("H5: same majority-vote exchange FUSED into one flat packed "
         "all-gather (kills H4's per-leaf collective latency)",
         {"loss_chunk": 512, "param_dtype": "bfloat16",
          "_compressed": True, "_fused": True}),
        ("H6a control: pure-DP 256x1 mesh, plain f32 all-reduce (the setting "
         "sign-compression actually targets)",
         {"loss_chunk": 512, "_dp_only": True}),
        ("H6b: pure-DP 256x1 mesh + fused majority-vote sign exchange → "
         "collective bytes drop vs H6a",
         {"loss_chunk": 512, "_compressed": True, "_fused": True,
          "_dp_only": True}),
        ("H7: two-phase majority exchange (all-to-all slice → local vote → "
         "all-gather result): per-device bytes independent of voter count — "
         "the scalable form of the paper-derived majority collective",
         {"loss_chunk": 512, "_compressed": True, "_fused": True,
          "_dp_only": True, "_two_phase": True}),
    ],
    "B-olmoe-train": [
        ("baseline", {}),
        ("H1: chunked-vocab loss (same reasoning as cell A)",
         {"loss_chunk": 512}),
        ("H2: bf16 params halve both param reads AND the gradient "
         "all-reduce payload → memory and collective terms drop",
         {"loss_chunk": 512, "param_dtype": "bfloat16"}),
        ("H3: MoE capacity factor 1.25→1.0 cuts dispatch/expert compute "
         "~20% at equal quality envelope",
         {"loss_chunk": 512, "param_dtype": "bfloat16",
          "capacity_factor": 1.0}),
    ],
    "C-mamba2-train": [
        ("baseline", {}),
        ("H1: SSD einsum operands in bf16 (f32 accumulation) halves the "
         "dominant intra-chunk G-matrix traffic",
         {"ssd_f32": False}),
        ("H2: smaller SSD chunk (64→32) quarters the Q² intra-chunk work "
         "per chunk while doubling chunk count → net ~2x less quadratic "
         "compute/bytes",
         {"ssd_f32": False, "ssm_chunk": 32}),
        ("H3: chunked-vocab loss (50k vocab × 1M tokens logits)",
         {"ssd_f32": False, "ssm_chunk": 32, "loss_chunk": 512}),
    ],
}


def lower_compressed_cell(arch: str, shape_name: str, cfg,
                          fused: bool = True, dp_only: bool = False,
                          two_phase: bool = False) -> dict:
    """Lower the majority-vote compressed-DP train step on the production
    mesh and extract the same statistics as `lower_cell` (abstractly — no
    parameter allocation on the 512 host devices)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS

    from ..configs.base import SHAPES
    from ..distributed.sharding import batch_shardings
    from ..train.optimizer import AdamWConfig
    from ..train.train_step import TrainState, make_compressed_train_step
    from .dryrun import (abstract_state, collective_bytes, input_specs,
                         roofline_terms, scan_corrected, state_shardings)
    from .mesh import make_production_mesh

    shape = SHAPES[shape_name]
    mesh = (jax.make_mesh((256, 1), ("data", "model")) if dp_only
            else make_production_mesh(multi_pod=False))
    state_sds, defs = abstract_state(cfg)
    # compressed DP needs the error-feedback buffer
    state_sds = TrainState(params=state_sds.params, opt=state_sds.opt,
                           error_fb=jax.tree.map(
                               lambda s: jax.ShapeDtypeStruct(
                                   s.shape, jnp.float32), state_sds.params))
    specs = input_specs(cfg, shape)
    step_inner, data_axes = make_compressed_train_step(
        cfg, AdamWConfig(), mesh, fused=fused, two_phase=two_phase)
    bspec = PS(data_axes if len(data_axes) > 1 else data_axes[0])
    # manual over data axes; 'model' stays auto (TP preserved) only on
    # modern jax — shard_map_compat replicates it on jax 0.4.x
    stepped = shard_map_compat(
        step_inner, mesh=mesh, axis_names=set(data_axes),
        in_specs=(jax.tree.map(lambda _: PS(), state_sds),
                  jax.tree.map(lambda _: bspec, specs)),
        out_specs=(jax.tree.map(lambda _: PS(), state_sds),
                   {"loss": PS(), "aux": PS(), "grad_norm": PS(),
                    "lr": PS()}),
        check_vma=False)
    st_shard = state_shardings(defs, mesh)
    st_shard = TrainState(params=st_shard.params, opt=st_shard.opt,
                          error_fb=st_shard.params)
    lowered = jax.jit(stepped, donate_argnums=(0,),
                      in_shardings=(st_shard, batch_shardings(mesh, specs))
                      ).lower(state_sds, specs)
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    stats = {"arch": arch, "shape": shape_name,
             "mesh": "256x1-dp" if dp_only else "16x16",
             "n_devices": mesh.devices.size, "skipped": False,
             "flops_per_device": ca.get("flops", 0.0),
             "bytes_per_device": ca.get("bytes accessed", 0.0)}
    stats["collectives"] = collective_bytes(compiled.as_text())
    if cfg.scan_layers:
        stats.update(scan_corrected(cfg, shape, arch, shape_name, stats,
                                    mesh.devices.size))
    stats.update(roofline_terms(cfg, shape, stats, mesh.devices.size))
    return stats


def lower_dp_baseline(arch: str, shape_name: str, cfg) -> dict:
    """Plain pjit train step on a pure-DP 256×1 mesh (compression control)."""
    from ..configs.base import SHAPES
    from ..distributed.sharding import batch_shardings
    from ..train.optimizer import AdamWConfig
    from ..train.train_step import make_train_step
    from .dryrun import (abstract_state, collective_bytes, input_specs,
                         roofline_terms, scan_corrected, state_shardings)
    shape = SHAPES[shape_name]
    mesh = jax.make_mesh((256, 1), ("data", "model"))
    state_sds, defs = abstract_state(cfg)
    specs = input_specs(cfg, shape)
    step = make_train_step(cfg, AdamWConfig(), loss_chunk=cfg.loss_chunk)
    lowered = jax.jit(step,
                      in_shardings=(state_shardings(defs, mesh),
                                    batch_shardings(mesh, specs)),
                      donate_argnums=(0,)).lower(state_sds, specs)
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    stats = {"arch": arch, "shape": shape_name, "mesh": "256x1-dp",
             "n_devices": 256, "skipped": False,
             "flops_per_device": ca.get("flops", 0.0),
             "bytes_per_device": ca.get("bytes accessed", 0.0)}
    stats["collectives"] = collective_bytes(compiled.as_text())
    if cfg.scan_layers:
        stats.update(scan_corrected(cfg, shape, arch, shape_name, stats, 256))
    stats.update(roofline_terms(cfg, shape, stats, 256))
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.json")
    ap.add_argument("--cell", default=None)
    args = ap.parse_args(argv)
    results = []
    for cell, (arch, shape) in CELLS.items():
        if args.cell and args.cell != cell:
            continue
        base_cfg = get_config(arch)
        for hyp, overrides in VARIANTS[cell]:
            ov = dict(overrides)
            compressed = ov.pop("_compressed", False)
            fused = ov.pop("_fused", True)
            dp_only = ov.pop("_dp_only", False)
            two_phase = ov.pop("_two_phase", False)
            cfg = dataclasses.replace(base_cfg, **ov)
            try:
                if compressed:
                    r = lower_compressed_cell(arch, shape, cfg, fused=fused,
                                              dp_only=dp_only,
                                              two_phase=two_phase)
                elif dp_only:
                    r = lower_dp_baseline(arch, shape, cfg)
                else:
                    r = lower_cell(arch, shape, multi_pod=False,
                                   cfg_override=cfg)
            except Exception as e:  # noqa: BLE001
                r = {"error": f"{type(e).__name__}: {e}"}
            r.update({"cell": cell, "hypothesis": hyp,
                      "overrides": overrides})
            results.append(r)
            print(json.dumps({k: v for k, v in r.items()
                              if k not in ("collectives", "memory")}),
                  flush=True)
            jax.clear_caches()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
