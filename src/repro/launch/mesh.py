"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — required for the smoke tests, which must
see one CPU device, while the dry-run forces 512 host devices *before* any
jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the pod axis is the
    slowest (DCN-connected) dimension."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for subprocess distributed tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
