"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and extract memory / FLOP / collective statistics for the roofline.

MUST be imported/run before any other jax usage in the process: the first
two lines force 512 host platform devices so ``jax.make_mesh`` can build the
production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k [--multi-pod] [--all] [--out results.json]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..configs import ARCHS, get_config
from ..configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from ..distributed.compat import cost_analysis_dict
from ..distributed.sharding import (batch_shardings, cache_shardings,
                                    replicated, tree_shardings)
from ..models.params import abstract_params
from ..models.transformer import init_cache_shapes, model_defs
from ..serve.decode import make_serve_step
from ..train.data import batch_spec
from ..train.optimizer import AdamWConfig
from ..train.train_step import TrainState, make_train_step
from .mesh import make_production_mesh

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (≈3 links usable per axis hop)

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


# ---------------------------------------------------------------------------
# input_specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return batch_spec(cfg, shape)
    if shape.kind == "prefill":
        spec = batch_spec(cfg, shape)
        spec.pop("labels")
        return spec
    # decode: one new token against a seq_len cache
    spec = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.rope == "mrope":
        spec["mrope_positions"] = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32)
    if cfg.enc_dec:
        spec["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return spec


def abstract_state(cfg: ModelConfig):
    """TrainState ShapeDtypeStructs without allocating anything."""
    defs = model_defs(cfg)
    pdt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    p = abstract_params(defs, dtype=pdt)
    # optimizer moments stay f32 regardless of the parameter dtype
    f32_like = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    from ..train.optimizer import AdamWState
    opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                     m=f32_like(p), v=f32_like(p))
    return TrainState(params=p, opt=opt, error_fb=None), defs


def state_shardings(defs, mesh):
    ps = tree_shardings(defs, mesh)
    from ..train.optimizer import AdamWState
    return TrainState(params=ps,
                      opt=AdamWState(step=replicated(mesh), m=ps, v=ps),
                      error_fb=None)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives: sum of result-shape bytes of
    every collective instruction in the optimized module."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    n = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.-]+ = (.*?) (\S+)\(", stripped)
        if not m:
            continue
        result_shape, opname = m.groups()
        for op in COLLECTIVE_OPS:
            if opname.startswith(op):
                out[op] += _shape_bytes(result_shape)
                n[op] += 1
    return {"bytes": out, "counts": n,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Scan-trip-count correction probe
# ---------------------------------------------------------------------------
# XLA's cost analysis counts a while-loop (lax.scan) body ONCE regardless of
# trip count, so scanned-layer models under-report FLOPs/bytes by ~n_layers×.
# The probe lowers the same step with python-unrolled 1- and 2-deep stacks at
# a small batch on a 1-device mesh; the difference is the exact per-layer
# cost, scaled linearly by token count (valid because every per-layer term is
# linear in batch at fixed sequence length).

import dataclasses as _dc
import functools as _ft


@_ft.lru_cache(maxsize=None)
def _probe_layer_cost(cfg: ModelConfig,
                      shape_name: str) -> tuple[float, float, int]:
    """(flops, bytes) added per extra layer (global, probe-batch), and the
    probe batch size."""
    shape = SHAPES[shape_name]
    probe_batch = 2 if shape.kind != "decode" else 2
    pshape = _dc.replace(shape, global_batch=probe_batch)
    base = 6 if cfg.family == "hybrid" else 1   # keep the shared-attn cadence
    costs = {}
    for mult in (1, 2):
        n = base * mult
        kw = dict(n_layers=n, scan_layers=False)
        if cfg.layer_pattern:
            kw["layer_pattern"] = ("ssm",) * n
        if cfg.enc_dec:
            kw["n_encoder_layers"] = n
        pcfg = _dc.replace(cfg, **kw)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        stats = _lower_with(pcfg, cfg.name, pshape, mesh, shape_name)
        costs[mult] = (stats["flops_per_device"], stats["bytes_per_device"])
    d_flops = (costs[2][0] - costs[1][0]) / base
    d_bytes = (costs[2][1] - costs[1][1]) / base
    return d_flops, d_bytes, probe_batch


def scan_corrected(cfg: ModelConfig, shape: ShapeConfig, arch: str,
                   shape_name: str, stats: dict, n_dev: int) -> dict:
    try:
        d_flops, d_bytes, probe_batch = _probe_layer_cost(cfg, shape_name)
    except Exception as e:  # noqa: BLE001 — correction is best-effort
        return {"scan_correction_error": f"{type(e).__name__}: {e}"}
    scale = shape.global_batch / probe_batch
    extra_layers = cfg.n_layers - 1
    if cfg.enc_dec:
        extra_layers += cfg.n_encoder_layers - 1
    add_flops = extra_layers * d_flops * scale / n_dev
    add_bytes = extra_layers * d_bytes * scale / n_dev
    return {
        "flops_per_device_corrected": stats["flops_per_device"] + add_flops,
        "bytes_per_device_corrected": stats["bytes_per_device"] + add_bytes,
        "probe_layer_flops": d_flops * scale,
    }


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _lower_with(cfg, arch: str, shape, mesh, shape_name: str) -> dict:
    """Shared lowering used by both real cells and probes."""
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        state_sds, defs = abstract_state(cfg)
        st_shard = state_shardings(defs, mesh)
        b_shard = batch_shardings(mesh, specs)
        step = make_train_step(cfg, AdamWConfig(),
                               loss_chunk=cfg.loss_chunk)
        lowered = jax.jit(step, in_shardings=(st_shard, b_shard),
                          donate_argnums=(0,)).lower(state_sds, specs)
    elif shape.kind == "prefill":
        defs = model_defs(cfg)
        p_sds = abstract_params(defs)
        from ..models.transformer import forward

        def prefill_step(params, batch):
            return forward(params, cfg, batch)[0]

        lowered = jax.jit(prefill_step,
                          in_shardings=(tree_shardings(defs, mesh),
                                        batch_shardings(mesh, specs))
                          ).lower(p_sds, specs)
    else:
        defs = model_defs(cfg)
        p_sds = abstract_params(defs)
        cache_sds = init_cache_shapes(cfg, shape.global_batch, shape.seq_len)
        step = make_serve_step(cfg)
        lowered = jax.jit(step,
                          in_shardings=(tree_shardings(defs, mesh),
                                        cache_shardings(mesh, cache_sds),
                                        batch_shardings(mesh, specs)),
                          donate_argnums=(1,)
                          ).lower(p_sds, cache_sds, specs)
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    return {"compiled": compiled,
            "flops_per_device": ca.get("flops", 0.0),
            "bytes_per_device": ca.get("bytes accessed", 0.0)}


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               compile_: bool = True, cfg_override=None):
    """Lower (and compile) one (arch × shape × mesh) cell; returns stats."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long-context needs sub-quadratic mixer "
                          "(full-attention arch; see DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    stats = {"arch": arch, "shape": shape_name,
             "mesh": "2x16x16" if multi_pod else "16x16",
             "n_devices": mesh.devices.size, "skipped": False}
    if not compile_:
        input_specs(cfg, shape)
        stats["lower_s"] = round(time.time() - t0, 1)
        return stats
    low = _lower_with(cfg, arch, shape, mesh, shape_name)
    compiled = low.pop("compiled")
    stats.update(low)
    stats["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    if mem is not None:
        stats["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    stats["collectives"] = collective_bytes(compiled.as_text())
    if cfg.scan_layers:
        stats.update(scan_corrected(cfg, shape, arch, shape_name, stats,
                                    mesh.devices.size))
    stats.update(roofline_terms(cfg, shape, stats, mesh.devices.size))
    return stats


def roofline_terms(cfg: ModelConfig, shape: ShapeConfig, stats: dict,
                   n_dev: int) -> dict:
    flops = (stats.get("flops_per_device_corrected")
             or stats.get("flops_per_device") or 0.0)
    byts = (stats.get("bytes_per_device_corrected")
            or stats.get("bytes_per_device") or 0.0)
    coll = stats.get("collectives", {}).get("total_bytes", 0)
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    tokens = shape.global_batch * shape.seq_len if shape.kind != "decode" \
        else shape.global_batch
    n_active = cfg.active_param_count()
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_total = flops * n_dev
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_total) if hlo_total else None,
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def iter_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args(argv)

    cells = (list(iter_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if (args.both_meshes or args.multi_pod is False
                               and args.all) else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                r = lower_cell(arch, shape, multi_pod=mp,
                               compile_=not args.no_compile)
            except Exception as e:  # noqa: BLE001 — cell result records error
                r = {"arch": arch, "shape": shape,
                     "mesh": "2x16x16" if mp else "16x16",
                     "error": f"{type(e).__name__}: {e}"}
            results.append(r)
            print(json.dumps(r), flush=True)
            if not r.get("skipped") and "error" not in r:
                print(f"  [{r['arch']} × {r['shape']} × {r['mesh']}] "
                      f"compile={r.get('compile_s')}s "
                      f"dominant={r.get('dominant')}", file=sys.stderr)
            jax.clear_caches()
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if "error" in r]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
