"""Serving: prefill + batched single-token decode with sharded KV caches."""
