"""Serving: prefill + batched single-token decode with sharded KV caches,
and the continuous-batching :class:`SimdramServer` over bank-sharded
machine pools (:mod:`repro.serve.server`)."""
from .batching import (ContinuousBatcher, DecodeSession, RequestProfile,
                       percentile, profile_for)
from .server import ServingStats, SessionHandle, SimdramServer

__all__ = ["ContinuousBatcher", "DecodeSession", "RequestProfile",
           "percentile", "profile_for", "ServingStats", "SessionHandle",
           "SimdramServer"]
