"""Continuous-batching primitives for the SIMDRAM serving layer.

A decode session is a stream of dependent single-token steps: each step
issues one bit-serial μProgram whose output feeds the next step's input
(the recurrence that makes decode latency-bound).  Continuous batching —
the vLLM-style serving discipline — packs the *independent* sessions'
current steps together instead: at every step boundary compatible
sessions stack along the bank axis into one bank-parallel request,
finished sessions retire, and newly arrived sessions join, so the rank
is never idle waiting for the slowest sequence.

This module holds the machine-local half of that discipline:

* :func:`profile_for` — maps a model-zoo config to a
  :class:`RequestProfile` (which μProgram a session issues per token, at
  which width and lane count), so the zoo supplies request-mix diversity
  without hauling full model graphs through the scheduler.
* :class:`DecodeSession` — one admitted request: its operand state (the
  value recurrence), progress, and modeled per-token timing.
* :class:`ContinuousBatcher` — drives ONE
  :class:`~repro.simdram.machine.SimdramMachine` step by step: submit
  every active session's token op (per-session tenant + priority),
  ``drain(batch=True)`` so compatible sessions ride one banked dispatch,
  advance the modeled clock by the step makespan, retire finished
  sessions.
* :func:`percentile` — the deterministic linear-interpolation percentile
  the SLO metrics use (golden-tested; no numpy dependency surprises).

Sharding sessions across a *pool* of machines and the request-loop
surface live in :mod:`repro.serve.server`.  All timing here is modeled
nanoseconds on each machine's rank clock — never wall clock — so serving
metrics are exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..configs import get_reduced

__all__ = ["RequestProfile", "profile_for", "DecodeSession",
           "ContinuousBatcher", "percentile"]


def percentile(values, q: float) -> float:
    """The q-th percentile of ``values`` by linear interpolation between
    closest ranks (the numpy default), implemented deterministically for
    the serving SLO metrics: ``percentile([1..100], 50) == 50.5``."""
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile of an empty sequence")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    pos = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclasses.dataclass(frozen=True)
class RequestProfile:
    """Per-token work one decode session issues, derived from a model-zoo
    config: each decode step of ``config`` is represented by one ``op``
    μProgram at ``n_bits`` over ``lanes`` SIMD lanes."""
    config: str
    family: str
    op: str
    n_bits: int
    lanes: int

    @property
    def batch_key(self) -> tuple:
        """Sessions with equal keys are bank-compatible: same trace and
        operand shape, so their steps stack into one banked dispatch."""
        return (self.op, self.n_bits, self.lanes)


# family → the μProgram standing in for one decode step of that family;
# a fixed map keeps the request mix deterministic per config
_FAMILY_OPS = {
    "dense": "addition",
    "moe": "maximum",
    "ssm": "multiplication",
    "audio": "subtraction",
    "vlm": "greater",
    "hybrid": "minimum",
}


def profile_for(config: str, n_bits: int = 8) -> RequestProfile:
    """The :class:`RequestProfile` of a model-zoo config (reduced size):
    op by model family, lane count from the reduced ``d_model`` (rounded
    up to a 32-lane granule, clamped to [32, 128])."""
    cfg = get_reduced(config)
    op = _FAMILY_OPS.get(cfg.family, "addition")
    lanes = min(128, max(32, ((cfg.d_model + 31) // 32) * 32))
    return RequestProfile(config=config, family=cfg.family, op=op,
                          n_bits=n_bits, lanes=lanes)


class DecodeSession:
    """One admitted decode request: ``n_tokens`` dependent steps of the
    session's :class:`RequestProfile`, with the op output feeding the
    next step's first operand (the decode recurrence).

    All clocks are modeled ns on the serving machine's rank clock:
    ``arrival_ns`` is stamped at submission, ``first_token_ns`` /
    ``finish_ns`` are absolute completion times, and ``token_ns`` holds
    each token's latency (arrival→finish for the first token, step
    issue→finish for steady-state tokens).
    """

    def __init__(self, sid: int, profile: RequestProfile, n_tokens: int,
                 arrival_ns: float = 0.0, priority: int = 0,
                 seed: int | None = None) -> None:
        if n_tokens < 1:
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        self.sid = sid
        self.tenant = f"s{sid}"
        self.profile = profile
        self.n_tokens = int(n_tokens)
        self.arrival_ns = float(arrival_ns)
        self.priority = int(priority)
        rng = np.random.default_rng(sid if seed is None else seed)
        hi = 1 << profile.n_bits
        self.a = rng.integers(0, hi, profile.lanes, dtype=np.int64)
        self.b = rng.integers(1, hi, profile.lanes, dtype=np.int64)
        self.tokens_done = 0
        self.first_token_ns: float | None = None
        self.finish_ns: float | None = None
        self.token_ns: list[float] = []
        self.queue_ns = 0.0         # summed per-token queue time
        self.machine_index: int | None = None

    def __repr__(self) -> str:
        return (f"<DecodeSession {self.tenant} {self.profile.config} "
                f"{self.tokens_done}/{self.n_tokens} tokens>")

    @property
    def done(self) -> bool:
        return self.tokens_done >= self.n_tokens

    def advance(self, value, timing, step_start_ns: float) -> None:
        """Record one completed token: fold the op output back into the
        recurrence and stamp the token's modeled latency from its
        :class:`~repro.simdram.scheduler.RequestTiming` (relative to the
        step's start on the machine clock)."""
        self.a = np.asarray(value, dtype=np.int64) & \
            ((1 << self.profile.n_bits) - 1)
        finish_abs = step_start_ns + timing.finish_ns
        if self.first_token_ns is None:
            self.first_token_ns = finish_abs
            # TTFT token: latency measured from the session's arrival
            self.token_ns.append(finish_abs - self.arrival_ns)
        else:
            self.token_ns.append(timing.finish_ns)
        self.queue_ns += timing.queue_ns
        self.tokens_done += 1
        if self.done:
            self.finish_ns = finish_abs

    @property
    def ttft_ns(self) -> float | None:
        """Time-to-first-token: arrival → first token complete."""
        if self.first_token_ns is None:
            return None
        return self.first_token_ns - self.arrival_ns


class ContinuousBatcher:
    """Step-boundary continuous batching over ONE machine.

    Each :meth:`step` submits every active session's current token op to
    the machine (per-session tenant for PerfStats isolation, the
    session's priority as its latency class) and drains with
    ``batch=True``: compatible sessions (equal
    :attr:`RequestProfile.batch_key`) stack along the bank axis into one
    scheduler request + one vmapped dispatch; incompatible ones still
    pack the same rank under FR-FCFS.  The modeled clock advances by the
    step's makespan, finished sessions retire, and the server admits new
    arrivals before the next step — never mid-step, matching the
    continuous-batching discipline.
    """

    def __init__(self, machine, n_banks: int | None = None,
                 refresh_policy: str = "aware") -> None:
        self.machine = machine
        self.n_banks = n_banks if n_banks is not None \
            else (machine.banks if machine.banks > 1
                  else machine.timing.banks_per_chip)
        self.refresh_policy = refresh_policy
        self.active: list[DecodeSession] = []
        self.clock_ns = 0.0          # this machine's modeled serving clock
        self.steps = 0
        self.tokens = 0

    def __repr__(self) -> str:
        return (f"<ContinuousBatcher machine={self.machine!r} "
                f"active={len(self.active)} clock={self.clock_ns:.0f}ns>")

    def admit(self, session: DecodeSession) -> None:
        """Join a session at the next step boundary.  An idle machine
        fast-forwards its clock to the session's arrival; on a busy one
        the caller admits only once the clock has reached the arrival
        (the server's admission rule), so a session never issues work
        before it exists."""
        if not self.active:
            self.clock_ns = max(self.clock_ns, session.arrival_ns)
        self.active.append(session)

    def step(self) -> list[DecodeSession]:
        """Run one decode step for every active session; returns the
        sessions that finished (already retired from :attr:`active`)."""
        if not self.active:
            return []
        step_start = self.clock_ns
        futs = []
        for s in self.active:
            fut = self.machine.submit(
                s.profile.op, s.a, s.b, n_bits=s.profile.n_bits,
                tenant=s.tenant, priority=s.priority,
                arrival_ns=max(0.0, s.arrival_ns - step_start))
            futs.append((s, fut))
        res = self.machine.drain(n_banks=self.n_banks,
                                 refresh_policy=self.refresh_policy,
                                 batch=True)
        self.clock_ns = step_start + res.ns
        self.steps += 1
        finished = []
        for s, fut in futs:
            s.advance(fut.result(), fut.timing, step_start)
            self.tokens += 1
            if s.done:
                finished.append(s)
        self.active = [s for s in self.active if not s.done]
        return finished
