"""Serving steps: prefill (cache-populating) and batched one-token decode.

``make_serve_step`` builds the function that the decode dry-run cells lower:
one new token per sequence against a KV cache of ``max_seq`` (the assigned
``decode_32k`` / ``long_500k`` shapes).

``simdram_greedy_token`` is the PuM-offloaded sampler: per-sequence logits
are quantized and the greedy token is selected by a bank-batched SIMDRAM
max tournament — each sequence's logits occupy one DRAM bank (the paper's
16-bank scaling), the whole batch votes in parallel, and every comparison
is a ``bbop_greater``/``bbop_if_else`` pair executing on the selected
backend with zero per-op layout conversion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.transformer import forward, init_cache_shapes
from ..ops.bbops import (PerfStats, bbop_greater, bbop_if_else,
                         simdram_pipeline)


def make_prefill(cfg: ModelConfig):
    """Multi-token forward that also populates the decode caches."""
    def prefill(params, batch, caches):
        logits, _, new_caches = forward(params, cfg, batch, caches)
        return logits[:, -1:], new_caches
    return prefill


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, caches, tokens (B,1)) → (logits, caches)."""
    def serve_step(params, caches, batch):
        logits, _, new_caches = forward(params, cfg, batch, caches)
        return logits, new_caches
    return serve_step


# ---------------------------------------------------------------------------
# PuM-offloaded greedy sampling (bank-batched SIMDRAM argmax)
# ---------------------------------------------------------------------------

_MIN_LANES = 32          # one packed word — the tournament floor


def simdram_argmax(values: jax.Array, n_bits: int = 8,
                   backend: str | None = None,
                   perf_stats: PerfStats | None = None,
                   machine=None) -> jax.Array:
    """Row-wise argmax of unsigned ``values (B, V)`` via a plane-resident
    max tournament, one bank per row.

    Values and winner indices are loaded vertical up front (one
    transposition pass each — they differ in width, so they cannot share a
    pass); each round splits the lane axis in half (free row/lane
    re-indexing) and keeps the winners with one ``bbop_greater`` + two
    ``bbop_if_else`` — all banks in parallel, zero per-op conversions.
    Below one packed word the same tournament continues SWAR-style: the
    candidates are compared against their lane-shifted selves
    (:meth:`~repro.simdram.layout.BitplaneArray.shift_lanes`, free word
    shifts) at strides 16, 8, 4, 2, 1 until lane 0 holds the winner, so
    only the index planes pay a reverse pass — 3 transposition passes
    total regardless of V or round count, no host reduction epilogue.
    Ties resolve to an arbitrary maximal index.

    ``perf_stats`` runs the tournament under the timed execution layer,
    accumulating modeled DRAM cost (latency, energy, transposition) into
    the given :class:`~repro.core.backends.PerfStats` — pass one
    accumulator across calls to meter a whole decode loop.  ``machine``
    binds the tournament to a :class:`~repro.simdram.machine.SimdramMachine`
    session: its backend, its μProgram Memory, and (absent an explicit
    ``perf_stats``) its own accumulator and DRAM model.
    """
    b, v = values.shape
    lanes = max(_MIN_LANES, 1 << (v - 1).bit_length())
    vals = jnp.pad(values.astype(jnp.uint32), ((0, 0), (0, lanes - v)))
    idx_bits = max(1, (lanes - 1).bit_length())
    idx = jnp.tile(jnp.arange(lanes, dtype=jnp.int32)[None, :], (b, 1))
    with simdram_pipeline(banks=b, backend=backend,
                          perf_stats=perf_stats, machine=machine,
                          timed=machine is not None and perf_stats is None
                          ) as p:
        cur_v = p.load(vals, n_bits)
        cur_i = p.load(idx, idx_bits)
        while cur_v.words > _MIN_LANES // 32:
            lo_v, hi_v = cur_v.split_lanes()
            lo_i, hi_i = cur_i.split_lanes()
            win = bbop_greater(hi_v, lo_v, n_bits)
            cur_v = bbop_if_else(win, hi_v, lo_v, n_bits)
            cur_i = bbop_if_else(win, hi_i, lo_i, idx_bits)
        # SWAR finish within the last packed word: strict greater keeps
        # the lower lane on ties, and the zero-filled shifted-in lanes
        # never beat a live candidate, so lane 0 converges to a maximal
        # index without leaving the vertical layout
        k = _MIN_LANES // 2
        while k:
            sh_v = cur_v.shift_lanes(k)
            sh_i = cur_i.shift_lanes(k)
            win = bbop_greater(sh_v, cur_v, n_bits)
            cur_v = bbop_if_else(win, sh_v, cur_v, n_bits)
            cur_i = bbop_if_else(win, sh_i, cur_i, idx_bits)
            k //= 2
        final_i = cur_i.to_values()              # (B, 32), winner in lane 0
    return final_i[:, 0]


def simdram_greedy_token(logits: jax.Array, n_bits: int = 8,
                         backend: str | None = None,
                         perf_stats: PerfStats | None = None,
                         machine=None) -> jax.Array:
    """Greedy token per sequence, selected in-memory.

    Logits ``(B, V)`` are affinely quantized per row to ``n_bits`` unsigned
    levels (the transposition-unit write format) and ranked by the banked
    SIMDRAM tournament.  Quantization collisions among near-ties may pick a
    token within one quantization bin of the float argmax.  Non-finite
    logits (vocab masking with ``-inf``) map to bin 0 rather than
    poisoning the per-row scale.
    """
    finite = jnp.isfinite(logits)
    lo = jnp.min(jnp.where(finite, logits, jnp.inf), -1, keepdims=True)
    hi = jnp.max(jnp.where(finite, logits, -jnp.inf), -1, keepdims=True)
    scale = (2 ** n_bits - 1) / jnp.maximum(hi - lo, 1e-9)
    q = jnp.round((logits - lo) * scale)
    q = jnp.clip(jnp.where(finite, q, 0), 0, 2 ** n_bits - 1)
    return simdram_argmax(q.astype(jnp.int32), n_bits=n_bits,
                          backend=backend, perf_stats=perf_stats,
                          machine=machine)


def greedy_decode(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
                  max_seq: int | None = None, extra_batch: dict | None = None,
                  sampler: str = "host", sampler_backend: str | None = None,
                  sampler_perf: PerfStats | None = None, machine=None):
    """e2e greedy decoding loop (examples/tests; single host).

    ``sampler="simdram"`` offloads greedy token selection to the
    bank-batched PuM tournament (:func:`simdram_greedy_token`); ``"host"``
    is the plain ``jnp.argmax``.  ``sampler_perf`` accumulates the
    tournament's modeled DRAM cost across every decoded token —
    ``sampler_perf.total_ns / steps`` is the modeled sampling cost per
    token.  ``machine`` binds sampling to a
    :class:`~repro.simdram.machine.SimdramMachine` session (its backend,
    μProgram Memory and — absent ``sampler_perf`` — its own accumulator),
    so concurrent decode services with different DRAM configs stay
    isolated; it is the same kwarg every ``bbop_*``/``simdram_*`` entry
    point takes.
    """
    if sampler == "simdram":
        def pick(logits):
            return simdram_greedy_token(logits, backend=sampler_backend,
                                        perf_stats=sampler_perf,
                                        machine=machine)
    elif sampler == "host":
        def pick(logits):
            return jnp.argmax(logits, -1)
    else:
        raise ValueError(f"unknown sampler {sampler!r}")
    b, s = prompt.shape
    max_seq = max_seq or (s + steps)
    cache_sds = init_cache_shapes(cfg, b, max_seq)
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cache_sds)
    extra = extra_batch or {}
    if cfg.enc_dec and "encoder_frames" in extra:
        from ..models.transformer import prime_encdec_caches
        caches = prime_encdec_caches(params, cfg, extra, caches)
    prefill = make_prefill(cfg)
    step = jax.jit(make_serve_step(cfg))
    batch = {"tokens": prompt, **extra}
    if cfg.rope == "mrope" and "mrope_positions" not in batch:
        batch["mrope_positions"] = jnp.tile(
            jnp.arange(s)[None, :, None], (b, 1, 3))
    logits, caches = jax.jit(prefill)(params, batch, caches)
    out = [pick(logits[:, -1])]
    for t in range(steps - 1):
        db = {"tokens": out[-1][:, None], **extra}
        if cfg.rope == "mrope":
            db["mrope_positions"] = jnp.full((b, 1, 3), s + t, jnp.int32)
        logits, caches = step(params, caches, db)
        out.append(pick(logits[:, -1]))
    return jnp.stack(out, 1)
