"""Serving steps: prefill (cache-populating) and batched one-token decode.

``make_serve_step`` builds the function that the decode dry-run cells lower:
one new token per sequence against a KV cache of ``max_seq`` (the assigned
``decode_32k`` / ``long_500k`` shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.transformer import forward, init_cache_shapes


def make_prefill(cfg: ModelConfig):
    """Multi-token forward that also populates the decode caches."""
    def prefill(params, batch, caches):
        logits, _, new_caches = forward(params, cfg, batch, caches)
        return logits[:, -1:], new_caches
    return prefill


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, caches, tokens (B,1)) → (logits, caches)."""
    def serve_step(params, caches, batch):
        logits, _, new_caches = forward(params, cfg, batch, caches)
        return logits, new_caches
    return serve_step


def greedy_decode(params, cfg: ModelConfig, prompt: jax.Array, steps: int,
                  max_seq: int | None = None, extra_batch: dict | None = None):
    """e2e greedy decoding loop (examples/tests; single host)."""
    b, s = prompt.shape
    max_seq = max_seq or (s + steps)
    cache_sds = init_cache_shapes(cfg, b, max_seq)
    caches = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), cache_sds)
    extra = extra_batch or {}
    if cfg.enc_dec and "encoder_frames" in extra:
        from ..models.transformer import prime_encdec_caches
        caches = prime_encdec_caches(params, cfg, extra, caches)
    prefill = make_prefill(cfg)
    step = jax.jit(make_serve_step(cfg))
    batch = {"tokens": prompt, **extra}
    if cfg.rope == "mrope" and "mrope_positions" not in batch:
        batch["mrope_positions"] = jnp.tile(
            jnp.arange(s)[None, :, None], (b, 1, 3))
    logits, caches = jax.jit(prefill)(params, batch, caches)
    out = [jnp.argmax(logits[:, -1], -1)]
    for t in range(steps - 1):
        db = {"tokens": out[-1][:, None], **extra}
        if cfg.rope == "mrope":
            db["mrope_positions"] = jnp.full((b, 1, 3), s + t, jnp.int32)
        logits, caches = step(params, caches, db)
        out.append(jnp.argmax(logits[:, -1], -1))
    return jnp.stack(out, 1)
