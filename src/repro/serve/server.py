"""``SimdramServer`` — the end-to-end serving layer (ROADMAP item 3).

The SIMDRAM paper evaluates operations; a *system* serves traffic.  This
module turns the repo's stack into one: concurrent decode sessions enter
through a thread-safe (and asyncio-friendly) request surface, shard
across a pool of isolated :class:`~repro.simdram.machine.SimdramMachine`
instances (PR-5 session isolation makes the pool safe in one process),
and each machine's :class:`~repro.serve.batching.ContinuousBatcher`
continuously batches the resident sessions' decode steps into the bank
axis — new arrivals join at step boundaries, finished sequences retire,
and the re-packed steps flow through ``machine.submit()`` / the
:class:`~repro.simdram.scheduler.BankScheduler` under FR-FCFS with the
chosen refresh policy.  Every prior subsystem is on the hot path:
compile/lower caching (μProgram Memory), the vectorized replay engine
and replay memo, the whole-schedule memo, trace lint, and per-tenant
PerfStats attribution.

Timing is *modeled*: each machine keeps a rank-clock in nanoseconds and
every latency below is derived from scheduler
:class:`~repro.simdram.scheduler.RequestTiming`, never wall clock —
serving metrics are bit-exact across runs.  :class:`ServingStats` sits
on top of :meth:`~repro.core.backends.PerfStats.snapshot` and reports
the SLO view: modeled p50/p99 ns-per-token, time-to-first-token
percentiles, and aggregate tokens/s at N concurrent users.

Typical use::

    server = SimdramServer(n_machines=2, n_banks=8)
    handles = [server.submit_session("qwen1_5_0_5b", n_tokens=8)
               for _ in range(8)]
    stats = server.run()            # steps until every session finishes
    print(stats.report())
    final_values = handles[0].result()
"""
from __future__ import annotations

import threading

from ..simdram.machine import SimdramMachine
from .batching import ContinuousBatcher, DecodeSession, percentile, \
    profile_for

__all__ = ["SimdramServer", "ServingStats", "SessionHandle"]


class SessionHandle:
    """Caller-side handle to one submitted decode session.

    ``wait``/``result`` block on a :class:`threading.Event` the serving
    loop sets at retirement; :meth:`wait_async` awaits the same event
    without blocking the event loop.  Timing properties are modeled ns.
    """

    def __init__(self, session: DecodeSession) -> None:
        self._session = session
        self._event = threading.Event()

    def __repr__(self) -> str:
        state = "done" if self.done() else "pending"
        return f"<SessionHandle {self._session.tenant} {state}>"

    @property
    def session(self) -> DecodeSession:
        return self._session

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the session retires (or ``timeout`` seconds of
        wall clock pass — the only wall-clock in this layer, and it never
        feeds a metric).  Returns whether the session is done."""
        return self._event.wait(timeout)

    async def wait_async(self) -> "SessionHandle":
        """Await retirement from an asyncio event loop (the serving loop
        itself may run in a worker thread)."""
        import asyncio
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._event.wait)
        return self

    def result(self, timeout: float | None = None):
        """The session's final operand state (the decode recurrence after
        its last token); raises if the session has not retired in time."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"session {self._session.tenant} still pending")
        return self._session.a

    @property
    def ttft_ns(self) -> float | None:
        return self._session.ttft_ns

    @property
    def finish_ns(self) -> float | None:
        return self._session.finish_ns

    @property
    def token_ns(self) -> tuple[float, ...]:
        return tuple(self._session.token_ns)


class ServingStats:
    """SLO-style rollup of one serving run (modeled ns only).

    Percentiles pool every completed session's per-token latencies;
    ``tokens_per_s`` is aggregate completed tokens over the serving span
    (earliest arrival → latest finish) at the run's concurrency.  The
    per-machine section embeds each machine's
    :meth:`~repro.core.backends.PerfStats.snapshot` and μProgram-Memory
    counters, so the serving view composes with — instead of replacing —
    the existing perf instrumentation.
    """

    def __init__(self, server: "SimdramServer") -> None:
        sessions = list(server.completed)
        self.users = server.peak_concurrency
        self.n_sessions = len(sessions)
        self.total_tokens = sum(s.tokens_done for s in sessions)
        token_ns = [t for s in sessions for t in s.token_ns]
        ttfts = [s.ttft_ns for s in sessions if s.ttft_ns is not None]
        self.p50_token_ns = percentile(token_ns, 50) if token_ns else 0.0
        self.p99_token_ns = percentile(token_ns, 99) if token_ns else 0.0
        self.p50_ttft_ns = percentile(ttfts, 50) if ttfts else 0.0
        self.p99_ttft_ns = percentile(ttfts, 99) if ttfts else 0.0
        arrivals = [s.arrival_ns for s in sessions]
        finishes = [s.finish_ns for s in sessions
                    if s.finish_ns is not None]
        self.span_ns = (max(finishes) - min(arrivals)) \
            if arrivals and finishes else 0.0
        self.tokens_per_s = (self.total_tokens / self.span_ns * 1e9) \
            if self.span_ns > 0 else 0.0
        self.machines = [{
            "clock_ns": b.clock_ns,
            "steps": b.steps,
            "tokens": b.tokens,
            "sessions": sorted(s.tenant for s in sessions
                               if s.machine_index == i),
            "perf": b.machine.stats.snapshot(),
            "cache": b.machine.cache_stats(),
        } for i, b in enumerate(server.batchers)]

    def snapshot(self) -> dict:
        """JSON-safe dict of every serving metric + per-machine detail."""
        return {
            "users": self.users,
            "n_sessions": self.n_sessions,
            "total_tokens": self.total_tokens,
            "p50_token_ns": self.p50_token_ns,
            "p99_token_ns": self.p99_token_ns,
            "p50_ttft_ns": self.p50_ttft_ns,
            "p99_ttft_ns": self.p99_ttft_ns,
            "span_ns": self.span_ns,
            "tokens_per_s": self.tokens_per_s,
            "machines": self.machines,
        }

    def report(self) -> str:
        lines = [
            "SIMDRAM serving stats (modeled)",
            f"  users (peak)        : {self.users}",
            f"  sessions completed  : {self.n_sessions}",
            f"  tokens              : {self.total_tokens}",
            f"  ns/token p50 / p99  : {self.p50_token_ns:,.1f} / "
            f"{self.p99_token_ns:,.1f}",
            f"  TTFT p50 / p99 (ns) : {self.p50_ttft_ns:,.1f} / "
            f"{self.p99_ttft_ns:,.1f}",
            f"  serving span        : {self.span_ns:,.1f} ns",
            f"  throughput          : {self.tokens_per_s:,.1f} tokens/s",
        ]
        for i, m in enumerate(self.machines):
            sched = m["cache"]
            lines.append(
                f"  machine[{i}]          : {m['tokens']} tokens / "
                f"{m['steps']} steps, clock {m['clock_ns']:,.1f} ns, "
                f"schedule memo {sched['schedule_hits']}h/"
                f"{sched['schedule_misses']}m")
        return "\n".join(lines)


class SimdramServer:
    """Continuous-batching decode server over a pool of bank-sharded
    SIMDRAM machines (see the module docstring).

    Parameters
    ----------
    n_machines : pool size; sessions shard to the least-active machine
        at admission (each machine is a fully isolated
        :class:`SimdramMachine`: own μProgram Memory, own PerfStats).
    n_banks : modeled controller width per machine — the continuous
        batch packs up to this many compatible sessions per dispatch.
    refresh_policy : scheduler refresh policy for every step
        (``"aware"`` / ``"stall"`` / ``"defer"``).
    backend / mode / timing : forwarded to each pooled machine.
    """

    def __init__(self, n_machines: int = 2, n_banks: int = 8,
                 refresh_policy: str = "aware",
                 backend: str | None = None, mode: str = "analytic",
                 timing=None, machines=None) -> None:
        if machines is None:
            if n_machines < 1:
                raise ValueError(f"n_machines must be >= 1, "
                                 f"got {n_machines}")
            machines = [SimdramMachine(timing=timing, backend=backend,
                                       mode=mode)
                        for _ in range(n_machines)]
        self.batchers = [ContinuousBatcher(m, n_banks=n_banks,
                                           refresh_policy=refresh_policy)
                         for m in machines]
        self._lock = threading.Lock()
        self._pending: list[tuple[DecodeSession, SessionHandle]] = []
        self._handles: dict[int, SessionHandle] = {}
        self._n_sessions = 0
        self.completed: list[DecodeSession] = []
        self.peak_concurrency = 0

    def __repr__(self) -> str:
        active = sum(len(b.active) for b in self.batchers)
        return (f"SimdramServer(machines={len(self.batchers)}, "
                f"active={active}, pending={len(self._pending)}, "
                f"completed={len(self.completed)})")

    @property
    def machines(self) -> list[SimdramMachine]:
        return [b.machine for b in self.batchers]

    # -- request surface (thread-safe) ---------------------------------------
    def submit_session(self, config: str = "qwen1_5_0_5b",
                       n_tokens: int = 8, arrival_ns: float = 0.0,
                       priority: int = 0, n_bits: int = 8,
                       seed: int | None = None) -> SessionHandle:
        """Admit one decode session (any thread); returns its
        :class:`SessionHandle`.  ``config`` names a model-zoo entry (its
        :func:`~repro.serve.batching.profile_for` profile defines the
        per-token work); ``arrival_ns`` stamps the session's arrival on
        the modeled clock; ``priority`` is its latency class."""
        profile = profile_for(config, n_bits=n_bits)
        with self._lock:
            sid = self._n_sessions
            self._n_sessions += 1
            session = DecodeSession(sid, profile, n_tokens,
                                    arrival_ns=arrival_ns,
                                    priority=priority, seed=seed)
            handle = SessionHandle(session)
            self._pending.append((session, handle))
            self._handles[sid] = handle
        return handle

    # -- the serving loop ----------------------------------------------------
    def _admit(self) -> None:
        """Join pending sessions at a step boundary: least-active machine
        first; an idle machine fast-forwards its clock to the arrival,
        a busy one admits only sessions that have already arrived on its
        modeled clock (future arrivals keep pending until the clock
        catches up)."""
        with self._lock:
            pending = self._pending
            self._pending = []
        still_pending = []
        for session, handle in sorted(
                pending, key=lambda p: (p[0].arrival_ns, p[0].sid)):
            order = sorted(range(len(self.batchers)),
                           key=lambda i: (len(self.batchers[i].active), i))
            placed = False
            for i in order:
                b = self.batchers[i]
                if not b.active or session.arrival_ns <= b.clock_ns:
                    session.machine_index = i
                    b.admit(session)
                    placed = True
                    break
            if not placed:
                still_pending.append((session, handle))
        if still_pending:
            with self._lock:
                self._pending = still_pending + self._pending
        live = sum(len(b.active) for b in self.batchers)
        self.peak_concurrency = max(self.peak_concurrency, live)

    def has_work(self) -> bool:
        with self._lock:
            if self._pending:
                return True
        return any(b.active for b in self.batchers)

    def step(self) -> int:
        """One serving step: admit pending sessions at the boundary, run
        every machine's continuous batch one decode step, retire finished
        sessions (setting their handles).  Returns the number of sessions
        retired this step."""
        self._admit()
        retired = 0
        for b in self.batchers:
            for session in b.step():
                self.completed.append(session)
                handle = self._handles.pop(session.sid, None)
                if handle is not None:
                    handle._event.set()
                retired += 1
        return retired

    def run(self, max_steps: int | None = None) -> ServingStats:
        """Step until every submitted session has retired (or
        ``max_steps``); returns the run's :class:`ServingStats`."""
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.stats()

    async def run_async(self, max_steps: int | None = None) -> ServingStats:
        """Run the serving loop in a worker thread and await completion —
        the asyncio face of :meth:`run` (handles stay awaitable via
        :meth:`SessionHandle.wait_async`)."""
        import asyncio
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(None, lambda: self.run(max_steps))

    def stats(self) -> ServingStats:
        return ServingStats(self)
