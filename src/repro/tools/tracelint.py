"""Sweep every registered operation × bit width through TraceLint.

    PYTHONPATH=src python -m repro.tools.tracelint [--bits 4,8,16,32]
                                                   [--ops add,mul,...]
                                                   [--optimize on|off|both]

Compiles each (operation, n_bits, optimize) key, runs the static verifier
(:mod:`repro.core.tracelint`) on the lowered trace and prints one line per
key; any lint *error* (or a compile failure) fails the sweep with a
non-zero exit.  This is the CI lint gate over the op registry — the same
checks ``compile_trace(..., verify=True)`` applies inline, but exhaustively
and with the full report rendered.
"""
from __future__ import annotations

import argparse
import sys
import time

DEFAULT_BITS = (4, 8, 16, 32)


def sweep(ops: tuple[str, ...], bits: tuple[int, ...],
          optimizes: tuple[bool, ...], verbose: bool = False) -> int:
    """Lint every (op, n_bits, optimize) key; returns the number of keys
    with lint errors or compile failures."""
    from ..core.trace import compile_trace

    failed = 0
    n_warn = 0
    t0 = time.perf_counter()
    for name in ops:
        for n_bits in bits:
            for optimize in optimizes:
                key = f"{name}/{n_bits}b" + ("" if optimize else "/ambit")
                try:
                    # verify=False: collect the full report ourselves
                    # instead of stopping at the first TraceLintError
                    _, trace = compile_trace(name, n_bits, optimize,
                                             verify=False)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"FAIL  {key}: compile error: {e}")
                    failed += 1
                    continue
                report = trace.lint()
                n_warn += len(report.warnings)
                if not report.ok:
                    failed += 1
                    print(f"FAIL  {key}")
                    print("      " + report.render().replace("\n", "\n      "))
                elif report.warnings and verbose:
                    print(f"warn  {key}")
                    print("      " + report.render().replace("\n", "\n      "))
                elif verbose:
                    print(f"ok    {key}  ({trace.cmds.shape[0]} cmds, "
                          f"{trace.n_rows} rows)")
    dt = time.perf_counter() - t0
    n_keys = len(ops) * len(bits) * len(optimizes)
    print(f"tracelint: {n_keys} trace(s) checked in {dt:.1f}s — "
          f"{failed} failing, {n_warn} warning(s)")
    return failed


def main(argv: list[str] | None = None) -> int:
    from ..core.circuits import list_operations

    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.tracelint",
        description="statically verify registered ops' lowered traces")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op names (default: every "
                         "registered operation)")
    ap.add_argument("--bits", default=",".join(map(str, DEFAULT_BITS)),
                    help="comma-separated element widths (default: "
                         "%(default)s)")
    ap.add_argument("--optimize", choices=("on", "off", "both"),
                    default="on",
                    help="MIG optimization: on (default), off (the Ambit "
                         "baseline lowering) or both")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print per-key ok lines and warning reports")
    args = ap.parse_args(argv)

    ops = (tuple(s for s in args.ops.split(",") if s) if args.ops
           else list_operations())
    unknown = set(ops) - set(list_operations())
    if unknown:
        ap.error(f"unknown op(s) {sorted(unknown)}; registered: "
                 f"{list_operations()}")
    bits = tuple(int(b) for b in args.bits.split(",") if b)
    optimizes = {"on": (True,), "off": (False,),
                 "both": (True, False)}[args.optimize]
    return 1 if sweep(ops, bits, optimizes, verbose=args.verbose) else 0


if __name__ == "__main__":
    sys.exit(main())
