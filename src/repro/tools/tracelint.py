"""Sweep every registered operation × bit width through TraceLint.

    PYTHONPATH=src python -m repro.tools.tracelint [--bits 4,8,16,32]
                                                   [--ops add,mul,...]
                                                   [--optimize on|off|both]
                                                   [--chains]

Compiles each (operation, n_bits, optimize) key, runs the static verifier
(:mod:`repro.core.tracelint`) on the lowered trace and prints one line per
key; any lint *error* (or a compile failure) fails the sweep with a
non-zero exit.  This is the CI lint gate over the op registry — the same
checks ``compile_trace(..., verify=True)`` applies inline, but exhaustively
and with the full report rendered.

``--chains`` additionally sweeps a set of representative fused chain
traces (:func:`repro.core.compiler.fuse_chain`) through the same verifier
— including the fused-only cross-op seam checks (``seam-clobber``).
"""
from __future__ import annotations

import argparse
import sys
import time

DEFAULT_BITS = (4, 8, 16, 32)


def sweep(ops: tuple[str, ...], bits: tuple[int, ...],
          optimizes: tuple[bool, ...], verbose: bool = False) -> int:
    """Lint every (op, n_bits, optimize) key; returns the number of keys
    with lint errors or compile failures."""
    from ..core.trace import compile_trace

    failed = 0
    n_warn = 0
    t0 = time.perf_counter()
    for name in ops:
        for n_bits in bits:
            for optimize in optimizes:
                key = f"{name}/{n_bits}b" + ("" if optimize else "/ambit")
                try:
                    # verify=False: collect the full report ourselves
                    # instead of stopping at the first TraceLintError
                    _, trace = compile_trace(name, n_bits, optimize,
                                             verify=False)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"FAIL  {key}: compile error: {e}")
                    failed += 1
                    continue
                report = trace.lint()
                n_warn += len(report.warnings)
                if not report.ok:
                    failed += 1
                    print(f"FAIL  {key}")
                    print("      " + report.render().replace("\n", "\n      "))
                elif report.warnings and verbose:
                    print(f"warn  {key}")
                    print("      " + report.render().replace("\n", "\n      "))
                elif verbose:
                    print(f"ok    {key}  ({trace.cmds.shape[0]} cmds, "
                          f"{trace.n_rows} rows)")
    dt = time.perf_counter() - t0
    n_keys = len(ops) * len(bits) * len(optimizes)
    print(f"tracelint: {n_keys} trace(s) checked in {dt:.1f}s — "
          f"{failed} failing, {n_warn} warning(s)")
    return failed


# representative fused pipelines: linear chains, a diamond (one producer
# feeding two consumers), reductions into arithmetic, and a long 8-op mix
CHAIN_CASES: dict[str, tuple] = {
    "fma": (("addition", ("a", "b"), "t0"),
            ("multiplication", ("t0", "a"), "t1")),
    "fma_relu": (("addition", ("a", "b"), "t0"),
                 ("multiplication", ("t0", "a"), "t1"),
                 ("relu", ("t1",), "t2")),
    "diamond": (("addition", ("a", "b"), "t0"),
                ("relu", ("t0",), "t1"),
                ("abs", ("t0",), "t2"),
                ("subtraction", ("t1", "t2"), "t3")),
    "minmax": (("maximum", ("a", "b"), "hi"),
               ("minimum", ("a", "b"), "lo"),
               ("subtraction", ("hi", "lo"), "range")),
    "xor_acc": (("xor_reduction", ("a", "b", "c"), "t0"),
                ("addition", ("t0", "a"), "t1")),
    "chain8": (("addition", ("a", "b"), "t0"),
               ("multiplication", ("t0", "a"), "t1"),
               ("subtraction", ("t1", "b"), "t2"),
               ("relu", ("t2",), "t3"),
               ("addition", ("t3", "a"), "t4"),
               ("abs", ("t4",), "t5"),
               ("subtraction", ("t5", "b"), "t6"),
               ("relu", ("t6",), "t7")),
}


def sweep_chains(bits: tuple[int, ...], optimizes: tuple[bool, ...],
                 verbose: bool = False) -> int:
    """Lint every representative fused chain × bit width; returns the
    number of keys with lint errors or compile failures."""
    from ..core.compiler import fuse_chain

    failed = 0
    n_warn = 0
    t0 = time.perf_counter()
    for cname, stages in CHAIN_CASES.items():
        for n_bits in bits:
            for optimize in optimizes:
                key = (f"chain:{cname}/{n_bits}b"
                       + ("" if optimize else "/ambit"))
                try:
                    trace = fuse_chain(stages, n_bits, optimize)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"FAIL  {key}: compile error: {e}")
                    failed += 1
                    continue
                report = trace.lint()
                n_warn += len(report.warnings)
                if not report.ok:
                    failed += 1
                    print(f"FAIL  {key}")
                    print("      " + report.render().replace("\n", "\n      "))
                elif verbose:
                    ch = trace.chain
                    print(f"ok    {key}  ({trace.cmds.shape[0]} cmds, "
                          f"{ch.n_stages} stages, "
                          f"{ch.elided_rows} rows elided)")
    dt = time.perf_counter() - t0
    n_keys = len(CHAIN_CASES) * len(bits) * len(optimizes)
    print(f"tracelint --chains: {n_keys} fused trace(s) checked in "
          f"{dt:.1f}s — {failed} failing, {n_warn} warning(s)")
    return failed


def main(argv: list[str] | None = None) -> int:
    from ..core.circuits import list_operations

    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.tracelint",
        description="statically verify registered ops' lowered traces")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op names (default: every "
                         "registered operation)")
    ap.add_argument("--bits", default=",".join(map(str, DEFAULT_BITS)),
                    help="comma-separated element widths (default: "
                         "%(default)s)")
    ap.add_argument("--optimize", choices=("on", "off", "both"),
                    default="on",
                    help="MIG optimization: on (default), off (the Ambit "
                         "baseline lowering) or both")
    ap.add_argument("--chains", action="store_true",
                    help="also lint representative fused chain traces "
                         "(cross-op seam checks)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print per-key ok lines and warning reports")
    args = ap.parse_args(argv)

    ops = (tuple(s for s in args.ops.split(",") if s) if args.ops
           else list_operations())
    unknown = set(ops) - set(list_operations())
    if unknown:
        ap.error(f"unknown op(s) {sorted(unknown)}; registered: "
                 f"{list_operations()}")
    bits = tuple(int(b) for b in args.bits.split(",") if b)
    optimizes = {"on": (True,), "off": (False,),
                 "both": (True, False)}[args.optimize]
    failed = sweep(ops, bits, optimizes, verbose=args.verbose)
    if args.chains:
        failed += sweep_chains(bits, optimizes, verbose=args.verbose)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
