"""Developer-facing command-line tools (``python -m repro.tools.<tool>``)."""
