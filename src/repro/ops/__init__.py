"""Public bbop API — the SIMDRAM ISA surface (paper Table 1) plus the
plane-resident pipeline / backend-selection layer, the session-scoped
:class:`~repro.simdram.machine.SimdramMachine` end-to-end API, and the
bank-level scheduling surface (``machine.submit()`` futures +
:class:`~repro.simdram.scheduler.BankScheduler`)."""
from ..core.backends import (PerfStats, execute_heterogeneous,
                             execute_program, list_backends,
                             register_backend, set_default_backend,
                             timed, use_backend)
from ..core.circuits import list_operations, register_operation
from ..simdram.layout import BitplaneArray
from ..simdram.machine import (SimdramFuture, SimdramMachine,
                               current_machine, default_machine)
from ..simdram.scheduler import BankScheduler, RequestTiming, ScheduleResult
from .bbops import (bbop_abs, bbop_add, bbop_and, bbop_bitcount, bbop_div,
                    bbop_equal, bbop_greater, bbop_greater_equal,
                    bbop_if_else, bbop_max, bbop_min, bbop_mul, bbop_or,
                    bbop_relu, bbop_sub, bbop_xor, compile_bbop,
                    planes_of, simdram_pipeline, values_of)

# Static so ruff sees the imports above as intentional re-exports (F401)
__all__ = [
    "bbop_abs", "bbop_add", "bbop_and", "bbop_bitcount", "bbop_div",
    "bbop_equal", "bbop_greater", "bbop_greater_equal", "bbop_if_else",
    "bbop_max", "bbop_min", "bbop_mul", "bbop_or", "bbop_relu", "bbop_sub",
    "bbop_xor",
    "compile_bbop", "planes_of", "values_of", "BitplaneArray",
    "simdram_pipeline", "use_backend", "set_default_backend",
    "register_backend", "list_backends", "execute_program",
    "execute_heterogeneous", "PerfStats", "timed", "SimdramMachine",
    "SimdramFuture", "BankScheduler", "ScheduleResult",
    "RequestTiming", "default_machine", "current_machine",
    "register_operation", "list_operations",
]
