"""bbop_* — array-level SIMDRAM operations (paper Table 1 ISA extensions).

Each ``bbop_<op>(dst ← srcs)`` mirrors one CPU ISA extension from the paper:
the operand arrays are transposed to the vertical layout (the transposition
unit, §5.1), the compiled μProgram for the operation is executed over the
bit-planes (Step 3), and results are transposed back.  μPrograms are compiled
once per (operation, element-width) and cached — exactly the paper's
μProgram Memory/Scratchpad behavior.

The execution backend is the trace-time unrolled engine
(``repro.core.unrolled``): jit-compatible, shardable (the lane dimension is
data-parallel), and differentiable-adjacent (integer ops; models use
straight-through estimators where needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.circuits import compile_operation
from ..core.unrolled import run_unrolled
from ..core.uprogram import UProgram
from ..simdram.layout import LANE_WORD, from_bitplanes, to_bitplanes


@functools.lru_cache(maxsize=None)
def compile_bbop(name: str, n_bits: int, optimize: bool = True) -> UProgram:
    """The μProgram Scratchpad: compile once, reuse (paper Fig. 7)."""
    return compile_operation(name, n_bits, optimize=optimize)


def planes_of(x: jax.Array, n_bits: int) -> tuple[jax.Array, int]:
    """Pad to a lane multiple of 32 and transpose to bit-planes."""
    (e,) = x.shape
    pad = (-e) % LANE_WORD
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return to_bitplanes(x, n_bits), e


def values_of(planes: jax.Array, n: int, signed: bool = False) -> jax.Array:
    return from_bitplanes(planes, signed=signed)[:n]


def _binary(name: str, a: jax.Array, b: jax.Array, n_bits: int,
            signed_out: bool = False, out_bits: int | None = None,
            optimize: bool = True) -> jax.Array:
    pa, n = planes_of(a, n_bits)
    pb, _ = planes_of(b, n_bits)
    prog = compile_bbop(name, n_bits, optimize)
    outs = run_unrolled(prog, {"a": pa, "b": pb},
                        out_bits={prog.outputs[0]: out_bits} if out_bits else None)
    return values_of(outs[prog.outputs[0]], n, signed_out)


def _unary(name: str, a: jax.Array, n_bits: int, out_bits: int | None = None,
           optimize: bool = True) -> jax.Array:
    pa, n = planes_of(a, n_bits)
    prog = compile_bbop(name, n_bits, optimize)
    outs = run_unrolled(prog, {"a": pa},
                        out_bits={prog.outputs[0]: out_bits} if out_bits else None)
    return values_of(outs[prog.outputs[0]], n)


def _flip_msb(x: jax.Array, n_bits: int) -> jax.Array:
    return x ^ (1 << (n_bits - 1))


# -- 2-input operations (bbop_op dst, src_1, src_2, size, n) -----------------

def bbop_add(a, b, n_bits: int = 8, **kw):
    return _binary("addition", a, b, n_bits, **kw)


def bbop_sub(a, b, n_bits: int = 8, **kw):
    return _binary("subtraction", a, b, n_bits, **kw)


def bbop_mul(a, b, n_bits: int = 8, **kw):
    return _binary("multiplication", a, b, n_bits, **kw)


def bbop_div(a, b, n_bits: int = 8, **kw):
    return _binary("division", a, b, n_bits, **kw)


def bbop_greater(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        a, b = _flip_msb(a, n_bits), _flip_msb(b, n_bits)
    return _binary("greater", a, b, n_bits, out_bits=1, **kw)


def bbop_greater_equal(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        a, b = _flip_msb(a, n_bits), _flip_msb(b, n_bits)
    return _binary("greater_equal", a, b, n_bits, out_bits=1, **kw)


def bbop_equal(a, b, n_bits: int = 8, **kw):
    return _binary("equal", a, b, n_bits, out_bits=1, **kw)


def bbop_max(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        sel = bbop_greater(a, b, n_bits, signed=True, **kw)
        return bbop_if_else(sel, a, b, n_bits, **kw)
    return _binary("maximum", a, b, n_bits, **kw)


def bbop_min(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        sel = bbop_greater(b, a, n_bits, signed=True, **kw)
        return bbop_if_else(sel, a, b, n_bits, **kw)
    return _binary("minimum", a, b, n_bits, **kw)


# -- 1-input operations -------------------------------------------------------

def bbop_relu(a, n_bits: int = 8, **kw):
    return _unary("relu", a, n_bits, **kw)


def bbop_abs(a, n_bits: int = 8, **kw):
    return _unary("abs", a, n_bits, **kw)


def bbop_bitcount(a, n_bits: int = 8, **kw):
    return _unary("bitcount", a, n_bits, out_bits=n_bits.bit_length(), **kw)


# -- N-input reductions (paper: Y = src(1) ∘ src(2) ∘ src(3)) ----------------

def _reduction(name: str, srcs, n_bits: int, optimize: bool = True):
    assert len(srcs) == 3, "the compiled reduction μPrograms are 3-input"
    planes = {}
    n = None
    for k, s in enumerate(srcs):
        planes[f"s{k}"], n = planes_of(s, n_bits)
    prog = compile_bbop(name, n_bits, optimize)
    outs = run_unrolled(prog, planes)
    return values_of(outs[prog.outputs[0]], n)


def bbop_and(srcs, n_bits: int = 8, **kw):
    return _reduction("and_reduction", srcs, n_bits, **kw)


def bbop_or(srcs, n_bits: int = 8, **kw):
    return _reduction("or_reduction", srcs, n_bits, **kw)


def bbop_xor(srcs, n_bits: int = 8, **kw):
    return _reduction("xor_reduction", srcs, n_bits, **kw)


# -- predication (bbop_if_else dst, src_1, src_2, select, size, n) ------------

def bbop_if_else(sel, a, b, n_bits: int = 8, optimize: bool = True):
    pa, n = planes_of(a, n_bits)
    pb, _ = planes_of(b, n_bits)
    ps, _ = planes_of(sel.astype(jnp.uint32), 1)
    prog = compile_bbop("if_else", n_bits, optimize)
    outs = run_unrolled(prog, {"a": pa, "b": pb, "sel": ps})
    return values_of(outs[prog.outputs[0]], n)
