"""bbop_* — array-level SIMDRAM operations (paper Table 1 ISA extensions).

Each ``bbop_<op>(dst ← srcs)`` mirrors one CPU ISA extension from the paper.
Two operand forms are accepted everywhere:

* **horizontal** ``jax.Array`` (compat path): operands are transposed to the
  vertical layout (the transposition unit, §5.1), the compiled μProgram is
  executed over the bit-planes (Step 3), and results are transposed back —
  one conversion round-trip *per op*.
* **plane-resident** :class:`~repro.simdram.layout.BitplaneArray` (fused
  path): planes in, planes out, zero transposition-unit traffic.  Chained
  ops stay vertical end-to-end, exactly like the paper's Steps 1–3 that only
  pay layout conversion at the memory boundary.

``simdram_pipeline`` is the ergonomic wrapper for the fused path: it loads
operands vertical in one batched transposition pass, keeps every
intermediate plane-resident, and stores results back horizontal in one pass.

Execution dispatches through the backend registry
(:mod:`repro.core.backends`): ``unrolled`` (trace-time jnp, default),
``pallas`` (the Fig.-7 control-unit FSM kernel), ``reference`` (the numpy
``Subarray`` oracle).  Select per call (``backend="pallas"``), per scope
(``with use_backend(...)``), or process-wide (``set_default_backend``).
μPrograms are compiled once per (operation, element-width) and cached — the
paper's μProgram Memory/Scratchpad behavior.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from ..core.backends import (PerfStats, execute_lowered,  # noqa: F401
                             execute_program, list_backends,
                             set_default_backend, use_backend)
from ..core.backends import timed as timed_execution
from ..core.trace import compile_chain_trace, compile_trace
from ..core.uprogram import UProgram
from ..simdram.layout import (LANE_WORD, BitplaneArray, from_bitplanes,
                              note_elided_movement, to_bitplanes)
from ..simdram.machine import current_machine


def compile_bbop(name: str, n_bits: int, optimize: bool = True) -> UProgram:
    """The μProgram Scratchpad: compile + lower once, reuse (paper Fig. 7).

    Backed by the ambient machine's μProgram Memory: the current session
    machine's when one is in scope (``with machine.session():`` / a
    machine pipeline), otherwise the process-wide compile/lower cache in
    :mod:`repro.core.trace` (the default machine's) — chained ``bbop_*``
    calls, pipelines and ``greedy_decode`` all fetch the same finished
    (μProgram, :class:`~repro.core.trace.LoweredTrace`) pair instead of
    re-running synthesis + row allocation per call.
    """
    m = current_machine()
    if m is not None:
        return m.memory.get(name, n_bits, optimize)[0]
    return compile_trace(name, n_bits, optimize)[0]


def planes_of(x: jax.Array, n_bits: int) -> tuple[jax.Array, int]:
    """Pad to a lane multiple of 32 and transpose to bit-planes."""
    (e,) = x.shape
    pad = (-e) % LANE_WORD
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return to_bitplanes(x, n_bits), e


def values_of(planes: jax.Array, n: int, signed: bool = False) -> jax.Array:
    return from_bitplanes(planes, signed=signed)[:n]


# ---------------------------------------------------------------------------
# Operand coercion / op core
# ---------------------------------------------------------------------------


def _as_planes(x, n_bits: int) -> tuple[BitplaneArray, bool]:
    """(plane-resident operand, was-already-vertical)."""
    if isinstance(x, _ChainValue):
        if x.rec is _current_fusion() and x.n_bits == n_bits:
            return x, True          # stays lazy inside its own fusion scope
        x = x.materialize()
    if isinstance(x, BitplaneArray):
        if x.n_bits != n_bits:
            raise ValueError(f"operand is {x.n_bits}-bit, op wants {n_bits}")
        return x, True
    return BitplaneArray.from_values(jnp.asarray(x), n_bits), False


def _check_banks(ops: list[BitplaneArray]) -> None:
    banks = {o.n_banks for o in ops}
    bankednesses = {o.banked for o in ops}
    if len(banks) > 1 or len(bankednesses) > 1:
        raise ValueError(f"operand bank shapes disagree: "
                         f"{[o.planes.shape for o in ops]}")
    if len({(o.length, o.words) for o in ops}) > 1:
        # same padded width but different logical lengths would silently
        # compute against the shorter operand's zero padding
        raise ValueError(
            f"operand lengths disagree: "
            f"{[(o.length, o.words * LANE_WORD) for o in ops]}")


def _run_op(name: str, operands: dict[str, BitplaneArray], n_bits: int,
            signed_out: bool = False, out_bits: int | None = None,
            optimize: bool = True, backend: str | None = None,
            keep_planes: bool = False, machine=None, compiled=None):
    """Compile-or-fetch + dispatch; returns planes or horizontal values.

    ``machine`` (explicit, or the innermost open machine session) routes
    the call through that machine's μProgram Memory and default backend;
    otherwise the process-wide cache and backend default apply (the
    default machine's configuration).  ``compiled`` short-circuits the
    cache with an already-fetched ``(UProgram, LoweredTrace)`` pair
    (bound ops pass theirs through so each call counts one cache access).
    """
    ops = list(operands.values())
    _check_banks(ops)
    rec = _current_fusion()
    if (rec is not None and keep_planes and compiled is None
            and out_bits is None and machine is None and backend is None
            and all(isinstance(v, (BitplaneArray, _ChainValue))
                    for v in operands.values())):
        # fused-trace pipeline: record the op instead of executing it —
        # the whole chain compiles to ONE LoweredTrace at flush time
        return rec.record(name, operands, n_bits, signed_out, optimize)
    operands = {k: (v.materialize() if isinstance(v, _ChainValue) else v)
                for k, v in operands.items()}
    ops = list(operands.values())
    m = machine if machine is not None else current_machine()
    if m is not None:
        prog, trace = compiled or m.memory.get(name, n_bits, optimize)
        backend = backend or m.backend
    else:
        prog, trace = compiled or compile_trace(name, n_bits, optimize)
    outs = execute_lowered(
        prog, trace, {k: v.planes for k, v in operands.items()},
        out_bits={prog.outputs[0]: out_bits} if out_bits else None,
        backend=backend, machine=m)
    first = ops[0]
    res = BitplaneArray(outs[prog.outputs[0]], out_bits or n_bits,
                        first.length, signed_out)
    if keep_planes:
        return res
    return res.to_values()


def _fused(*xs) -> bool:
    return any(isinstance(x, (BitplaneArray, _ChainValue)) for x in xs)


def _binary(name: str, a, b, n_bits: int, signed_out: bool = False,
            out_bits: int | None = None, optimize: bool = True,
            backend: str | None = None, machine=None):
    keep = _fused(a, b)
    pa, _ = _as_planes(a, n_bits)
    pb, _ = _as_planes(b, n_bits)
    return _run_op(name, {"a": pa, "b": pb}, n_bits, signed_out=signed_out,
                   out_bits=out_bits, optimize=optimize, backend=backend,
                   keep_planes=keep, machine=machine)


def _unary(name: str, a, n_bits: int, out_bits: int | None = None,
           optimize: bool = True, backend: str | None = None, machine=None):
    keep = _fused(a)
    pa, _ = _as_planes(a, n_bits)
    return _run_op(name, {"a": pa}, n_bits, out_bits=out_bits,
                   optimize=optimize, backend=backend, keep_planes=keep,
                   machine=machine)


def _flip_msb(x, n_bits: int):
    if isinstance(x, BitplaneArray):
        return x.flip_msb()
    return x ^ (1 << (n_bits - 1))


# -- 2-input operations (bbop_op dst, src_1, src_2, size, n) -----------------

def bbop_add(a, b, n_bits: int = 8, **kw):
    return _binary("addition", a, b, n_bits, **kw)


def bbop_sub(a, b, n_bits: int = 8, **kw):
    return _binary("subtraction", a, b, n_bits, **kw)


def bbop_mul(a, b, n_bits: int = 8, **kw):
    return _binary("multiplication", a, b, n_bits, **kw)


def bbop_div(a, b, n_bits: int = 8, **kw):
    return _binary("division", a, b, n_bits, **kw)


def bbop_greater(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        a, b = _flip_msb(a, n_bits), _flip_msb(b, n_bits)
    return _binary("greater", a, b, n_bits, out_bits=1, **kw)


def bbop_greater_equal(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        a, b = _flip_msb(a, n_bits), _flip_msb(b, n_bits)
    return _binary("greater_equal", a, b, n_bits, out_bits=1, **kw)


def bbop_equal(a, b, n_bits: int = 8, **kw):
    return _binary("equal", a, b, n_bits, out_bits=1, **kw)


def bbop_max(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        sel = bbop_greater(a, b, n_bits, signed=True, **kw)
        return bbop_if_else(sel, a, b, n_bits, **kw)
    return _binary("maximum", a, b, n_bits, **kw)


def bbop_min(a, b, n_bits: int = 8, signed: bool = False, **kw):
    if signed:
        sel = bbop_greater(b, a, n_bits, signed=True, **kw)
        return bbop_if_else(sel, a, b, n_bits, **kw)
    return _binary("minimum", a, b, n_bits, **kw)


# -- 1-input operations -------------------------------------------------------

def bbop_relu(a, n_bits: int = 8, **kw):
    return _unary("relu", a, n_bits, **kw)


def bbop_abs(a, n_bits: int = 8, **kw):
    return _unary("abs", a, n_bits, **kw)


def bbop_bitcount(a, n_bits: int = 8, **kw):
    return _unary("bitcount", a, n_bits, out_bits=n_bits.bit_length(), **kw)


# -- N-input reductions (paper: Y = src(1) ∘ src(2) ∘ src(3)) ----------------

def _reduction(name: str, srcs, n_bits: int, optimize: bool = True,
               backend: str | None = None, machine=None):
    assert len(srcs) == 3, "the compiled reduction μPrograms are 3-input"
    keep = _fused(*srcs)
    operands = {f"s{k}": _as_planes(s, n_bits)[0] for k, s in enumerate(srcs)}
    return _run_op(name, operands, n_bits, optimize=optimize,
                   backend=backend, keep_planes=keep, machine=machine)


def bbop_and(srcs, n_bits: int = 8, **kw):
    return _reduction("and_reduction", srcs, n_bits, **kw)


def bbop_or(srcs, n_bits: int = 8, **kw):
    return _reduction("or_reduction", srcs, n_bits, **kw)


def bbop_xor(srcs, n_bits: int = 8, **kw):
    return _reduction("xor_reduction", srcs, n_bits, **kw)


# -- predication (bbop_if_else dst, src_1, src_2, select, size, n) ------------

def bbop_if_else(sel, a, b, n_bits: int = 8, optimize: bool = True,
                 backend: str | None = None, machine=None):
    keep = _fused(sel, a, b)
    pa, _ = _as_planes(a, n_bits)
    pb, _ = _as_planes(b, n_bits)
    if isinstance(sel, _ChainValue):
        sel = sel.materialize()
    if isinstance(sel, BitplaneArray):
        ps = sel if sel.n_bits == 1 else sel.astype_bits(1)
    else:
        ps, _ = _as_planes(sel.astype(jnp.uint32), 1)
    return _run_op("if_else", {"a": pa, "b": pb, "sel": ps}, n_bits,
                   optimize=optimize, backend=backend, keep_planes=keep,
                   machine=machine)


# ---------------------------------------------------------------------------
# Cross-op trace fusion (lazy recording inside simdram_pipeline)
# ---------------------------------------------------------------------------

# per-thread stack of active fusion recorders — innermost
# ``simdram_pipeline(fused_trace=True)`` scope records the bbops run in it
_FUSION = threading.local()


def _fusion_stack() -> list:
    st = getattr(_FUSION, "stack", None)
    if st is None:
        st = _FUSION.stack = []
    return st


def _current_fusion():
    st = _fusion_stack()
    return st[-1] if st else None


class _ChainValue:
    """The lazy output of an op recorded into a fused-trace pipeline.

    Stands in for a :class:`BitplaneArray` inside its own fusion scope
    (same layout metadata, so bank/length checks work unchanged) without
    holding planes: the planes exist only after the recorder flushes the
    whole chain as ONE fused :class:`~repro.core.trace.LoweredTrace`.
    Leaving the scope — or any eager consumption (``store``, an
    unfusible op, a different pipeline) — triggers the flush."""

    def __init__(self, rec, op: str, operands: dict, n_bits: int,
                 signed: bool) -> None:
        self.rec = rec
        self.op = op
        self.operands = operands        # input name → BitplaneArray | lazy
        self.n_bits = n_bits
        self.signed = signed
        self.name = f"v{rec.counter}"
        rec.counter += 1
        self._planes = None
        probe = next(iter(operands.values()))
        self.length = probe.length
        self.words = probe.words
        self.banked = probe.banked
        self.n_banks = probe.n_banks

    def materialize(self) -> BitplaneArray:
        """Planes-in-hand value (flushes the pending chain if needed)."""
        if self._planes is None:
            self.rec.flush()
        return BitplaneArray(self._planes, self.n_bits, self.length,
                             self.signed)

    @property
    def planes(self):
        return self.materialize().planes

    def to_values(self, dtype=jnp.int32) -> jax.Array:
        return self.materialize().to_values(dtype)


class _FusionRecorder:
    """Accumulates recorded ops and flushes them as one fused trace."""

    def __init__(self, pipe) -> None:
        self.pipe = pipe
        self.pending: list[_ChainValue] = []
        self.counter = 0
        self.n_bits: int | None = None
        self.optimize: bool | None = None
        self.machine = None             # captured at pipeline __enter__

    def record(self, op: str, operands: dict, n_bits: int, signed: bool,
               optimize: bool) -> _ChainValue:
        if self.pending and (self.n_bits != n_bits
                             or self.optimize != optimize):
            # a chain compiles at one element width / one optimize level;
            # a switch seals the pending chain and starts a new one
            self.flush()
        self.n_bits, self.optimize = n_bits, optimize
        w = _ChainValue(self, op, dict(operands), n_bits, signed)
        self.pending.append(w)
        return w

    def _fetch_prog(self, op: str, n_bits: int, optimize: bool):
        if self.machine is not None:
            return self.machine.memory.get(op, n_bits, optimize)[0]
        return compile_trace(op, n_bits, optimize)[0]

    def flush(self) -> None:
        """Compile the pending ops to ONE fused trace and execute it.

        External operands (loaded planes, prior flushes' outputs) become
        the chain's inputs, deduplicated by plane identity; every pending
        value is a chain output (the user may store any of them).  Each
        chain-internal operand reference is an inter-op relocation the
        fused allocator elided — noted (never charged) through the
        movement hooks so snapshots prove the hop delta."""
        pending = [w for w in self.pending if w._planes is None]
        self.pending = []
        if not pending:
            return
        n_bits, optimize = self.n_bits, self.optimize
        m = self.machine
        ext: dict[int, tuple[str, object]] = {}   # id(planes) → (name, pl)

        def ext_name(bpa: BitplaneArray) -> str:
            key = id(bpa.planes)
            hit = ext.get(key)
            if hit is None:
                hit = (f"in{len(ext)}", bpa.planes)
                ext[key] = hit
            return hit[0]

        stages = []
        n_internal_refs = 0
        for w in pending:
            prog = self._fetch_prog(w.op, n_bits, optimize)
            names = tuple(dict.fromkeys(prog.inputs))
            ins = []
            for nm in names:
                o = w.operands[nm]
                if isinstance(o, _ChainValue) and o.rec is self \
                        and o._planes is None:
                    ins.append(o.name)
                    n_internal_refs += 1
                else:
                    if isinstance(o, _ChainValue):
                        o = o.materialize()
                    ins.append(ext_name(o))
            stages.append((w.op, tuple(ins), w.name))
        out_names = tuple(w.name for w in pending)
        if m is not None:
            prog, trace = m.memory.get_chain(stages, n_bits, optimize,
                                             outputs=out_names)
        else:
            prog, trace = compile_chain_trace(stages, n_bits, optimize,
                                              outputs=out_names)
        outs = execute_lowered(
            prog, trace, {name: pl for name, pl in ext.values()},
            backend=self.pipe.backend, machine=m)
        for w in pending:
            w._planes = outs[w.name]
        for _ in range(n_internal_refs):
            note_elided_movement(n_bits)


# ---------------------------------------------------------------------------
# Plane-resident pipelines
# ---------------------------------------------------------------------------


class simdram_pipeline(contextlib.AbstractContextManager):
    """Keep a chain of bbops vertical end-to-end.

    ::

        with simdram_pipeline(backend="unrolled") as p:
            a, b, c = p.load([av, bv, cv], n_bits=8)
            out = bbop_relu(bbop_add(bbop_mul(a, b, 8), c, 8), 8)
            result = p.store(out)

    ``load`` transposes all operands in ONE pass of the transposition unit
    (operands are stacked along the lane axis, like the hardware streaming a
    block through the unit); every intermediate stays a
    :class:`BitplaneArray`; ``store`` pays the single reverse pass.  The
    scope also pins the execution backend for every op inside it.

    ``timed=True`` (or passing ``perf_stats``/``perf_model``) runs the chain
    under the timed execution layer: every op charges its modeled μProgram
    latency/energy, every inter-op operand relocation its MovementModel
    cost, and the load/store passes their TranspositionModel cost.  The
    accumulated :class:`~repro.core.backends.PerfStats` is ``p.stats`` and
    :meth:`perf_report` renders it — modeled end-to-end DRAM nanoseconds,
    nanojoules, and effective GOps/s per bank for the whole chain.

    ``machine=`` (usually via ``SimdramMachine.pipeline()``) binds the whole
    chain to one session machine: ops fetch from that machine's μProgram
    Memory (including its user-defined ops), execute on its backend, and —
    when timed — charge its own PerfStats with its own DRAM model, fully
    isolated from any other machine in the process.

    ``model="replay"`` additionally replays every executed command trace on
    the cycle-accurate per-bank FSM array
    (:class:`~repro.simdram.timing.TraceReplayTiming`): one desynchronized
    stream per engaged bank under the rank-level tRRD/tFAW activation
    windows and tREFI/tRFC refresh windows, so ``p.stats`` reports replayed
    and analytic ns/nJ side by side (``replay_ns``/``replay_nj`` vs
    ``exec_ns``/``exec_nj``) plus the per-bank stall breakdown
    (``replay_tfaw_ns``/``replay_refresh_ns``/``replay_bank_spread_ns``).
    ``refresh_phase=True`` threads the replay clock through the refresh
    grid across ops (cross-op refresh phase) instead of anchoring every
    op's windows at its own t=0.

    ``fused_trace=True`` turns the pipeline into a *fused-trace* pipeline:
    bbops inside the scope record lazily instead of executing, and the
    whole chain compiles (through the μProgram Memory's chain cache) to
    ONE fused :class:`~repro.core.trace.LoweredTrace` — row allocation
    re-run across op boundaries, so producer outputs land where consumers
    want them and the inter-op LISA relocations the unfused pipeline pays
    are elided (counted as ``elided`` hops in the movement snapshot,
    charged nothing).  The fused trace executes once, at ``store`` of any
    chain value or at scope exit, whichever comes first.  Ops a chain
    cannot absorb (width-changing ops like ``bbop_greater``, explicit
    per-call ``backend=``/``machine=``) run eagerly, sealing the pending
    chain at that point.
    """

    def __init__(self, backend: str | None = None, banks: int | None = None,
                 timed: bool = False, perf_stats: PerfStats | None = None,
                 perf_model=None, model: str | None = None,
                 refresh_phase: bool | None = None, machine=None,
                 fused_trace: bool = False):
        if model is not None and not isinstance(model, str):
            raise TypeError(
                "model= selects the timing mode ('analytic' or 'replay'); "
                "pass a SimdramPerfModel via perf_model=")
        self.backend = backend
        self.banks = banks
        self.stats = perf_stats
        # any timing knob implies a timed pipeline — refresh_phase too,
        # or passing it alone would silently measure nothing
        self._timed = (timed or perf_stats is not None
                       or perf_model is not None or model is not None
                       or refresh_phase is not None)
        self._perf_model = perf_model
        # refresh-phase threading is a replay-mode concept: asking for it
        # without naming a mode means a replay pipeline
        self._mode = model if model is not None else (
            "replay" if refresh_phase is not None else None)
        self._refresh_phase = refresh_phase
        self._machine = machine
        self._fusion = _FusionRecorder(self) if fused_trace else None
        self._ctx = None
        self._tctx = None
        self._mctx = None

    def __enter__(self):
        if self._machine is not None:
            # machine scope first: every op inside fetches from the
            # machine's μProgram Memory and fires its scoped hooks
            self._mctx = self._machine.session()
            self._mctx.__enter__()
        backend = self.backend
        if backend is None and self._machine is not None:
            backend = self._machine.backend
        try:
            if backend is not None:
                self._ctx = use_backend(backend)
                self._ctx.__enter__()
            if self._timed:
                if (self._machine is not None and self.stats is None
                        and self._perf_model is None):
                    # charge the machine's own accumulator (its model)
                    self.stats = self._machine._stats_for(
                        self._mode, self._refresh_phase)
                    self._mode = self.stats.mode
                self._tctx = timed_execution(stats=self.stats,
                                             model=self._perf_model,
                                             mode=self._mode,
                                             refresh_phase=self._refresh_phase)
                self.stats = self._tctx.__enter__()
        except BaseException:
            # __exit__ never runs when __enter__ raises — unwind the
            # scopes entered so far or they leak process-wide
            if self._ctx is not None:
                self._ctx.__exit__(None, None, None)
                self._ctx = None
            if self._mctx is not None:
                self._mctx.__exit__(None, None, None)
                self._mctx = None
            raise
        if self._fusion is not None:
            self._fusion.machine = self._machine if self._machine is not None \
                else current_machine()
            _fusion_stack().append(self._fusion)
        return self

    def __exit__(self, *exc):
        if self._fusion is not None:
            st = _fusion_stack()
            if self._fusion in st:
                st.remove(self._fusion)
            if exc[0] is None:
                # seal the chain while the backend/timed/machine scopes
                # are still open: ONE fused trace executes here
                self._fusion.flush()
            else:
                self._fusion.pending = []
        if self._tctx is not None:
            self._tctx.__exit__(*exc)
        if self._ctx is not None:
            self._ctx.__exit__(*exc)
        if self._mctx is not None:
            self._mctx.__exit__(*exc)
        return False

    def perf_report(self) -> str:
        """Render the accumulated modeled-DRAM cost of the chain."""
        if self.stats is None:
            raise ValueError(
                "pipeline was not timed — construct it with timed=True "
                "(or pass perf_stats=) to collect modeled DRAM cost")
        return self.stats.report()

    def load(self, arrays, n_bits: int, signed: bool = False):
        """Horizontal array(s) → plane-resident, in one transposition pass.

        ``arrays`` may be a single array or a list; each entry is ``(E,)``
        or — when the pipeline is banked — ``(banks, E)``.  Returns
        BitplaneArray(s) matching the input structure.
        """
        single = not isinstance(arrays, (list, tuple))
        xs = [jnp.asarray(a) for a in ([arrays] if single else arrays)]
        shapes = {x.shape for x in xs}
        if len(shapes) > 1:
            raise ValueError(f"load operands disagree in shape: {shapes}")
        if self.banks is not None and (
                xs[0].ndim != 2 or xs[0].shape[0] != self.banks):
            raise ValueError(
                f"banks={self.banks} pipeline expects (banks, E) operands, "
                f"got shape {xs[0].shape}")
        banked = xs[0].ndim == 2
        stacked = jnp.stack(xs)                  # (K, E) or (K, banks, E)
        flat = stacked.reshape(len(xs) * (xs[0].shape[0] if banked else 1),
                               xs[0].shape[-1])
        bpa = BitplaneArray.from_values(flat, n_bits, signed=signed)
        # bpa.planes: (K[*banks], n_bits, W) — split back per operand
        planes = bpa.planes
        outs = []
        for k in range(len(xs)):
            if banked:
                nb = xs[0].shape[0]
                p = planes[k * nb:(k + 1) * nb]
            else:
                p = planes[k]
            outs.append(BitplaneArray(p, n_bits, xs[0].shape[-1], signed))
        return outs[0] if single else outs

    def store(self, *results):
        """Plane-resident result(s) → horizontal, in one reverse pass when
        the results share a layout (width/bits/length/signedness); mixed
        layouts fall back to one pass per result."""
        results = tuple(r.materialize() if isinstance(r, _ChainValue) else r
                        for r in results)
        if len(results) == 1:
            return results[0].to_values()
        # stack along the bank axis so the reverse pass is also single
        layouts = {(r.planes.shape[-1], r.n_bits, r.length, r.signed)
                   for r in results}
        if len(layouts) == 1 and not any(r.banked for r in results):
            merged = BitplaneArray(
                jnp.stack([r.planes for r in results]),
                results[0].n_bits, results[0].length,
                results[0].signed)
            vals = merged.to_values()
            return tuple(vals[i] for i in range(len(results)))
        return tuple(r.to_values() for r in results)
