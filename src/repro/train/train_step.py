"""Train-step factory: loss, grads, clipping, AdamW, microbatch
accumulation, and the majority-vote compressed-DP variant.

Two step flavors:

* ``make_train_step``     — plain pjit step: XLA inserts the gradient
  all-reduce implied by the shardings (baseline).
* ``make_compressed_train_step`` — shard_map over the data axes with
  *explicit* majority-vote sign compression of gradients (the paper's MAJ
  primitive lifted to distributed optimization): per-replica gradient signs
  are bit-packed 32×, all-gathered, and the element-wise majority vote —
  computed exactly like a SIMDRAM TRA, as a bit-plane popcount majority —
  becomes the update direction, with local error feedback.  Wire bytes drop
  32× vs an f32 ring all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.transformer import forward
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    error_fb: Any = None          # error-feedback residual (compressed DP)


def init_train_state(params, compressed: bool = False) -> TrainState:
    err = jax.tree.map(jnp.zeros_like, params) if compressed else None
    return TrainState(params=params, opt=adamw_init(params), error_fb=err)


def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    return -jnp.mean(ll)


def make_loss_fn(cfg: ModelConfig, aux_weight: float = 0.01,
                 loss_chunk: int = 0):
    """``loss_chunk > 0`` enables the chunked-vocab loss: the LM head and
    cross-entropy run per sequence-chunk inside a scan, so the (tokens ×
    vocab) f32 logits tensor is never materialized — the memory-term
    optimization for large-vocab training cells (§Perf hillclimb)."""
    def loss_fn(params, batch):
        if not loss_chunk:
            logits, aux, _ = forward(params, cfg, batch)
            loss = softmax_xent(logits, batch["labels"])
            return loss + aux_weight * aux, {"loss": loss, "aux": aux}
        hidden, aux, _ = forward(params, cfg, batch, return_hidden=True)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        b, s, d = hidden.shape
        n_chunks = max(1, s // loss_chunk)
        hs = hidden.reshape(b, n_chunks, s // n_chunks, d).transpose(
            1, 0, 2, 3)
        ls = batch["labels"].reshape(b, n_chunks, s // n_chunks).transpose(
            1, 0, 2)

        def chunk_loss(carry, xs):
            h, lab = xs
            logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(logp, lab[..., None], -1)[..., 0]
            return carry - jnp.sum(ll), None

        total, _ = jax.lax.scan(chunk_loss, jnp.zeros(()), (hs, ls))
        loss = total / (b * s)
        return loss + aux_weight * aux, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, loss_chunk: int = 0):
    """Plain (pjit) step with optional microbatch gradient accumulation —
    accumulation is expressed as a scan so XLA can overlap the k-th
    microbatch's gradient reduction with the (k+1)-th backward pass."""
    loss_fn = make_loss_fn(cfg, loss_chunk=loss_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                g_acc, _ = carry
                (_, metrics), g = grad_fn(state.params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, metrics), None

            zero = jax.tree.map(jnp.zeros_like, state.params)
            (grads, metrics), _ = jax.lax.scan(
                acc_body, (zero, {"loss": jnp.zeros(()),
                                  "aux": jnp.zeros(())}), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt, stats = adamw_update(opt_cfg, state.opt, state.params,
                                          grads)
        metrics.update(stats)
        return TrainState(params, opt, state.error_fb), metrics

    return train_step


# ---------------------------------------------------------------------------
# Majority-vote compressed data parallelism (paper technique, lifted)
# ---------------------------------------------------------------------------

def _pack_signs(g: jax.Array) -> jax.Array:
    """f32 (..., n) → uint32 (..., n/32) packed sign bits (1 ⇔ g ≥ 0)."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % 32
    flat = jnp.pad(flat, (0, pad))
    bits = (flat >= 0).astype(jnp.uint32).reshape(-1, 32)
    return (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1,
                                                          dtype=jnp.uint32)


def _majority_from_packed(words: jax.Array, n_voters: int, n: int):
    """words: (R, W) packed sign planes from R replicas → ±1 majority vote.

    This is SIMDRAM's TRA generalized to R inputs: per bit-lane popcount
    against R/2 (computed SWAR on the packed words, no unpacking on the
    wire)."""
    counts = jnp.zeros(words.shape[1:] + (32,), jnp.int32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    unpacked = ((words[:, :, None] >> shifts) & 1).astype(jnp.int32)
    counts = unpacked.sum(0)                     # (W, 32)
    maj = (2 * counts > n_voters).astype(jnp.float32) * 2 - 1
    return maj.reshape(-1)[:n]


def make_compressed_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                               mesh, sign_lr_scale: float = 1.0,
                               fused: bool = True, two_phase: bool = False,
                               exchange: str = "packed"):
    """shard_map step: per-DP-replica grads → error-feedback add → packed
    sign exchange over the data axes → bit-plane majority vote → update.
    Model-axis sharding stays under XLA's automatic partitioner (auto axes).

    ``fused=True`` (hillclimb H5): every gradient leaf is flattened into one
    vector so the whole exchange is a single packed collective.

    ``two_phase=True`` (hillclimb H7): instead of all-gathering R packed
    planes (whose per-device bytes grow with R), do the scalable exchange —
    all-to-all a 1/R slice of packed words to each voter, majority locally,
    all-gather only the majority result: per-device bytes ≈ 2·n/32 words
    independent of R (the reduce-scatter analogue for majority voting).

    ``exchange`` selects the vote collective: ``"packed"`` (all-gather of
    bit-packed sign planes, the true 32×-compressed wire format) or
    ``"psum"`` (sum of ±1 votes — the identical majority, since
    popcount(ones) > R/2 ⇔ Σ±1 > 0, but exchanged uncompressed; the
    dense-allreduce control for wire-byte comparisons).
    """
    loss_fn = make_loss_fn(cfg, loss_chunk=cfg.loss_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # static voter count: the mesh is known at build time (and jax 0.4.x has
    # no jax.lax.axis_size to query it inside the traced body)
    n_voters = 1
    for a in data_axes:
        n_voters *= int(mesh.shape[a])
    if exchange not in ("packed", "psum"):
        raise ValueError(f"unknown exchange mode {exchange!r}")

    def _psum_vote(gf):
        """Majority direction via Σ±1 (wire-uncompressed, vote-identical)."""
        votes = jnp.where(gf >= 0, jnp.int32(1), jnp.int32(-1))
        counts = jax.lax.psum(votes, data_axes)
        return (counts > 0).astype(jnp.float32) * 2 - 1

    def step(state: TrainState, batch):
        (_, metrics), grads = grad_fn(state.params, batch)
        # grads here are per-DP-shard (shard_map over data axes)

        def compress_one(g, e):
            gf = g.astype(jnp.float32) + e
            scale = jnp.mean(jnp.abs(gf))
            if exchange == "psum":
                maj = _psum_vote(gf.reshape(-1))
            else:
                packed = _pack_signs(gf)
                gathered = jax.lax.all_gather(packed, data_axes, tiled=False)
                gathered = gathered.reshape(n_voters, -1)
                maj = _majority_from_packed(gathered, n_voters, gf.size)
            maj = maj.reshape(g.shape)
            scale = jax.lax.pmean(scale, data_axes)
            decoded = (maj * scale).astype(jnp.float32)
            new_e = gf - decoded
            return decoded * sign_lr_scale, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(state.error_fb)
        if not fused:
            dec_err = [compress_one(g, e) for g, e in zip(flat_g, flat_e)]
            grads = jax.tree.unflatten(tdef, [d for d, _ in dec_err])
            new_err = jax.tree.unflatten(tdef, [e for _, e in dec_err])
        else:
            # ONE flat exchange: concat leaves → pack → single all-gather
            sizes = [g.size for g in flat_g]
            offs = np.cumsum([0] + sizes)
            gf = jnp.concatenate(
                [g.astype(jnp.float32).reshape(-1) + e.reshape(-1)
                 for g, e in zip(flat_g, flat_e)])
            scales = jnp.array(
                [jnp.mean(jnp.abs(gf[offs[i]:offs[i + 1]]))
                 for i in range(len(sizes))])
            scales = jax.lax.pmean(scales, data_axes)
            if exchange == "psum":
                maj = _psum_vote(gf)
            elif two_phase:
                packed = _pack_signs(gf)
                # pad so the word count splits evenly across voters
                w = packed.shape[0]
                pad = (-w) % n_voters
                packed = jnp.pad(packed, (0, pad))
                chunks = packed.reshape(n_voters, -1)
                # phase 1: voter j receives every replica's chunk j
                recv = jax.lax.all_to_all(chunks, data_axes, split_axis=0,
                                          concat_axis=0, tiled=True)
                recv = recv.reshape(n_voters, -1)
                slice_maj = _majority_from_packed(
                    recv, n_voters, recv.shape[1] * 32)          # ±1 slice
                # phase 2: gather the (repacked) majority slices
                slice_packed = _pack_signs(slice_maj)
                gathered = jax.lax.all_gather(slice_packed, data_axes,
                                              tiled=True)
                maj = _majority_from_packed(gathered[None, :], 1,
                                            gf.size + pad * 32)[:gf.size]
            else:
                packed = _pack_signs(gf)
                gathered = jax.lax.all_gather(packed, data_axes, tiled=False)
                maj = _majority_from_packed(gathered.reshape(n_voters, -1),
                                            n_voters, gf.size)
            scale_vec = jnp.concatenate(
                [jnp.full((s,), scales[i]) for i, s in enumerate(sizes)])
            decoded = maj * scale_vec
            new_e_flat = gf - decoded
            grads = jax.tree.unflatten(tdef, [
                (decoded[offs[i]:offs[i + 1]] * sign_lr_scale
                 ).reshape(flat_g[i].shape) for i in range(len(sizes))])
            new_err = jax.tree.unflatten(tdef, [
                new_e_flat[offs[i]:offs[i + 1]].reshape(flat_g[i].shape)
                for i in range(len(sizes))])
        params, opt, stats = adamw_update(opt_cfg, state.opt, state.params,
                                          grads)
        metrics.update(stats)
        metrics["loss"] = jax.lax.pmean(metrics["loss"], data_axes)
        return TrainState(params, opt, new_err), metrics

    return step, data_axes
