"""Deterministic synthetic data pipeline (sharded, restartable).

Real deployments swap in a tokenized corpus reader; the pipeline contract is
what matters for the framework: (1) deterministic per-step batches keyed by
(seed, step) so restarts/elastic rescales reproduce the same stream; (2)
host-local sharding — each data-parallel host materializes only its slice;
(3) an explicit schema matching ``input_specs``.

The synthetic distribution is a Zipf-ish token mixture with a simple Markov
structure so the LM loss is learnable (used by the e2e example)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8


def synthetic_batch(cfg: DataConfig, step: int,
                    lo: int = 0, hi: int | None = None) -> dict:
    """Batch rows [lo, hi) of the global batch for this step (host slice)."""
    hi = cfg.global_batch if hi is None else hi
    rng = np.random.default_rng(np.uint64(cfg.seed) + np.uint64(step))
    # markov-ish stream: next ~ (prev*a + noise) mod vocab, zipf-biased
    n = hi - lo
    base = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    base = np.minimum(base, cfg.vocab - 1)
    drift = np.cumsum(rng.integers(0, 7, size=base.shape), axis=1)
    toks = ((base + drift) % cfg.vocab).astype(np.int32)[lo:hi]
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def batch_spec(model: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one batch — feeds input_specs/dry-run."""
    b, s = shape.global_batch, shape.seq_len
    spec = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if model.rope == "mrope":
        spec["mrope_positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    if model.enc_dec:
        spec["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, model.encoder_seq, model.d_model), jnp.bfloat16)
    return spec


def make_batch_like(spec_tree, seed: int = 0) -> dict:
    """Materialize a concrete batch matching a spec tree (tests/examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, sds in spec_tree.items():
        if jnp.issubdtype(sds.dtype, jnp.integer):
            out[k] = jnp.asarray(
                rng.integers(0, 64, size=sds.shape), sds.dtype)
        else:
            out[k] = jnp.asarray(
                rng.normal(0, 0.1, size=sds.shape), sds.dtype)
    return out
