"""AdamW in pure JAX (pytree-structured state, shardable like params)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, state: AdamWState, params, grads):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m2 / b1c, v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
