"""Faithful μProgram executor over a modeled DRAM subarray (paper Step 3).

This is the *reference semantics* implementation: a numpy bit-plane subarray
with exact AAP/AP behavior, including

* destructive triple-row activation: after an AP, **all three** cells hold
  the majority (each through its own wordline polarity — a cell activated
  through its n-wordline both contributes its complement to the bitline and
  stores back the complement of the sensed value);
* dual-contact-cell port semantics for NOT;
* multi-row AAP destinations (coalesced copies);
* Case-2 coalesced AAPs whose *source* activation is itself a TRA.

Rows hold ``W`` SIMD lanes packed as uint64 words (W = row width in bits =
number of bitlines = SIMD lanes), mirroring the paper's 65 536-lane 8 kB row.

The executor also doubles as the command-sequence *counter* feeding the
timing/energy model — each AAP/AP is logged.
"""
from __future__ import annotations

import numpy as np

from .uprogram import AAP, AP, CRow, DRow, N_B_CELLS, Port, UProgram

WORD = 64


class Subarray:
    """A modeled SIMDRAM subarray: D-group operand arrays + C-group constants
    + the six B-group compute cells, all ``lanes`` bits wide."""

    def __init__(self, lanes: int, seed_garbage: int | None = 0xD1) -> None:
        if lanes % WORD:
            raise ValueError(f"lanes must be a multiple of {WORD}")
        self.lanes = lanes
        self.words = lanes // WORD
        self.d_rows: dict[tuple[str, int], np.ndarray] = {}
        # B-group cells power up with garbage (nothing may rely on it)
        rng = np.random.default_rng(seed_garbage)
        self.b_cells = [
            rng.integers(0, 1 << 63, size=self.words, dtype=np.uint64) << np.uint64(1)
            if seed_garbage is not None else np.zeros(self.words, np.uint64)
            for _ in range(N_B_CELLS)
        ]
        self.stats = {"AAP": 0, "AP": 0, "TRA": 0, "rows_activated": 0}

    # -- D-group access ------------------------------------------------------
    def write_operand(self, name: str, planes: np.ndarray) -> None:
        """planes: uint64[n_bits, words] — vertical layout (bit i in row i)."""
        planes = np.asarray(planes, dtype=np.uint64)
        for i in range(planes.shape[0]):
            self.d_rows[(name, i)] = planes[i].copy()

    def read_operand(self, name: str, n_bits: int) -> np.ndarray:
        return np.stack([self.d_rows[(name, i)] for i in range(n_bits)])

    def alloc_operand(self, name: str, n_bits: int) -> None:
        for i in range(n_bits):
            self.d_rows[(name, i)] = np.zeros(self.words, np.uint64)

    # -- row read/write through ports ---------------------------------------
    def _read(self, ref) -> np.ndarray:
        if isinstance(ref, Port):
            v = self.b_cells[ref.cell]
            return ~v if ref.neg else v
        if isinstance(ref, CRow):
            return (np.full(self.words, ~np.uint64(0)) if ref.one
                    else np.zeros(self.words, np.uint64))
        if isinstance(ref, DRow):
            row = self.d_rows.get((ref.array, ref.bit))
            if row is None:
                raise KeyError(f"read of unallocated D-row {ref}")
            return row
        raise TypeError(ref)

    def _write(self, ref, bitline: np.ndarray) -> None:
        if isinstance(ref, Port):
            self.b_cells[ref.cell] = ~bitline if ref.neg else bitline.copy()
        elif isinstance(ref, DRow):
            self.d_rows[(ref.array, ref.bit)] = bitline.copy()
        elif isinstance(ref, CRow):
            raise ValueError("C-group rows are read-only")
        else:
            raise TypeError(ref)

    # -- command sequences ----------------------------------------------------
    @staticmethod
    def _maj(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
        return (a & b) | (a & c) | (b & c)

    def _tra(self, ports) -> np.ndarray:
        """Perform the charge-sharing majority across three ports; write the
        sensed value back through every port (destructive)."""
        cells = {p.cell for p in ports}
        if len(cells) != 3:
            raise ValueError(f"TRA needs 3 distinct cells, got {ports}")
        vals = [self._read(p) for p in ports]
        bitline = self._maj(*vals)
        for p in ports:
            self._write(p, bitline)
        self.stats["TRA"] += 1
        self.stats["rows_activated"] += 3
        return bitline

    def execute(self, uop) -> None:
        if isinstance(uop, AP):
            self._tra(uop.ports)
            self.stats["AP"] += 1
        elif isinstance(uop, AAP):
            if isinstance(uop.src, tuple):       # Case-2 coalesced: ACT#1 is a TRA
                bitline = self._tra(uop.src)
            else:
                bitline = self._read(uop.src)
                self.stats["rows_activated"] += 1
            for d in uop.dsts:
                self._write(d, bitline)
            self.stats["AAP"] += 1
            self.stats["rows_activated"] += len(uop.dsts)
        else:
            raise TypeError(f"not a command-sequence μOp: {uop}")

    def run(self, prog: UProgram) -> None:
        for name in prog.scratch:
            self.alloc_operand(name, prog.n_bits + 1)
        for u in prog.flatten():
            self.execute(u)


def run_program(prog: UProgram, operands: dict[str, np.ndarray],
                lanes: int | None = None, out_bits: dict[str, int] | None = None,
                ) -> tuple[dict[str, np.ndarray], Subarray]:
    """Execute a compiled μProgram on the reference subarray.

    ``operands``: array name → 1-D numpy integer array (horizontal values).
    Returns (output planes per output array, subarray) — callers decode with
    :func:`from_planes`.
    """
    n = prog.n_bits
    first = next(iter(operands.values()))
    n_elems = len(first)
    lanes = lanes or ((n_elems + WORD - 1) // WORD) * WORD
    sa = Subarray(lanes)
    for name, vals in operands.items():
        sa.write_operand(name, to_planes(vals, n, lanes))
    # scratch + outputs: allocate zeroed D rows (a real system would μProgram
    # the zeroing; our compiled programs zero what they rely on explicitly)
    out_bits = out_bits or {}
    for name in prog.outputs:
        sa.alloc_operand(name, out_bits.get(name, n))
    for name in prog.scratch:
        sa.alloc_operand(name, out_bits.get(name, 2 * n + 2))
    for u in prog.flatten():
        # lazily allocate any referenced scratch rows (spills)
        for r in _uop_drows(u):
            if (r.array, r.bit) not in sa.d_rows:
                sa.d_rows[(r.array, r.bit)] = np.zeros(sa.words, np.uint64)
        sa.execute(u)
    outs = {name: sa.read_operand(name, out_bits.get(name, n))
            for name in prog.outputs}
    return outs, sa


def _uop_drows(u) -> list:
    rows = []
    if isinstance(u, AAP):
        if isinstance(u.src, DRow):
            rows.append(u.src)
        rows.extend(d for d in u.dsts if isinstance(d, DRow))
    return rows


# ---------------------------------------------------------------------------
# Vertical-layout helpers (numpy oracle side; the JAX/Pallas versions live in
# repro.simdram.layout / repro.kernels)
# ---------------------------------------------------------------------------

def to_planes(values: np.ndarray, n_bits: int, lanes: int | None = None) -> np.ndarray:
    """Horizontal ints → vertical bit-planes uint64[n_bits, lanes/64].

    Element j's bit i lands in plane i, lane j (paper Fig. 4b)."""
    values = np.asarray(values)
    n = values.shape[0]
    lanes = lanes or ((n + WORD - 1) // WORD) * WORD
    assert lanes % WORD == 0 and lanes >= n
    u = values.astype(np.int64).astype(np.uint64)
    planes = np.zeros((n_bits, lanes // WORD), dtype=np.uint64)
    lane = np.arange(n)
    word, off = lane // WORD, np.uint64(1) << (lane % WORD).astype(np.uint64)
    for i in range(n_bits):
        bits = (u >> np.uint64(i)) & np.uint64(1)
        np.add.at(planes[i], word[bits == 1], off[bits == 1])
    return planes


def from_planes(planes: np.ndarray, n: int, signed: bool = False) -> np.ndarray:
    """Vertical bit-planes → horizontal ints (first ``n`` lanes)."""
    n_bits = planes.shape[0]
    lane = np.arange(n)
    word, sh = lane // WORD, (lane % WORD).astype(np.uint64)
    out = np.zeros(n, dtype=np.uint64)
    for i in range(n_bits):
        bits = (planes[i][word] >> sh) & np.uint64(1)
        out |= bits << np.uint64(i)
    if signed:
        sign = (out >> np.uint64(n_bits - 1)) & np.uint64(1)
        out = out.astype(np.int64) - (sign.astype(np.int64) << n_bits)
        return out
    return out.astype(np.int64)
