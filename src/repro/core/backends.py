"""Pluggable μProgram execution backends (the Step-3 seam).

Every ``execute_program`` call lowers its compiled
:class:`~repro.core.uprogram.UProgram` (once, memoized) to the shared
command-trace IR — :class:`~repro.core.trace.LoweredTrace` — and every
backend consumes that same trace with the same plane-level operand format:
``name → uint32[n_bits, W]`` bit planes (optionally
``uint32[banks, n_bits, W]`` for the paper's multi-bank scaling).
Registered backends:

* ``reference`` — decodes the trace back to μOps and runs them on the
  faithful numpy :class:`~repro.core.executor.Subarray` model: exact
  AAP/AP semantics, destructive TRAs, DCC ports.  The oracle.
* ``unrolled``  — scans the trace's command array at trace time into pure
  jnp dataflow (:func:`repro.core.unrolled.run_trace_unrolled`): copies
  vanish, constants fold; the TPU-native fast path.  jit/vmap-compatible.
* ``pallas``    — the Fig.-7 control-unit FSM as a Pallas kernel
  (:func:`repro.kernels.ops.run_trace_kernel`): the trace's int32 command
  stream driving a VMEM row file.  ``interpret=True`` runs it on CPU; on a
  real TPU the same kernel is the explicitly-tiled memory-traffic path.

New substrates (real-DRAM timing models, GPU bit-slice engines, …) plug in
with :func:`register_backend` — a ``BackendFn`` takes ``(trace, operands,
out_bits=...)`` — and are immediately usable from every ``bbop_*`` and from
:class:`~repro.ops.bbops.simdram_pipeline` via ``backend="name"``.

Timed execution.  :func:`timed` opens a scope in which every
:func:`execute_program` call — on *any* registered substrate — charges its
modeled DRAM cost to a :class:`PerfStats` accumulator: μProgram command
latency/energy from :class:`~repro.simdram.timing.SimdramPerfModel`,
inter-op operand relocation from its ``MovementModel`` (intra-bank LISA
hops, inter-bank RowClone-PSM transfers via the layout movement hooks),
and every transposition-unit pass (``to_bitplanes``/``from_bitplanes``)
from its ``TranspositionModel``.  Charging is trace-level, like
``TRANSPOSE_STATS``: it reflects the command stream the chain *issues*,
independent of which substrate executes it — the paper's §7 methodology
(sum of AAP/AP command-sequence latencies), reported per live pipeline.

``timed(mode="replay")`` (or ``simdram_pipeline(timed=True,
model="replay")``) additionally replays every lowered trace on the
cycle-accurate per-bank FSM
(:class:`~repro.simdram.timing.TraceReplayTiming`) and accumulates the
replayed ns/nJ next to the analytic ones — measured-style timing behind the
same accumulator surface.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..simdram.layout import (LANE_WORD, register_movement_hook,
                              register_transpose_hook)
from ..simdram.timing import SimdramPerfModel
from .trace import GLOBAL_TRACE_CACHE, LoweredTrace, lower_program
from .uprogram import UProgram

# backend: (trace, operands: dict[str, uint32[n_bits, W]], out_bits) → outputs
BackendFn = Callable[..., dict]

_REGISTRY: dict[str, BackendFn] = {}
_DEFAULT = "unrolled"


def register_backend(name: str, fn: BackendFn) -> None:
    _REGISTRY[name] = fn


def list_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str | None = None) -> BackendFn:
    key = name or _DEFAULT
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(f"unknown backend {key!r}; registered: "
                       f"{list_backends()}") from None


def default_backend() -> str:
    return _DEFAULT


# bumped on every set_default_backend so use_backend can tell "still the
# default I set" from "somebody re-set it inside my scope" (a plain name
# comparison cannot: set_default_backend(<the scope's own name>) must win)
_DEFAULT_EPOCH = 0


def set_default_backend(name: str) -> None:
    global _DEFAULT, _DEFAULT_EPOCH
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{list_backends()}")
    _DEFAULT = name
    _DEFAULT_EPOCH += 1


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped default-backend override: ``with use_backend("pallas"): ...``

    On exit the previous default is restored *only if* no
    ``set_default_backend`` call was made inside the scope — an explicit
    set survives the scope instead of being silently discarded.
    """
    global _DEFAULT, _DEFAULT_EPOCH
    prev = _DEFAULT
    epoch_at_entry = _DEFAULT_EPOCH
    set_default_backend(name)
    token = _DEFAULT_EPOCH
    try:
        yield
    finally:
        if _DEFAULT_EPOCH == token:
            # restoring rewinds the epoch too, so enclosing scopes still
            # see "unchanged" and restore in turn
            _DEFAULT = prev
            _DEFAULT_EPOCH = epoch_at_entry


# ---------------------------------------------------------------------------
# Timed execution: modeled-DRAM cost accounting for any substrate
# ---------------------------------------------------------------------------

# PerfStats currently charging (a stack: nested timed scopes all observe;
# the same accumulator registered twice still charges once).  Accumulators
# *owned* by a SimdramMachine only observe work executed by that machine —
# two interleaved machine sessions never cross-charge.
_ACTIVE_STATS: list["PerfStats"] = []


# replaced by repro.simdram.machine at import with its current_machine();
# kept injectable so this module never imports the machine layer eagerly
def _current_machine():
    return None


def _charging_stats(machine=None) -> list["PerfStats"]:
    """Active accumulators that should observe work executed by
    ``machine`` (None = the innermost open machine session, if any)."""
    if not _ACTIVE_STATS:
        return _ACTIVE_STATS
    eff = machine if machine is not None else _current_machine()
    return [st for st in _ACTIVE_STATS
            if st.owner is None or st.owner is eff]

# op outputs tracked for movement charging are bounded: consumers only ever
# reach a handful of ops back, and an unbounded map would pin every
# intermediate plane of a long timed region in memory
_RESIDENT_CAP = 64

# the per-accumulator μProgram/trace cost memos are bounded the same way:
# a long-lived accumulator (e.g. one threaded through a whole decode
# service) would otherwise pin every ad-hoc program and trace it ever
# charged, forever, by id — FIFO-capped like _RESIDENT_CAP bounds _resident
_COST_CAP = 256


@dataclasses.dataclass
class PerfStats:
    """Modeled-DRAM cost accumulator for a timed execution scope.

    Three meters, analytic by default (paper §7 methodology):

    * ``exec_ns`` / ``exec_nj`` — per ``execute_program`` call, the
      μProgram's summed AAP/AP command-sequence latency and energy
      (:meth:`SimdramPerfModel.latency_ns` / ``energy_nj``).  Banks run the
      command stream in lockstep, so latency is charged once per call and
      energy × banks.
    * movement — per in-DRAM operand relocation, broken out per kind: when
      an op consumes another op's output planes directly, its ``n_bits``
      result rows are charged one *intra-bank* LISA hop each
      (``MovementModel.intra_bank_ns``); bank redistributions
      (``BitplaneArray.rebank`` via the layout movement hooks) charge
      *inter-bank* RowClone-PSM transfers (``inter_bank_ns``).  Plane-level
      rewrites (``flip_msb``/``split_lanes``/``astype_bits``) produce new
      arrays and are *not* tracked — they are free row re-indexing.
    * transposition — per transposition-unit pass inside the scope
      (``TranspositionModel.first_subarray_ns``), broken out per direction
      (``to_bitplanes`` loads vs ``from_bitplanes`` stores).

    With ``mode="replay"`` every executed trace is *additionally* replayed
    on the cycle-accurate per-bank FSM array
    (:class:`~repro.simdram.timing.TraceReplayTiming`): ``replay_ns`` /
    ``replay_nj`` accumulate next to the analytic meters (replay ≥ analytic
    always — the FSMs can only add stall cycles, and stalls burn background
    power), so replayed-vs-analytic deltas are attributable per op.  The
    replay runs one FSM per engaged bank under the rank-level constraints
    the ``DRAMTiming`` enables (tRRD, the four-activate tFAW window,
    tREFI/tRFC refresh windows; ``desync_policy="lockstep"`` restores the
    legacy broadcast FSM), and the per-bank breakdown accumulates here:
    ``replay_tfaw_ns`` / ``replay_refresh_ns`` attribute stall time to the
    two rank mechanisms and ``replay_bank_spread_ns`` sums each op's
    slowest-minus-fastest bank finish gap.  An inter-bank
    ``BitplaneArray.rebank`` scatter serializes each bank's planes over
    the internal bus, giving each bank a data-arrival skew; the layout
    movement hook records it here *keyed to the scattered plane array*,
    and the replayed op that consumes those planes issues each bank's
    stream at that offset (consumed once).

    Charging is trace-level: under ``jit`` a charge lands once at trace
    time, like ``TRANSPOSE_STATS``.  Movement/transposition *energy* is not
    modeled (the paper provides no figures for either); ``total_nj`` is
    execution energy only.
    """

    model: SimdramPerfModel = dataclasses.field(
        default_factory=SimdramPerfModel)
    mode: str = "analytic"             # or "replay"
    # replay mode only: thread the accumulated replay clock into each op's
    # refresh-window grid, so refresh windows are anchored in pipeline time
    # instead of per-op t=0 and ops shorter than tREFI still accrue their
    # share of refresh stall across a long chain (the cross-op refresh
    # phase).  Phase threading only ever moves windows *earlier* in an
    # op's local time, so phased replay_ns >= per-op-anchored replay_ns
    # for chains whose individual ops fit inside one tREFI interval.
    refresh_phase: bool = False
    # the SimdramMachine this accumulator belongs to, if any: an owned
    # accumulator only observes its own machine's work even while other
    # sessions' timed scopes are open (see _charging_stats)
    owner: object = dataclasses.field(default=None, repr=False, compare=False)
    exec_ns: float = 0.0
    exec_nj: float = 0.0
    replay_ns: float = 0.0
    replay_nj: float = 0.0
    replay_stall_ns: float = 0.0
    replay_tfaw_ns: float = 0.0        # stall attributed to the tFAW window
    replay_refresh_ns: float = 0.0     # stall attributed to refresh windows
    replay_bank_spread_ns: float = 0.0  # Σ per-op (max − min) bank finish
    movement_intra_ns: float = 0.0
    movement_inter_ns: float = 0.0
    transpose_to_ns: float = 0.0
    transpose_from_ns: float = 0.0
    n_programs: int = 0
    n_commands: int = 0
    n_moves_intra: int = 0
    n_moves_inter: int = 0
    n_moves_elided: int = 0    # inter-op hops the fusion allocator removed
    n_transposes_to: int = 0
    n_transposes_from: int = 0
    elem_ops: int = 0
    max_banks: int = 1
    per_op: dict = dataclasses.field(default_factory=dict)
    # tenant name → child PerfStats: per-tenant attribution for scheduled
    # (submit/drain) execution.  Children share this accumulator's owner,
    # and are *additionally* registered only while their tenant's
    # submissions execute, so concurrent tenants never cross-charge and
    # the tenant rollup sums to this accumulator's totals.
    tenants: dict = dataclasses.field(default_factory=dict)
    # id(planes) → planes for the most recent op outputs of this scope
    # (strong refs so ids cannot be recycled, FIFO-bounded by
    # _RESIDENT_CAP); consumed ids trigger movement charges
    _resident: dict = dataclasses.field(default_factory=dict, repr=False)
    # trace.fingerprint → (latency_ns, energy_nj, n_commands) — scoped to
    # this accumulator so cache entries die with it, FIFO-bounded by
    # _COST_CAP.  Content-keyed: an ``id()`` key could alias once the entry
    # no longer pins its program and the allocator reuses the address for a
    # new one, and it missed on every recompile of the same op anyway.
    _prog_costs: dict = dataclasses.field(default_factory=dict, repr=False)
    # (trace.fingerprint, banks, offsets, phase) → ReplayResult, same bounds
    _replay_costs: dict = dataclasses.field(default_factory=dict, repr=False)
    # id(planes) → (per-bank issue offsets, planes) for inter-bank scatters
    # (data-arrival skew; strong refs keep ids stable, FIFO-bounded like
    # _resident) — consumed by the op that consumes the scattered planes
    _bank_skew: dict = dataclasses.field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if self.mode not in ("analytic", "replay"):
            raise ValueError(f"unknown timing mode {self.mode!r} "
                             "(expected 'analytic' or 'replay')")

    def _prog_cost(self, prog: UProgram, trace: LoweredTrace) -> tuple:
        hit = self._prog_costs.get(trace.fingerprint)
        if hit is None:
            mix = prog.command_mix()
            hit = (self.model.latency_ns(prog), self.model.energy_nj(prog),
                   mix["AAP"] + mix["AP"])
            self._prog_costs[trace.fingerprint] = hit
            while len(self._prog_costs) > _COST_CAP:
                del self._prog_costs[next(iter(self._prog_costs))]
        return hit

    def _replay_cost(self, trace: LoweredTrace, banks: int, offsets,
                     phase_ns: float = 0.0):
        key = (trace.fingerprint, banks, offsets, round(phase_ns, 3))
        hit = self._replay_costs.get(key)
        if hit is None:
            # L2: the TraceCache replay memo (the owner machine's memory,
            # else the process-wide cache) — persists across accumulator
            # lifetimes, so a fresh timed() scope replays warm traces as
            # a table lookup
            memory = getattr(self.owner, "memory", None)
            if memory is None:
                memory = GLOBAL_TRACE_CACHE
            hit = self.model.replay_result(trace, banks=banks,
                                           offsets_ns=offsets,
                                           refresh_phase_ns=phase_ns,
                                           cache=memory)
            self._replay_costs[key] = hit
            while len(self._replay_costs) > _COST_CAP:
                del self._replay_costs[next(iter(self._replay_costs))]
        return hit

    def _op_shares(self, prog: UProgram,
                   trace: LoweredTrace) -> tuple[list, int]:
        """Per-op charge split for one trace: ``([(per_op key, fraction)],
        n_stage_ops)``.  Fused chain traces split proportionally by each
        stage's share of command sequences and perform one element-op per
        stage per lane; plain traces map to their own name."""
        chain = getattr(trace, "chain", None)
        stages = getattr(chain, "stages", ()) if chain is not None else ()
        if stages:
            total = max(1, sum(s.seq_end - s.seq_start for s in stages))
            return ([(f"{s.op}/{prog.n_bits}b",
                      (s.seq_end - s.seq_start) / total)
                     for s in stages], len(stages))
        return ([(f"{prog.name}/{prog.n_bits}b", 1.0)], 1)

    # -- charging (called by execute_program / the layout hooks) ------------
    def charge_program(self, prog: UProgram, banks: int, lanes: int,
                       trace: LoweredTrace | None = None,
                       offsets=None) -> None:
        replayable = trace is not None
        if trace is None:
            # analytic-only callers: the lowering memo makes this cheap,
            # and the trace fingerprint is the stable cost-memo key
            trace = lower_program(prog)
        lat, en, cmds = self._prog_cost(prog, trace)
        self.exec_ns += lat
        self.exec_nj += en * banks
        self.n_programs += 1
        self.n_commands += cmds
        self.max_banks = max(self.max_banks, banks)
        # fused chain traces attribute per_op charges to the constituent
        # stages (proportional to each stage's share of command sequences),
        # so per-op stall attribution survives fusion — the aggregate
        # chain gets no row of its own (it would double-count)
        shares, n_stage_ops = self._op_shares(prog, trace)
        self.elem_ops += lanes * banks * n_stage_ops
        entries = []
        for key, frac in shares:
            d = self.per_op.setdefault(key,
                                       {"calls": 0, "ns": 0.0, "nj": 0.0,
                                        "replay_ns": 0.0})
            d["calls"] += 1
            d["ns"] += lat * frac
            d["nj"] += en * banks * frac
            entries.append((d, frac))
        if self.mode == "replay" and replayable:
            # phase = the replay clock *before* this op starts
            phase_ns = self.replay_ns if self.refresh_phase else 0.0
            res = self._replay_cost(trace, banks, offsets, phase_ns)
            self.replay_ns += res.ns
            self.replay_stall_ns += res.stall_ns
            self.replay_tfaw_ns += res.tfaw_stall_ns
            self.replay_refresh_ns += res.refresh_stall_ns
            self.replay_bank_spread_ns += res.bank_spread_ns
            self.replay_nj += self.model.replay_energy_nj(
                prog, trace, banks=banks, result=res)
            for d, frac in entries:
                d["replay_ns"] += res.ns * frac

    def charge_banked_share(self, prog: UProgram, trace: LoweredTrace,
                            banks_total: int, banks_own: int,
                            lanes: int) -> None:
        """Charge this accumulator its *share* of one banked dispatch that
        several requests rode together (the batched drain path:
        :meth:`~repro.simdram.machine.SimdramMachine.drain` with
        ``batch=True``).

        The stacked execute issues ONE command stream to ``banks_total``
        banks and the machine accumulator takes the full banked
        :meth:`charge_program`; each rider owns ``banks_own`` of those
        banks.  Latency — a shared, overlapped quantity — is apportioned
        by bank fraction, and per-bank energy / element-ops are charged
        for the rider's own banks, so summing ``exec_ns`` / ``exec_nj`` /
        ``elem_ops`` (and, under the default per-op-anchored refresh
        phase, the replay meters) over all riders reproduces the banked
        machine charge exactly.  Counters (``n_programs``,
        ``n_commands``, ``per_op["calls"]``) count per rider — each rider
        did submit a request — so in batched drains the tenant-summed
        counters intentionally exceed the machine's dispatch counts.
        """
        lat, en, cmds = self._prog_cost(prog, trace)
        frac = banks_own / max(1, banks_total)
        self.exec_ns += lat * frac
        self.exec_nj += en * banks_own
        self.n_programs += 1
        self.n_commands += cmds
        self.max_banks = max(self.max_banks, banks_own)
        shares, n_stage_ops = self._op_shares(prog, trace)
        self.elem_ops += lanes * banks_own * n_stage_ops
        entries = []
        for key, share in shares:
            d = self.per_op.setdefault(key,
                                       {"calls": 0, "ns": 0.0, "nj": 0.0,
                                        "replay_ns": 0.0})
            d["calls"] += 1
            d["ns"] += lat * frac * share
            d["nj"] += en * banks_own * share
            entries.append((d, share))
        if self.mode == "replay":
            phase_ns = self.replay_ns if self.refresh_phase else 0.0
            res = self._replay_cost(trace, banks_total, None, phase_ns)
            self.replay_ns += res.ns * frac
            self.replay_stall_ns += res.stall_ns * frac
            self.replay_tfaw_ns += res.tfaw_stall_ns * frac
            self.replay_refresh_ns += res.refresh_stall_ns * frac
            self.replay_bank_spread_ns += res.bank_spread_ns * frac
            self.replay_nj += self.model.replay_energy_nj(
                prog, trace, banks=banks_total, result=res) * frac
            for d, share in entries:
                d["replay_ns"] += res.ns * frac * share

    def note_elided_movement(self, n_rows: int) -> None:
        """Count an inter-op relocation the fusion allocator removed:
        metered (so fused-vs-unfused hop deltas are provable from one
        snapshot) but never charged — the whole point of eliding it."""
        del n_rows          # the hop never happens; only its count matters
        self.n_moves_elided += 1

    def charge_movement(self, n_rows: int, inter_bank: bool = False) -> None:
        if inter_bank:
            self.movement_inter_ns += self.model.movement.inter_bank_ns(
                n_rows)
            self.n_moves_inter += 1
        else:
            self.movement_intra_ns += self.model.movement.intra_bank_ns(
                n_rows)
            self.n_moves_intra += 1

    def charge_transpose(self, n_bits: int, lanes: int,
                         kind: str = "to") -> None:
        ns = self.model.transposition.first_subarray_ns(n_bits, lanes)
        if kind == "from":
            self.transpose_from_ns += ns
            self.n_transposes_from += 1
        else:
            self.transpose_to_ns += ns
            self.n_transposes_to += 1

    def note_output(self, planes) -> None:
        """Track an op output for movement charging (FIFO-bounded)."""
        self._resident[id(planes)] = planes
        while len(self._resident) > _RESIDENT_CAP:
            del self._resident[next(iter(self._resident))]

    def note_bank_skew(self, banks: int, n_rows: int, planes,
                       machine=None) -> None:
        """Record the per-bank data-arrival skew of an inter-bank scatter,
        keyed to the scattered plane array: the redistributed rows ride the
        shared internal bus serially, so bank *k*'s plane stack is complete
        ``k × rows_per_bank × t_PSM`` after bank 0's.  The replayed program
        that *consumes those planes* takes the skew as its per-bank issue
        offsets (a one-shot: once the banks have executed an op they are
        back in step up to the FSM's own desynchronization).  The skew is
        scoped to the machine session it was recorded under (``machine``):
        a different machine replaying the same planes must not consume
        another session's offsets."""
        if self.mode != "replay" or banks <= 1 or planes is None:
            return      # analytic accumulators never read offsets
        per_bank_ns = self.model.movement.inter_bank_ns(n_rows) / banks
        skew = tuple(k * per_bank_ns for k in range(banks))
        self._bank_skew[id(planes)] = (skew, planes, machine)
        while len(self._bank_skew) > _RESIDENT_CAP:
            del self._bank_skew[next(iter(self._bank_skew))]

    def take_bank_skew(self, planes_id: int, banks: int, machine=None):
        """Consume the skew recorded for a scattered plane array (if its
        bank count matches the consuming op's).  A pending skew recorded
        under a *different* machine's session is left pending — the
        rightful machine's next replayed op still consumes it."""
        hit = self._bank_skew.get(planes_id)
        if hit is None or hit[2] is not machine:
            return None
        del self._bank_skew[planes_id]
        return hit[0] if len(hit[0]) == banks else None

    # -- aggregates ---------------------------------------------------------
    @property
    def movement_ns(self) -> float:
        return self.movement_intra_ns + self.movement_inter_ns

    @property
    def n_moves(self) -> int:
        return self.n_moves_intra + self.n_moves_inter

    @property
    def transpose_ns(self) -> float:
        return self.transpose_to_ns + self.transpose_from_ns

    @property
    def n_transposes(self) -> int:
        return self.n_transposes_to + self.n_transposes_from

    @property
    def total_ns(self) -> float:
        return self.exec_ns + self.movement_ns + self.transpose_ns

    @property
    def total_nj(self) -> float:
        return self.exec_nj

    @property
    def replay_total_ns(self) -> float:
        """Replayed end-to-end latency: FSM-replayed execution plus the
        (mode-independent) movement/transposition charges."""
        return self.replay_ns + self.movement_ns + self.transpose_ns

    def gops(self) -> float:
        """Effective element-ops per modeled nanosecond (= GOps/s), counting
        every engaged SIMD lane × bank and *all* modeled overheads."""
        return self.elem_ops / self.total_ns if self.total_ns else 0.0

    def gops_per_bank(self) -> float:
        return self.gops() / max(1, self.max_banks)

    def reset(self) -> None:
        fresh = PerfStats(model=self.model, mode=self.mode,
                          refresh_phase=self.refresh_phase)
        for f in dataclasses.fields(self):
            if f.name not in ("model", "mode", "refresh_phase", "owner"):
                setattr(self, f.name, getattr(fresh, f.name))

    def snapshot(self) -> dict:
        """Structured, machine-readable view of every meter — per-kind
        movement and transposition breakdowns, replay stall attribution,
        and the per-tenant rollup.  :meth:`report` renders from this;
        benchmarks and serving layers should consume the snapshot instead
        of parsing report text.  Values are plain floats/ints/dicts
        (recursively so for ``tenants``), safe to serialize as JSON."""
        snap = {
            "mode": self.mode,
            "refresh_phase": self.refresh_phase,
            "totals": {
                "ns": self.total_ns, "nj": self.total_nj,
                "gops": self.gops(), "gops_per_bank": self.gops_per_bank(),
                "elem_ops": self.elem_ops, "max_banks": self.max_banks,
                "replay_total_ns": self.replay_total_ns,
            },
            "execute": {
                "ns": self.exec_ns, "nj": self.exec_nj,
                "n_programs": self.n_programs,
                "n_commands": self.n_commands,
            },
            "replay": {
                "ns": self.replay_ns, "nj": self.replay_nj,
                "stall_ns": self.replay_stall_ns,
                "tfaw_stall_ns": self.replay_tfaw_ns,
                "refresh_stall_ns": self.replay_refresh_ns,
                "bank_spread_ns": self.replay_bank_spread_ns,
            },
            "movement": {
                "ns": self.movement_ns, "n": self.n_moves,
                "per_kind": {
                    "intra": {"ns": self.movement_intra_ns,
                              "n": self.n_moves_intra},
                    "inter": {"ns": self.movement_inter_ns,
                              "n": self.n_moves_inter},
                    "elided": {"ns": 0.0, "n": self.n_moves_elided},
                },
            },
            "transposition": {
                "ns": self.transpose_ns, "n": self.n_transposes,
                "per_kind": {
                    "to": {"ns": self.transpose_to_ns,
                           "n": self.n_transposes_to},
                    "from": {"ns": self.transpose_from_ns,
                             "n": self.n_transposes_from},
                },
            },
            "per_op": {op: dict(d) for op, d in self.per_op.items()},
            "tenants": {name: st.snapshot()
                        for name, st in self.tenants.items()},
        }
        return snap

    def report(self) -> str:
        snap = self.snapshot()
        tot, ex = snap["totals"], snap["execute"]
        mv, tr = snap["movement"], snap["transposition"]
        lines = [
            f"modeled DRAM cost: {tot['ns']:.1f} ns / "
            f"{tot['nj']:.1f} nJ  ({ex['n_programs']} μPrograms, "
            f"{ex['n_commands']} command sequences, "
            f"banks={tot['max_banks']})",
            f"  execute    {ex['ns']:12.1f} ns  {ex['nj']:10.1f} nJ",
        ]
        if snap["mode"] == "replay":
            rp = snap["replay"]
            lines += [
                f"  replayed   {rp['ns']:12.1f} ns  "
                f"{rp['nj']:10.1f} nJ  "
                f"(+{rp['stall_ns']:.1f} ns stall vs analytic)",
                f"    tFAW stalls     {rp['tfaw_stall_ns']:9.1f} ns   "
                f"refresh stalls {rp['refresh_stall_ns']:9.1f} ns "
                f"({'phase-threaded' if snap['refresh_phase'] else 'per-op anchored'})",
                f"    bank finish spread {rp['bank_spread_ns']:6.1f} ns"
                f"  (Σ per-op slowest − fastest bank)",
            ]
        lines += [
            f"  movement   {mv['ns']:12.1f} ns  "
            f"({mv['n']} relocations)",
            f"    intra-bank LISA {mv['per_kind']['intra']['ns']:9.1f} ns  "
            f"({mv['per_kind']['intra']['n']} hops)",
            f"    inter-bank PSM  {mv['per_kind']['inter']['ns']:9.1f} ns  "
            f"({mv['per_kind']['inter']['n']} transfers)",
        ]
        if mv["per_kind"]["elided"]["n"]:
            lines.append(
                f"    fusion-elided         0.0 ns  "
                f"({mv['per_kind']['elided']['n']} hops removed)")
        lines += [
            f"  transpose  {tr['ns']:12.1f} ns  "
            f"({tr['n']} passes)",
            f"    to_bitplanes    {tr['per_kind']['to']['ns']:9.1f} ns  "
            f"({tr['per_kind']['to']['n']} passes)",
            f"    from_bitplanes  {tr['per_kind']['from']['ns']:9.1f} ns  "
            f"({tr['per_kind']['from']['n']} passes)",
            f"  effective  {tot['gops']:.4f} GOps/s "
            f"({tot['gops_per_bank']:.4f} per bank)",
        ]
        for op, d in sorted(snap["per_op"].items()):
            extra = (f" {d['replay_ns']:10.1f} ns replayed"
                     if snap["mode"] == "replay" else "")
            lines.append(f"    {op:<24} ×{d['calls']:<4} {d['ns']:10.1f} ns "
                         f"{d['nj']:10.1f} nJ{extra}")
        for name, t in sorted(snap["tenants"].items()):
            lines.append(
                f"  tenant {name:<17} {t['totals']['ns']:10.1f} ns  "
                f"{t['totals']['nj']:10.1f} nJ  "
                f"({t['execute']['n_programs']} μPrograms, "
                f"{t['totals']['gops']:.4f} GOps/s)")
        return "\n".join(lines)


def active_stats() -> tuple["PerfStats", ...]:
    """The PerfStats currently charging (outermost first)."""
    return tuple(_ACTIVE_STATS)


def _default_model() -> SimdramPerfModel:
    """The perf model fresh accumulators charge with when none is given —
    the default machine's, so the ambient ``timed()`` surface and
    :class:`~repro.simdram.machine.SimdramMachine` sessions agree."""
    from ..simdram.machine import default_machine
    return default_machine().model


@contextlib.contextmanager
def timed(backend: str | None = None, stats: PerfStats | None = None,
          model: SimdramPerfModel | None = None, mode: str | None = None,
          refresh_phase: bool | None = None):
    """Scoped timed execution: every ``execute_program`` call and every
    transposition-unit pass inside the scope charges its modeled DRAM cost.

    ::

        with timed(backend="pallas") as stats:
            out = bbop_add(a, b, 8)
        print(stats.report())

    ``mode="replay"`` meters the cycle-accurate trace-replay substrate next
    to the analytic model (``stats.replay_ns`` / ``replay_nj``): one FSM
    per engaged bank, coupled by the rank-level tRRD/tFAW activation
    windows and tREFI/tRFC refresh windows of the model's ``DRAMTiming``
    (disable with ``tFAW_ns=0`` / ``tREFI_ns=0``; ``desync_policy=
    "lockstep"`` restores the legacy broadcast FSM).  The per-bank
    breakdown lands in ``replay_tfaw_ns`` / ``replay_refresh_ns`` /
    ``replay_bank_spread_ns`` and in ``report()``.  ``refresh_phase=True``
    (replay mode) threads the accumulated replay clock into each op's
    refresh-window grid, so refresh stall accrues across op boundaries in
    long chains instead of re-anchoring at every op's t=0.  Pass an
    existing ``stats`` to keep accumulating across scopes (e.g. one
    accumulator for a whole decode loop); nested scopes each observe every
    charge.  Yields the :class:`PerfStats`.
    """
    if stats is not None and model is not None and stats.model is not model:
        raise ValueError(
            "pass either an existing stats accumulator (charged with its "
            "own model) or a model for a fresh one, not both — a shared "
            "accumulator cannot switch models mid-flight")
    if stats is not None and mode is not None and stats.mode != mode:
        raise ValueError(
            f"stats accumulator runs in {stats.mode!r} mode; it cannot "
            f"switch to {mode!r} mid-flight — pass a fresh accumulator")
    if stats is not None and refresh_phase is not None \
            and stats.refresh_phase != refresh_phase:
        raise ValueError(
            "stats accumulator cannot switch refresh-phase threading "
            "mid-flight — pass a fresh accumulator")
    st = stats if stats is not None else PerfStats(
        model=model or _default_model(), mode=mode or "analytic",
        refresh_phase=bool(refresh_phase))
    ctx = use_backend(backend) if backend is not None \
        else contextlib.nullcontext()
    with ctx:
        # an accumulator already active (shared across nested scopes) is
        # not re-registered — it must charge once, not once per scope
        fresh = not any(s is st for s in _ACTIVE_STATS)
        if fresh:
            _ACTIVE_STATS.append(st)
        try:
            yield st
        finally:
            if fresh:
                for i in range(len(_ACTIVE_STATS) - 1, -1, -1):
                    if _ACTIVE_STATS[i] is st:
                        del _ACTIVE_STATS[i]
                        break
                # movement tracking is scoped: op outputs stop being
                # "resident" (and their memory is released) when the
                # accumulator's outermost scope closes; unconsumed scatter
                # skew dies with the scope too
                st._resident.clear()
                st._bank_skew.clear()


def _transpose_hook(kind: str, n_bits: int, lanes: int) -> None:
    for st in _charging_stats():
        st.charge_transpose(n_bits, lanes, kind=kind)


def _movement_hook(kind: str, n_rows: int, banks: int | None = None,
                   planes=None) -> None:
    inter = kind == "inter"
    eff = _current_machine()
    for st in _charging_stats(eff):
        if kind == "elided":
            # a hop the fusion allocator removed: counted, never charged
            st.note_elided_movement(n_rows)
            continue
        st.charge_movement(n_rows, inter_bank=inter)
        if inter and banks:
            # scatter: the serialized bus transfer desynchronizes the
            # banks; the skew is keyed to the session that scattered them
            st.note_bank_skew(banks, n_rows, planes, machine=eff)


register_transpose_hook(_transpose_hook)
register_movement_hook(_movement_hook)


def execute_program(prog: UProgram, operands: dict, out_bits=None,
                    backend: str | None = None) -> dict:
    """Lower a μProgram to its command trace (memoized) and dispatch it to
    a backend; banked operands vmap over banks.

    ``operands``: name → uint32[n_bits, W] or uint32[banks, n_bits, W];
    all operands must agree on bankedness.  Returns planes with a matching
    leading bank axis when the inputs were banked.  Inside a :func:`timed`
    scope, the call charges its modeled DRAM cost before dispatch (and, in
    replay mode, the FSM-replayed cost of the same trace).
    """
    return execute_lowered(prog, lower_program(prog), operands,
                           out_bits=out_bits, backend=backend)


def execute_lowered(prog: UProgram, trace: LoweredTrace, operands: dict,
                    out_bits=None, backend: str | None = None,
                    machine=None) -> dict:
    """Dispatch an already-lowered ``(μProgram, trace)`` pair to a backend.

    The seam per-machine μProgram Memories execute through: a
    :class:`~repro.core.trace.TraceCache` hands back its cached pair and
    nothing re-lowers.  Semantics are identical to :func:`execute_program`
    (which is this plus the process-wide lowering memo).  ``machine``
    attributes the work for accumulator filtering: machine-owned PerfStats
    only charge for their own machine's executions.
    """
    fn = get_backend(backend)
    first = next(iter(operands.values()))
    banked = first.ndim == 3
    if banked and any(v.ndim != 3 for v in operands.values()):
        raise ValueError("banked execution needs every operand banked")
    banks = first.shape[0] if banked else 1
    eff = machine if machine is not None else _current_machine()
    charging = _charging_stats(eff)
    for st in charging:
        offsets = None
        for planes in operands.values():
            if id(planes) in st._resident:
                # direct reuse of a prior op's output planes stays inside
                # the bank: an intra-bank LISA relocation per result row.
                # Inter-bank PSM traffic is charged where it actually
                # happens — BitplaneArray.rebank via the layout movement
                # hooks (bank layouts cannot silently change between an
                # op's output and a consumer's operand; rebank creates a
                # new array).
                st.charge_movement(int(planes.shape[-2]))
            skew = st.take_bank_skew(id(planes), banks, machine=eff)
            if skew is not None:
                # this op consumes freshly scattered planes: its per-bank
                # streams cannot start before each bank's data arrived
                # (two scattered operands gate on the later arrival)
                offsets = skew if offsets is None else tuple(
                    max(a, b) for a, b in zip(offsets, skew))
        st.charge_program(prog, banks, int(first.shape[-1]) * LANE_WORD,
                          trace=trace, offsets=offsets)
    if banked:                   # bank axis: one subarray per bank
        if not getattr(fn, "jax_traceable", True):
            # non-traceable backends (numpy oracle) iterate banks instead
            per = [fn(trace, {k: v[i] for k, v in operands.items()},
                      out_bits=out_bits) for i in range(banks)]
            outs = {k: jnp.stack([p[k] for p in per]) for k in per[0]}
        else:
            outs = jax.vmap(lambda ops: fn(trace, ops, out_bits=out_bits)
                            )(operands)
    else:
        outs = fn(trace, operands, out_bits=out_bits)
    for st in charging:
        for arr in outs.values():
            st.note_output(arr)
    return outs


def execute_heterogeneous(items, machine=None) -> list:
    """Execute a heterogeneous batch of lowered programs — the execution
    half of bank-level scheduling (:class:`~repro.simdram.scheduler
    .BankScheduler` models the timing half).

    ``items`` is a sequence of ``(prog, trace, operands, out_bits,
    backend)`` tuples with plane-level operands (``name →
    uint32[n_bits, W]``, unbanked).  Returns one output dict per item, in
    order.  Adjacent items that share the same trace, backend, out_bits
    and operand layout are *stacked along the bank axis* and dispatched as
    one banked :func:`execute_lowered` call — a tenant's stream of
    identical requests collapses into a handful of vmapped executions
    instead of one dispatch per request, exactly the bank-parallel
    placement the scheduler models.  The modeled charge is the banked
    charge (latency once, energy × the stacked width); per-request timing
    comes from the scheduler, not from here.
    """
    items = list(items)
    results: list = [None] * len(items)

    def _sig(item):
        prog, trace, ops, ob, be = item
        if any(v.ndim != 2 for v in ops.values()):
            return None              # banked operands: dispatch solo
        shapes = tuple((k, tuple(ops[k].shape), str(ops[k].dtype))
                       for k in sorted(ops))
        frozen_ob = None if ob is None else tuple(sorted(ob.items()))
        return (id(trace), be, frozen_ob, shapes)

    i = 0
    while i < len(items):
        prog, trace, ops, ob, be = items[i]
        sig = _sig(items[i])
        j = i + 1
        while sig is not None and j < len(items) and _sig(items[j]) == sig:
            j += 1
        if j - i > 1:
            stacked = {k: jnp.stack([items[x][2][k] for x in range(i, j)])
                       for k in ops}
            outs = execute_lowered(prog, trace, stacked, out_bits=ob,
                                   backend=be, machine=machine)
            for x in range(i, j):
                results[x] = {k: v[x - i] for k, v in outs.items()}
        else:
            results[i] = execute_lowered(prog, trace, ops, out_bits=ob,
                                         backend=be, machine=machine)
        i = j
    return results


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


def _unrolled_execute(trace: LoweredTrace, operands: dict,
                      out_bits=None) -> dict:
    from .unrolled import run_trace_unrolled
    return run_trace_unrolled(trace, operands, out_bits=out_bits)


def _pallas_execute(trace: LoweredTrace, operands: dict,
                    out_bits=None) -> dict:
    from ..kernels.ops import run_trace_kernel
    interpret = jax.default_backend() != "tpu"
    return run_trace_kernel(trace, operands, out_bits=out_bits,
                            interpret=interpret)


def _reference_execute(trace: LoweredTrace, operands: dict,
                       out_bits=None) -> dict:
    """Planes → horizontal numpy values → faithful Subarray run → planes.

    The trace is *decoded* back to μOps (:meth:`LoweredTrace.to_uprogram`)
    and executed on the stateful Subarray — exercising the IR's round-trip
    on every oracle run.  Conversions use the numpy layout twins (not the
    jnp transposition-unit path) so reference execution never perturbs
    TRANSPOSE_STATS.
    """
    from ..core.executor import from_planes, run_program
    from ..simdram.layout import LANE_WORD, np_from_bitplanes, np_to_bitplanes

    prog = trace.to_uprogram()
    vals = {}
    lanes = None
    for name, planes in operands.items():
        p = np.asarray(planes)
        lanes = p.shape[1] * LANE_WORD
        vals[name] = np_from_bitplanes(p).astype(np.int64)
    # the Subarray packs 64 lanes per word — round the lane count up
    run_lanes = ((lanes + 63) // 64) * 64
    if run_lanes != lanes:
        vals = {k: np.pad(v, (0, run_lanes - lanes)) for k, v in vals.items()}
    outs, _ = run_program(prog, vals, lanes=run_lanes, out_bits=out_bits)
    out_bits = out_bits or {}
    result = {}
    for name, planes64 in outs.items():
        nb = out_bits.get(name, prog.n_bits)
        horizontal = from_planes(planes64, run_lanes)[:lanes]
        result[name] = jnp.asarray(
            np_to_bitplanes(horizontal.astype(np.uint64), nb))
    return result


_reference_execute.jax_traceable = False

register_backend("unrolled", _unrolled_execute)
register_backend("pallas", _pallas_execute)
register_backend("reference", _reference_execute)
