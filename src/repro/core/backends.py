"""Pluggable μProgram execution backends (the Step-3 seam).

Every backend consumes the same compiled :class:`~repro.core.uprogram.UProgram`
and the same plane-level operand format — ``name → uint32[n_bits, W]`` bit
planes (optionally ``uint32[banks, n_bits, W]`` for the paper's multi-bank
scaling) — and returns output planes.  Registered backends:

* ``reference`` — the faithful numpy :class:`~repro.core.executor.Subarray`
  model: exact AAP/AP semantics, destructive TRAs, DCC ports.  The oracle.
* ``unrolled``  — trace-time unrolled jnp dataflow
  (:func:`repro.core.unrolled.run_unrolled`): copies vanish, constants fold;
  the TPU-native fast path.  jit/vmap/shard-compatible.
* ``pallas``    — the Fig.-7 control-unit FSM as a Pallas kernel
  (:func:`repro.kernels.ops.run_uprogram_kernel`): encoded AAP/AP command
  stream driving a VMEM row file.  ``interpret=True`` runs it on CPU; on a
  real TPU the same kernel is the explicitly-tiled memory-traffic path.

New substrates (real-DRAM timing models, GPU bit-slice engines, …) plug in
with :func:`register_backend` and are immediately usable from every
``bbop_*`` and from :class:`~repro.ops.bbops.simdram_pipeline` via
``backend="name"``.
"""
from __future__ import annotations

import contextlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .uprogram import UProgram

# backend: (prog, operands: dict[str, uint32[n_bits, W]], out_bits) → outputs
BackendFn = Callable[..., dict]

_REGISTRY: dict[str, BackendFn] = {}
_DEFAULT = "unrolled"


def register_backend(name: str, fn: BackendFn) -> None:
    _REGISTRY[name] = fn


def list_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str | None = None) -> BackendFn:
    key = name or _DEFAULT
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(f"unknown backend {key!r}; registered: "
                       f"{list_backends()}") from None


def default_backend() -> str:
    return _DEFAULT


def set_default_backend(name: str) -> None:
    global _DEFAULT
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{list_backends()}")
    _DEFAULT = name


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped default-backend override: ``with use_backend("pallas"): ...``"""
    global _DEFAULT
    prev = _DEFAULT
    set_default_backend(name)
    try:
        yield
    finally:
        _DEFAULT = prev


def execute_program(prog: UProgram, operands: dict, out_bits=None,
                    backend: str | None = None) -> dict:
    """Dispatch a μProgram to a backend; banked operands vmap over banks.

    ``operands``: name → uint32[n_bits, W] or uint32[banks, n_bits, W];
    all operands must agree on bankedness.  Returns planes with a matching
    leading bank axis when the inputs were banked.
    """
    fn = get_backend(backend)
    first = next(iter(operands.values()))
    if first.ndim == 3:          # bank axis: one subarray per bank
        if any(v.ndim != 3 for v in operands.values()):
            raise ValueError("banked execution needs every operand banked")
        if not getattr(fn, "jax_traceable", True):
            # non-traceable backends (numpy oracle) iterate banks instead
            banks = first.shape[0]
            per = [fn(prog, {k: v[i] for k, v in operands.items()},
                      out_bits=out_bits) for i in range(banks)]
            return {k: jnp.stack([p[k] for p in per]) for k in per[0]}
        return jax.vmap(lambda ops: fn(prog, ops, out_bits=out_bits)
                        )(operands)
    return fn(prog, operands, out_bits=out_bits)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


def _unrolled_execute(prog: UProgram, operands: dict, out_bits=None) -> dict:
    from .unrolled import run_unrolled
    return run_unrolled(prog, operands, out_bits=out_bits)


def _pallas_execute(prog: UProgram, operands: dict, out_bits=None) -> dict:
    from ..kernels.ops import run_uprogram_kernel
    interpret = jax.default_backend() != "tpu"
    return run_uprogram_kernel(prog, operands, out_bits=out_bits,
                               interpret=interpret)


def _reference_execute(prog: UProgram, operands: dict, out_bits=None) -> dict:
    """Planes → horizontal numpy values → faithful Subarray run → planes.

    Conversions use the numpy layout twins (not the jnp transposition-unit
    path) so reference execution never perturbs TRANSPOSE_STATS.
    """
    from ..core.executor import from_planes, run_program
    from ..simdram.layout import LANE_WORD, np_from_bitplanes, np_to_bitplanes

    vals = {}
    lanes = None
    for name, planes in operands.items():
        p = np.asarray(planes)
        lanes = p.shape[1] * LANE_WORD
        vals[name] = np_from_bitplanes(p).astype(np.int64)
    # the Subarray packs 64 lanes per word — round the lane count up
    run_lanes = ((lanes + 63) // 64) * 64
    if run_lanes != lanes:
        vals = {k: np.pad(v, (0, run_lanes - lanes)) for k, v in vals.items()}
    outs, _ = run_program(prog, vals, lanes=run_lanes, out_bits=out_bits)
    out_bits = out_bits or {}
    result = {}
    for name, planes64 in outs.items():
        nb = out_bits.get(name, prog.n_bits)
        horizontal = from_planes(planes64, run_lanes)[:lanes]
        result[name] = jnp.asarray(
            np_to_bitplanes(horizontal.astype(np.uint64), nb))
    return result


_reference_execute.jax_traceable = False

register_backend("unrolled", _unrolled_execute)
register_backend("pallas", _pallas_execute)
register_backend("reference", _reference_execute)
