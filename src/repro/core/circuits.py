"""AOIG circuit definitions for the 16 SIMDRAM operations (paper §4.4).

Every operation enters the framework as an AND/OR/NOT description (AOIG) of
its 1-bit slice — exactly the paper's Step-1 input — and is synthesized to an
optimized MIG by ``repro.core.synthesis`` before μProgram generation.

Operation classes (paper Table 5):
  class 1 (linear):    abs, addition, bitcount, max, min, ReLU, subtraction,
                       if_else, equal, greater, greater_equal
  class 2 (log):       and_reduction, or_reduction, xor_reduction
  class 3 (quadratic): multiplication, division
"""
from __future__ import annotations

import dataclasses

from .compiler import SliceSpec, compile_flat, compile_slice
from .graph import CONST0, LogicGraph, lit_not
from .uprogram import AAP, C0, DRow, UProgram, concat_programs

# ---------------------------------------------------------------------------
# 1-bit slice AOIGs (class-1 ops)
# ---------------------------------------------------------------------------


def _full_add(g: LogicGraph, a: int, b: int, c: int) -> tuple[int, int]:
    """(sum, carry) as AOIG — the paper's Fig. 15a structure."""
    axb = g.gate_xor(a, b)
    s = g.gate_xor(axb, c)
    cout = g.gate_or_node(g.gate_and(a, b), g.gate_and(c, axb))
    return s, cout


def build_add(g: LogicGraph) -> None:
    a, b, c = g.input("a"), g.input("b"), g.input("carry")
    s, cout = _full_add(g, a, b, c)
    g.add_output("out", s)
    g.add_output("carry", cout)


def build_sub(g: LogicGraph) -> None:
    # a - b via borrow: d = a ⊕ b ⊕ w ;  w' = ¬a·b + w·(a XNOR b)
    a, b, w = g.input("a"), g.input("b"), g.input("borrow")
    axb = g.gate_xor(a, b)
    d = g.gate_xor(axb, w)
    wn = g.gate_or_node(g.gate_and(lit_not(a), b), g.gate_and(w, lit_not(axb)))
    g.add_output("out", d)
    g.add_output("borrow", wn)


def build_greater(g: LogicGraph) -> None:
    # src1 > src2  ⇔  borrow-out of (src2 - src1); scan LSB→MSB
    a, b, w = g.input("a"), g.input("b"), g.input("gt")
    axb = g.gate_xor(a, b)
    wn = g.gate_or_node(g.gate_and(a, lit_not(b)), g.gate_and(w, lit_not(axb)))
    g.add_output("gt", wn)


def build_greater_equal(g: LogicGraph) -> None:
    # src1 >= src2 ⇔ ¬ borrow-out of (src1 - src2)
    a, b, w = g.input("a"), g.input("b"), g.input("lt")
    axb = g.gate_xor(a, b)
    wn = g.gate_or_node(g.gate_and(lit_not(a), b), g.gate_and(w, lit_not(axb)))
    g.add_output("lt", wn)
    g.add_output("ge", lit_not(wn))


def build_equal(g: LogicGraph) -> None:
    # running neq' = neq | (a ⊕ b); final eq = ¬neq
    a, b, q = g.input("a"), g.input("b"), g.input("neq")
    nq = g.gate_or_node(q, g.gate_xor(a, b))
    g.add_output("neq", nq)
    g.add_output("eq", lit_not(nq))


def build_if_else(g: LogicGraph) -> None:
    s, a, b = g.input("sel"), g.input("a"), g.input("b")
    g.add_output("out", g.gate_mux(s, a, b))


def build_relu(g: LogicGraph) -> None:
    # out = ¬sign · x  (sign = MSB row, loop-invariant binding)
    s, a = g.input("sgn"), g.input("a")
    g.add_output("out", g.gate_and(lit_not(s), a))


def build_abs(g: LogicGraph) -> None:
    # |x| = (x ⊕ s) + s, s = sign bit: slice is t = a⊕s with half-add carry
    s, a, c = g.input("sgn"), g.input("a"), g.input("carry")
    t = g.gate_xor(a, s)
    g.add_output("out", g.gate_xor(t, c))
    g.add_output("carry", g.gate_and(t, c))


def build_gated_add(g: LogicGraph) -> None:
    """acc += a·gate  (the inner slice of multiplication)."""
    acc, a, gate, c = g.input("acc"), g.input("a"), g.input("gate"), g.input("carry")
    t = g.gate_and(a, gate)
    s, cout = _full_add(g, acc, t, c)
    g.add_output("out", s)
    g.add_output("carry", cout)


def _nary(g: LogicGraph, op: str, n_srcs: int) -> None:
    ins = [g.input(f"s{k}") for k in range(n_srcs)]
    acc = ins[0]
    for x in ins[1:]:
        if op == "and":
            acc = g.gate_and(acc, x)
        elif op == "or":
            acc = g.gate_or_node(acc, x)
        else:
            acc = g.gate_xor(acc, x)
    g.add_output("out", acc)


def build_and_reduction(g: LogicGraph, n_srcs: int = 3) -> None:
    _nary(g, "and", n_srcs)


def build_or_reduction(g: LogicGraph, n_srcs: int = 3) -> None:
    _nary(g, "or", n_srcs)


def build_xor_reduction(g: LogicGraph, n_srcs: int = 3) -> None:
    _nary(g, "xor", n_srcs)


# ---------------------------------------------------------------------------
# Slice specs (class-1 / class-2)
# ---------------------------------------------------------------------------


def spec_add() -> SliceSpec:
    return SliceSpec("addition", build_add, ("a", "b"), states={"carry": 0})


def spec_sub() -> SliceSpec:
    return SliceSpec("subtraction", build_sub, ("a", "b"), states={"borrow": 0})


def spec_greater() -> SliceSpec:
    return SliceSpec("greater", build_greater, ("a", "b"), states={"gt": 0},
                     out_array=None, epilogue_outputs={"gt": ("out", 0)})


def spec_greater_equal() -> SliceSpec:
    return SliceSpec("greater_equal", build_greater_equal, ("a", "b"),
                     states={"lt": 0}, out_array=None,
                     epilogue_outputs={"ge": ("out", 0)})


def spec_equal() -> SliceSpec:
    return SliceSpec("equal", build_equal, ("a", "b"), states={"neq": 0},
                     out_array=None, epilogue_outputs={"eq": ("out", 0)})


def spec_if_else() -> SliceSpec:
    return SliceSpec("if_else", build_if_else, ("a", "b"),
                     invariants={"sel": DRow("sel", 0, fixed=True)})


def spec_relu(n_bits: int) -> SliceSpec:
    return SliceSpec("relu", build_relu, ("a",),
                     invariants={"sgn": DRow("a", n_bits - 1, fixed=True)})


def spec_abs(n_bits: int) -> SliceSpec:
    return SliceSpec("abs", build_abs, ("a",),
                     invariants={"sgn": DRow("a", n_bits - 1, fixed=True)},
                     states={"carry": DRow("a", n_bits - 1, fixed=True)})


def spec_reduction(kind: str, n_srcs: int = 3) -> SliceSpec:
    build = {"and": build_and_reduction, "or": build_or_reduction,
             "xor": build_xor_reduction}[kind]
    return SliceSpec(f"{kind}_reduction",
                     lambda g: build(g, n_srcs),
                     tuple(f"s{k}" for k in range(n_srcs)))


def spec_gated_add() -> SliceSpec:
    return SliceSpec("gated_add", build_gated_add, ("acc", "a"),
                     invariants={"gate": DRow("gate", 0, fixed=True)},
                     states={"carry": 0}, out_array="acc")


# ---------------------------------------------------------------------------
# Rebasing helper for composite ops
# ---------------------------------------------------------------------------


def rebase(prog: UProgram, offsets: dict[str, int],
           renames: dict[str, str] | None = None) -> UProgram:
    """Shift/rename D-row arrays of a compiled μProgram (composite ops)."""
    renames = renames or {}

    def fix(r):
        if isinstance(r, DRow):
            arr = renames.get(r.array, r.array)
            return DRow(arr, r.bit + offsets.get(r.array, 0), r.fixed)
        return r

    def fix_uop(u):
        if isinstance(u, AAP):
            src = u.src if isinstance(u.src, tuple) else fix(u.src)
            return AAP(src, tuple(fix(d) for d in u.dsts))
        return u

    return UProgram(name=prog.name, n_bits=prog.n_bits,
                    prologue=[fix_uop(u) for u in prog.prologue],
                    body=[fix_uop(u) for u in prog.body],
                    epilogue=[fix_uop(u) for u in prog.epilogue],
                    body_reps=prog.body_reps, inputs=prog.inputs,
                    outputs=prog.outputs, scratch=prog.scratch)


# ---------------------------------------------------------------------------
# Composite operations (class-3 + tree ops)
# ---------------------------------------------------------------------------


def compile_max(n_bits: int, minimum: bool = False, optimize: bool = True) -> UProgram:
    """max/min = greater(a,b) feeding a predicated select (paper: 10n+2)."""
    gt = compile_slice(spec_greater(), n_bits, optimize=optimize)
    gt = rebase(gt, {}, {"out": "_gtrow"})
    sel = compile_slice(spec_if_else(), n_bits, optimize=optimize)
    if minimum:
        sel = rebase(sel, {}, {"a": "b", "b": "a", "sel": "_gtrow"})
    else:
        sel = rebase(sel, {}, {"sel": "_gtrow"})
    return concat_programs("minimum" if minimum else "maximum",
                           [gt, sel], n_bits, inputs=("a", "b"),
                           outputs=("out",), scratch=("_gtrow",))


def compile_bitcount(n_bits: int, optimize: bool = True) -> UProgram:
    """Popcount over the n bit-rows of each element via a CSA/adder tree of
    full adders (cost ≈ 8 per FA ⇒ ~8n, matching Table 5's Ω=8n−8log(n+1))."""
    g = LogicGraph()
    bits = [(g.input(f"a{i}"), 0) for i in range(n_bits)]  # (lit, weight)
    out_width = max(1, (n_bits).bit_length())
    columns: dict[int, list[int]] = {}
    for lit, w in bits:
        columns.setdefault(0, []).append(lit)
    weight = 0
    while weight < out_width:
        col = columns.get(weight, [])
        while len(col) >= 3:
            a, b, c = col.pop(), col.pop(), col.pop()
            s, k = _full_add(g, a, b, c)
            col.append(s)
            columns.setdefault(weight + 1, []).append(k)
        while len(col) >= 2:
            a, b = col.pop(), col.pop()
            s, k = _full_add(g, a, b, CONST0)   # half adder
            col.append(s)
            columns.setdefault(weight + 1, []).append(k)
        g.add_output(f"out{weight}", col[0] if col else CONST0)
        weight += 1
    binding = {f"a{i}": DRow("a", i, fixed=True) for i in range(n_bits)}
    targets = {f"out{w}": DRow("out", w, fixed=True) for w in range(out_width)}
    prog = compile_flat("bitcount", g, binding, targets, n_bits,
                        optimize=optimize)
    prog.inputs, prog.outputs = ("a",), ("out",)
    return prog


def compile_multiplication(n_bits: int, optimize: bool = True) -> UProgram:
    """Truncating n×n→n multiply: n gated-add passes; shifts are free row
    re-indexing (vertical layout).  Paper: 11n²−5n−1 (class 3)."""
    progs: list[UProgram] = []
    # zero the accumulator rows
    zero = UProgram("mul_zero", n_bits,
                    prologue=[AAP(C0, (DRow("out", i, fixed=True),))
                              for i in range(n_bits)], body=[], body_reps=0)
    progs.append(zero)
    base = compile_slice(spec_gated_add(), n_bits, optimize=optimize)
    for j in range(n_bits):
        pj = rebase(base, {"acc": j, "gate": j},
                    renames={"acc": "out", "gate": "b"})
        pj = dataclasses.replace(pj, body_reps=n_bits - j, name=f"mul_pass{j}")
        progs.append(pj)
    return concat_programs("multiplication", progs, n_bits,
                           inputs=("a", "b"), outputs=("out",))


def compile_division(n_bits: int, optimize: bool = True) -> UProgram:
    """Restoring long division (unsigned): quotient in 'out', remainder in the
    final R window.  The left-shift of the remainder each step is *free*: the
    R window simply slides down one row index (the paper's 'changing the row
    indices' optimization for shifts under vertical layout).  Paper reports
    8n²+12n with a non-restoring scheme; our restoring scheme is ~16n² —
    recorded as a deviation in EXPERIMENTS.md."""
    from .uprogram import P_DCC0, P_NDCC0

    n = n_bits
    progs: list[UProgram] = []
    # R value at step j occupies rows R[j .. j+n] (LSB at R[j]).
    # zero the initial window rows [n .. 2n-1]
    init_ops = [AAP(C0, (DRow("R", n + k, fixed=True),)) for k in range(n)]
    # _bx = b zero-extended to n+1 bits
    init_ops += [AAP(DRow("b", i, fixed=True), (DRow("_bx", i, fixed=True),))
                 for i in range(n)]
    init_ops.append(AAP(C0, (DRow("_bx", n, fixed=True),)))
    progs.append(UProgram("div_init", n, prologue=init_ops, body=[], body_reps=0))

    sub = compile_slice(
        SliceSpec("div_sub", build_sub, ("a", "b"), states={"borrow": 0},
                  epilogue_outputs={"borrow": ("_q", 0)}), n + 1,
        optimize=optimize)
    mux = compile_slice(spec_if_else(), n + 1, optimize=optimize)
    for step in range(n - 1, -1, -1):
        # shift-in: new LSB of the window is a[step]
        progs.append(UProgram(f"div_in{step}", n, prologue=[
            AAP(DRow("a", step, fixed=True), (DRow("R", step, fixed=True),))],
            body=[], body_reps=0))
        # _t = R_window - _bx ; borrow → _q[0]
        s = rebase(sub, {"a": step}, renames={"a": "R", "b": "_bx", "out": "_t"})
        s = dataclasses.replace(s, name=f"div_sub{step}")
        progs.append(s)
        # quotient bit = ¬borrow (routed through a dual-contact cell)
        progs.append(UProgram(f"div_q{step}", n, prologue=[
            AAP(DRow("_q", 0, fixed=True), (P_DCC0,)),
            AAP(P_NDCC0, (DRow("out", step, fixed=True),))],
            body=[], body_reps=0))
        # restore: R = borrow ? R : _t
        m = rebase(mux, {"a": step, "out": step},
                   renames={"a": "R", "b": "_t", "out": "R", "sel": "_q"})
        m = dataclasses.replace(m, name=f"div_mux{step}")
        progs.append(m)
    return concat_programs("division", progs, n,
                           inputs=("a", "b"), outputs=("out",),
                           scratch=("R", "_t", "_bx", "_q"))


# ---------------------------------------------------------------------------
# Operation registry — the framework's programmable op table
# ---------------------------------------------------------------------------
#
# The paper's pitch is the *framework*, not the 16 built-in operations: any
# AOIG a programmer supplies runs through the same Step-1/2/3 pipeline.  The
# op table is therefore a registry, not a hardcoded dispatch: the 16 Table-5
# operations register at import, and user operations join at runtime via
# :func:`register_operation` (process-wide) or, scoped to one session,
# ``SimdramMachine.define_op`` (:mod:`repro.simdram.machine`).

CLASS_OF = {
    "abs": 1, "addition": 1, "bitcount": 1, "maximum": 1, "minimum": 1,
    "relu": 1, "subtraction": 1, "if_else": 1, "equal": 1, "greater": 1,
    "greater_equal": 1, "and_reduction": 2, "or_reduction": 2,
    "xor_reduction": 2, "multiplication": 3, "division": 3,
}

PAPER_COUNTS = {  # Table 5 closed forms
    "abs": lambda n: 10 * n - 2,
    "addition": lambda n: 8 * n + 1,
    "bitcount": lambda n: 8 * n,
    "division": lambda n: 8 * n * n + 12 * n,
    "maximum": lambda n: 10 * n + 2,
    "minimum": lambda n: 10 * n + 2,
    "multiplication": lambda n: 11 * n * n - 5 * n - 1,
    "relu": lambda n: 3 * n + ((n - 1) % 2),
    "subtraction": lambda n: 8 * n + 1,
    "if_else": lambda n: 7 * n,
    "and_reduction": lambda n: 5 * (n // 2) + 2,
    "or_reduction": lambda n: 5 * (n // 2) + 2,
    "xor_reduction": lambda n: 6 * (n // 2) + 1,
    "equal": lambda n: 4 * n + 3,
    "greater": lambda n: 3 * n + 2,
    "greater_equal": lambda n: 3 * n + 2,
}

# the 16 built-ins, frozen before any user registration can extend CLASS_OF
ALL_OPS = tuple(CLASS_OF)


@dataclasses.dataclass(frozen=True)
class OperationDef:
    """One registered operation: a compile entry point plus metadata.

    ``compile_fn(n_bits, optimize)`` must return a fully-formed
    :class:`~repro.core.uprogram.UProgram` — anything built from
    :func:`~repro.core.compiler.compile_slice` /
    :func:`~repro.core.compiler.compile_flat` / :func:`rebase` /
    :func:`~repro.core.uprogram.concat_programs` qualifies.
    """

    name: str
    compile_fn: object            # (n_bits: int, optimize: bool) -> UProgram
    op_class: int | None = None   # paper Table-5 class (1/2/3), if meaningful
    builtin: bool = False


_OPERATIONS: dict[str, OperationDef] = {}


def register_operation(name: str, compile_fn, *, op_class: int | None = None,
                       paper_count=None, override: bool = False,
                       _builtin: bool = False) -> OperationDef:
    """Register a new operation with the process-wide op table.

    After registration the operation is a first-class citizen of the whole
    framework: :func:`compile_operation`, the compile/lower cache
    (:func:`repro.core.trace.compile_trace`), every execution backend and
    the replay-timing substrate all pick it up with no other change.
    ``paper_count`` optionally records a closed-form command count (joins
    ``PAPER_COUNTS``); ``override=True`` replaces an existing non-builtin
    registration.
    """
    if not callable(compile_fn):
        raise TypeError(f"compile_fn for {name!r} must be callable")
    existing = _OPERATIONS.get(name)
    if existing is not None:
        if existing.builtin:
            raise ValueError(f"cannot override built-in operation {name!r}")
        if not override:
            raise ValueError(f"operation {name!r} already registered "
                             "(pass override=True to replace it)")
    d = OperationDef(name, compile_fn, op_class, _builtin)
    _OPERATIONS[name] = d
    if op_class is not None:
        CLASS_OF[name] = op_class
    if paper_count is not None:
        PAPER_COUNTS[name] = paper_count
    if existing is not None:
        _drop_cached_compiles(name)
    return d


def unregister_operation(name: str) -> None:
    """Remove a user-registered operation (built-ins are protected)."""
    d = _OPERATIONS.get(name)
    if d is None:
        return
    if d.builtin:
        raise ValueError(f"cannot unregister built-in operation {name!r}")
    del _OPERATIONS[name]
    if name not in ALL_OPS:
        CLASS_OF.pop(name, None)
        PAPER_COUNTS.pop(name, None)
    _drop_cached_compiles(name)


def _drop_cached_compiles(name: str) -> None:
    """A replaced or removed registration must also leave every live
    compile/lower cache — private machine memories resolve unknown names
    through this registry, so the stale compile could otherwise keep
    executing out of any of them."""
    from .trace import invalidate_everywhere
    invalidate_everywhere(name)


def get_operation(name: str) -> OperationDef:
    try:
        return _OPERATIONS[name]
    except KeyError:
        raise KeyError(name) from None


def list_operations() -> tuple[str, ...]:
    """Every registered operation name (built-ins + user registrations)."""
    return tuple(sorted(_OPERATIONS))


def compile_operation(name: str, n_bits: int, optimize: bool = True) -> UProgram:
    """Compile any registered SIMDRAM operation for n-bit elements.

    ``optimize=False`` skips Step-1 MIG optimization, yielding the naive
    AND/OR/NOT-equivalent command stream — this is the paper's Ambit
    baseline (§6: 'evaluate all 16 SIMDRAM operations in Ambit using their
    equivalent AND/OR/NOT-based implementations').
    """
    return get_operation(name).compile_fn(n_bits, optimize)


def _register_builtins() -> None:
    def slice_of(spec_fn, per_width: bool = False):
        if per_width:
            return lambda n, opt=True: compile_slice(spec_fn(n), n,
                                                     optimize=opt)
        return lambda n, opt=True: compile_slice(spec_fn(), n, optimize=opt)

    builtins = {
        "addition": slice_of(spec_add),
        "subtraction": slice_of(spec_sub),
        "greater": slice_of(spec_greater),
        "greater_equal": slice_of(spec_greater_equal),
        "equal": slice_of(spec_equal),
        "if_else": slice_of(spec_if_else),
        "relu": slice_of(spec_relu, per_width=True),
        "abs": slice_of(spec_abs, per_width=True),
        "and_reduction": lambda n, opt=True: compile_slice(
            spec_reduction("and"), n, optimize=opt),
        "or_reduction": lambda n, opt=True: compile_slice(
            spec_reduction("or"), n, optimize=opt),
        "xor_reduction": lambda n, opt=True: compile_slice(
            spec_reduction("xor"), n, optimize=opt),
        "maximum": lambda n, opt=True: compile_max(n, optimize=opt),
        "minimum": lambda n, opt=True: compile_max(n, minimum=True,
                                                   optimize=opt),
        "bitcount": lambda n, opt=True: compile_bitcount(n, optimize=opt),
        "multiplication": lambda n, opt=True: compile_multiplication(
            n, optimize=opt),
        "division": lambda n, opt=True: compile_division(n, optimize=opt),
    }
    assert set(builtins) == set(ALL_OPS)
    for name, fn in builtins.items():
        register_operation(name, fn, op_class=CLASS_OF[name], _builtin=True)


_register_builtins()
