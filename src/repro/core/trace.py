"""Lowered command-trace IR — the single form every substrate executes.

The paper's control unit (Fig. 7) executes one thing: a linear stream of
AAP/AP command sequences.  :class:`LoweredTrace` is that stream as data —
an int32 command array plus the row-index map that binds symbolic row
references (D rows, C rows, B-group cells) to physical row numbers — and is
produced exactly once per compiled μProgram.  All registered backends
consume it: the ``reference`` oracle decodes it back to μOps, ``unrolled``
and ``pallas`` scan the command array directly, and the trace-replay timing
substrate (:mod:`repro.simdram.timing`) replays it against per-bank DRAM
timing state machines.

Command encoding (int32[N, 4], shared with the Pallas FSM kernel in
:mod:`repro.kernels.uprog_executor`)::

    (op, a, b, c)
    op = CMD_COPY (0): row|a| ← read(b)                               (AAP)
    op = CMD_MAJ  (1): rows |a|,|b|,|c| ← MAJ(read(a),read(b),read(c)) (AP)

Row operands are 1-based; a negative index reads/writes through a
dual-contact cell's n-wordline (complement).  The C0/C1 constant rows are
ordinary rows pre-filled with zeros/ones.

Because a multi-destination AAP lowers to several COPY commands and a
Case-2 fused AAP lowers to MAJ + COPY, the executable array alone cannot
reproduce command-sequence structure (which both the Table-5 accounting and
the DRAM timing FSM need: one AAP is one ACT-ACT-PRE regardless of how many
destination rows its pair address covers).  ``seqs`` therefore records, per
original command sequence, its kind and its span of command rows::

    seqs int32[M, 3] = (kind, start, end)       # cmds[start:end]
    kind = SEQ_AAP (0) | SEQ_AP (1) | SEQ_AAP_TRA (2, Case-2 fused)

The module also owns the process-wide **compile/lower cache**: the paper's
μProgram Memory holds the 16 compiled operations once, and
:func:`compile_trace` mirrors it — synthesis, row allocation and lowering
run once per ``(op, n_bits, optimize)`` and every later ``bbop_*`` call
(including chained pipelines and ``greedy_decode`` sampling) fetches the
finished trace.  Hit/miss counters are exposed for the benchmark gate.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import weakref

import numpy as np

from .uprogram import (AAP, AP, C0, C1, CRow, DRow, N_B_CELLS, Port,
                       UProgram, normalize_uop)

# command opcodes (shared with the Pallas FSM kernel)
CMD_COPY, CMD_MAJ = 0, 1
# command-sequence kinds
SEQ_AAP, SEQ_AP, SEQ_AAP_TRA = 0, 1, 2

# per-ACT gap-kind codes (see :meth:`LoweredTrace.act_structure`) — the
# timing-independent skeleton the vectorized replay engine compiles each
# trace to.  Code k tells how the k-th activation follows its predecessor
# on the same bank: the stream's first ACT has no predecessor (START), the
# back-to-back second ACT of an AAP issues tRAS later (RAS), and the first
# ACT of every later sequence issues tRC after the previous sequence's
# final ACT (RC).  The replay engine maps codes to cycle counts for its
# own DRAMTiming, so one compiled structure serves every timing.
ACT_GAP_START, ACT_GAP_RAS, ACT_GAP_RC = 0, 1, 2


# ---------------------------------------------------------------------------
# Encoding
# ---------------------------------------------------------------------------


def _encode_ref(ref, row_index: dict) -> int:
    if isinstance(ref, Port):
        base = row_index[("cell", ref.cell)]
        return -base if ref.neg else base
    if isinstance(ref, CRow):
        return row_index["C1"] if ref.one else row_index["C0"]
    if isinstance(ref, DRow):
        return row_index[(ref.array, ref.bit)]
    raise TypeError(ref)


def encode_uops(uops, row_index: dict) -> tuple[np.ndarray, np.ndarray]:
    """Flattened μOps → (cmds int32[N,4], seqs int32[M,3])."""
    def tra_ports(ports) -> tuple:
        # triple-row-activation addresses decode B-group μRegisters only
        # (paper §3.1) — a clear error here beats a KeyError mid-encode
        if not all(isinstance(p, Port) for p in ports):
            raise TypeError(f"TRA operands must be B-group ports, got "
                            f"{tuple(map(str, ports))}")
        return tuple(_encode_ref(p, row_index) for p in ports)

    cmds: list[tuple[int, int, int, int]] = []
    seqs: list[tuple[int, int, int]] = []
    for u in uops:
        start = len(cmds)
        if isinstance(u, AP):
            a, b, c = tra_ports(u.ports)
            cmds.append((CMD_MAJ, a, b, c))
            kind = SEQ_AP
        elif isinstance(u, AAP):
            if isinstance(u.src, tuple):
                a, b, c = tra_ports(u.src)
                cmds.append((CMD_MAJ, a, b, c))
                src = a
                kind = SEQ_AAP_TRA
            else:
                src = _encode_ref(u.src, row_index)
                kind = SEQ_AAP
            for d in u.dsts:
                cmds.append((CMD_COPY, _encode_ref(d, row_index), src, src))
        else:
            raise TypeError(u)
        seqs.append((kind, start, len(cmds)))
    return (np.asarray(cmds, np.int32).reshape(-1, 4),
            np.asarray(seqs, np.int32).reshape(-1, 3))


def _uop_drows(u) -> list[DRow]:
    rows = []
    if isinstance(u, AAP):
        if isinstance(u.src, DRow):
            rows.append(u.src)
        rows.extend(d for d in u.dsts if isinstance(d, DRow))
    return rows


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChainStageInfo:
    """One fused stage's seam spans inside a chain trace:
    ``seqs[seq_start:seq_end]`` / ``cmds[cmd_start:cmd_end]`` are the
    command sequences this stage contributed after seam optimization, and
    ``value`` is the SSA value name the stage produces."""
    op: str
    value: str
    seq_start: int
    seq_end: int
    cmd_start: int
    cmd_end: int


@dataclasses.dataclass(frozen=True)
class ChainInfo:
    """Seam metadata on a fused cross-op trace (see
    :func:`repro.core.compiler.compile_chain`): per-stage command-sequence
    spans so replay timing, TraceLint and per-op stall attribution still
    see op boundaries; the constituent ``ops`` (the cache-invalidation
    keys — redefining any of them must evict the fused entry); and the
    rows/sequences the cross-op allocator elided versus per-op lowering."""
    stages: tuple
    ops: tuple
    elided_rows: int = 0
    elided_seqs: int = 0

    @property
    def n_stages(self) -> int:
        return len(self.stages)


def _chain_info(meta, seqs) -> "ChainInfo | None":
    """μProgram chain metadata (flattened-μOp spans) → trace seam metadata.
    One flattened μOp encodes to exactly one ``seqs`` row (see
    :func:`encode_uops`), so μOp spans ARE sequence spans; command spans
    read off the seqs table."""
    if not meta:
        return None
    stages = []
    for op, value, s, e in meta["stages"]:
        if s < e:
            cs, ce = int(seqs[s, 1]), int(seqs[e - 1, 2])
        else:  # stage fully elided by seam optimization: empty span
            cs = ce = int(seqs[s - 1, 2]) if s > 0 else 0
        stages.append(ChainStageInfo(str(op), str(value), int(s), int(e),
                                     cs, ce))
    return ChainInfo(stages=tuple(stages), ops=tuple(meta["ops"]),
                     elided_rows=int(meta.get("elided_rows", 0)),
                     elided_seqs=int(meta.get("elided_seqs", 0)))


@dataclasses.dataclass
class LoweredTrace:
    """A μProgram lowered to the executable command-trace form.

    ``row_index`` maps row keys to 1-based physical row numbers:
    ``(array, bit)`` for D-group rows, ``("cell", c)`` for the six B-group
    compute cells, and ``"C0"``/``"C1"`` for the constant rows.  ``d_rows``
    lists the D-group keys in row order (operand loading).  Metadata
    (``inputs``/``outputs``/``scratch``) is carried over from the source
    μProgram so backends need nothing else.
    """

    name: str
    n_bits: int
    cmds: np.ndarray                       # int32[N, 4]
    seqs: np.ndarray                       # int32[M, 3] (kind, start, end)
    row_index: dict
    d_rows: tuple
    inputs: tuple = ()
    outputs: tuple = ()
    scratch: tuple = ()
    chain: object = None                   # ChainInfo for fused chain traces
    _decoded: object = dataclasses.field(default=None, repr=False)
    _lint: object = dataclasses.field(default=None, repr=False)
    _fingerprint: object = dataclasses.field(default=None, repr=False)
    _act_struct: object = dataclasses.field(default=None, repr=False)

    @property
    def n_rows(self) -> int:
        return len(self.row_index)

    @property
    def fingerprint(self) -> str:
        """Stable content hash of the trace (commands, seqs, row map and
        metadata) — identical traces share it across object identities, so
        it is the key for cost memos that must survive recompiles and can
        never alias the way a recycled ``id()`` can."""
        if self._fingerprint is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(repr((self.name, self.n_bits, self.d_rows, self.inputs,
                           self.outputs, self.scratch, self.chain)).encode())
            h.update(np.ascontiguousarray(self.cmds, np.int32).tobytes())
            h.update(np.ascontiguousarray(self.seqs, np.int32).tobytes())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    def act_structure(self) -> np.ndarray:
        """The trace's per-ACT gap-kind codes (int8[n_acts]) — its compiled
        replay structure.

        Every command sequence issues a fixed activation pattern (AP: one
        TRA; AAP: source ACT then back-to-back destination ACT), so the
        whole trace flattens to one per-bank ACT stream whose inter-ACT
        gaps depend only on the sequence kinds: ``ACT_GAP_START`` /
        ``ACT_GAP_RAS`` / ``ACT_GAP_RC``.  The vectorized replay engine
        turns these codes into cycle vectors and solves the stall
        recurrences with prefix scans instead of stepping the FSM.
        Timing-independent, hence memoized here on the trace (one
        structure serves every DRAMTiming and bank count)."""
        if self._act_struct is None:
            kinds = self.seqs[:, 0]
            if kinds.size == 0:
                self._act_struct = np.zeros(0, np.int8)
                return self._act_struct
            acts_per_seq = np.where(kinds == SEQ_AP, 1, 2)
            starts = np.concatenate(([0], np.cumsum(acts_per_seq)[:-1]))
            codes = np.full(int(acts_per_seq.sum()), ACT_GAP_RAS, np.int8)
            codes[starts] = ACT_GAP_RC
            codes[0] = ACT_GAP_START
            self._act_struct = codes
        return self._act_struct

    def lint(self, max_diagnostics: int = 100):
        """Statically verify this trace (see :mod:`repro.core.tracelint`);
        the :class:`~repro.core.tracelint.LintReport` is memoized on the
        trace, so cached compiles pay for verification exactly once."""
        if self._lint is None:
            from .tracelint import lint_trace
            self._lint = lint_trace(self, max_diagnostics)
        return self._lint

    @property
    def n_commands(self) -> int:
        """Command *sequences* (the paper's Table-5 metric), not cmd rows."""
        return int(self.seqs.shape[0])

    def command_mix(self) -> dict:
        """(n_AAP, n_AP, n_TRA) — identical to ``UProgram.command_mix``."""
        kinds = self.seqs[:, 0]
        n_ap = int((kinds == SEQ_AP).sum())
        n_fused = int((kinds == SEQ_AAP_TRA).sum())
        n_aap = int((kinds == SEQ_AAP).sum()) + n_fused
        return {"AAP": n_aap, "AP": n_ap, "TRA": n_ap + n_fused}

    def out_row_ids(self, name: str, n_bits: int) -> list[int]:
        """0-based row indices holding bits 0..n_bits-1 of output ``name``
        (missing bits resolve to the all-zeros C0 row)."""
        c0 = self.row_index["C0"]
        return [self.row_index.get((name, i), c0) - 1 for i in range(n_bits)]

    # -- decoding ------------------------------------------------------------
    def decode(self) -> list:
        """Reconstruct the (normalized) μOp sequence this trace was lowered
        from — the inverse of :func:`lower_program` up to
        :func:`~repro.core.uprogram.normalize_uop` (the ``fixed``
        loop-invariance mark on D rows names the same physical row and is
        consumed by flattening, so it does not survive lowering)."""
        inv = {idx: key for key, idx in self.row_index.items()}

        def ref(code: int):
            key = inv[abs(int(code))]
            if key == "C0":
                return C0
            if key == "C1":
                return C1
            if isinstance(key, tuple) and key[0] == "cell":
                return Port(key[1], neg=code < 0)
            return DRow(key[0], key[1])

        uops: list = []
        for kind, start, end in self.seqs.tolist():
            if kind == SEQ_AP:
                uops.append(AP(tuple(ref(c) for c in self.cmds[start, 1:4])))
            elif kind == SEQ_AAP_TRA:
                src = tuple(ref(c) for c in self.cmds[start, 1:4])
                dsts = tuple(ref(self.cmds[i, 1])
                             for i in range(start + 1, end))
                uops.append(AAP(src, dsts))
            else:
                src = ref(self.cmds[start, 2])
                dsts = tuple(ref(self.cmds[i, 1]) for i in range(start, end))
                uops.append(AAP(src, dsts))
        return uops

    def to_uprogram(self) -> UProgram:
        """Decoded μOps re-wrapped as a flat μProgram (what the ``reference``
        backend feeds the faithful ``Subarray`` executor); memoized, since
        banked oracle runs decode once per bank otherwise."""
        if self._decoded is None:
            self._decoded = UProgram(
                name=self.name, n_bits=self.n_bits, prologue=self.decode(),
                body=[], epilogue=[], body_reps=0, inputs=self.inputs,
                outputs=self.outputs, scratch=self.scratch)
        return self._decoded


# ---------------------------------------------------------------------------
# Lowering (memoized per program object)
# ---------------------------------------------------------------------------

# id(prog) → (prog, trace); strong refs keep ids stable, LRU-bounded (a
# hit refreshes recency) so ad-hoc programs (tests, experiments) cannot
# grow it without bound and a sustained mixed workload cannot evict its
# hottest program first.  Guarded by a lock: per-machine compile caches
# share this memo, so concurrent sessions race on it otherwise.
_LOWER_MEMO: dict[int, tuple[UProgram, "LoweredTrace"]] = {}
_LOWER_MEMO_CAP = 256
_LOWER_LOCK = threading.Lock()


def lower_program(prog: UProgram) -> LoweredTrace:
    """Lower a compiled μProgram to its command trace (once per object)."""
    with _LOWER_LOCK:
        hit = _LOWER_MEMO.get(id(prog))
        if hit is not None:
            # LRU move-to-end: eviction order is recency, not insertion —
            # FIFO evicted the hottest program first under mixed workloads
            _LOWER_MEMO[id(prog)] = _LOWER_MEMO.pop(id(prog))
            return hit[1]
    flat = prog.flatten()
    drows = sorted({(r.array, r.bit) for u in flat for r in _uop_drows(u)})
    if any(arr == "cell" for arr, _ in drows):
        raise ValueError('operand array name "cell" collides with the '
                         "B-group row keys")
    row_index: dict = {}
    for key in drows:
        row_index[key] = len(row_index) + 1
    row_index["C0"] = len(row_index) + 1
    row_index["C1"] = len(row_index) + 1
    for cell in range(N_B_CELLS):
        row_index[("cell", cell)] = len(row_index) + 1
    cmds, seqs = encode_uops(flat, row_index)
    trace = LoweredTrace(name=prog.name, n_bits=prog.n_bits, cmds=cmds,
                         seqs=seqs, row_index=row_index,
                         d_rows=tuple(drows), inputs=tuple(prog.inputs),
                         outputs=tuple(prog.outputs),
                         scratch=tuple(prog.scratch),
                         chain=_chain_info(getattr(prog, "chain", None),
                                           seqs))
    with _LOWER_LOCK:
        # re-check: another thread may have lowered the same program while
        # we computed — keep the first trace so every caller sees one object
        prior = _LOWER_MEMO.get(id(prog))
        if prior is not None:
            return prior[1]
        _LOWER_MEMO[id(prog)] = (prog, trace)
        while len(_LOWER_MEMO) > _LOWER_MEMO_CAP:
            del _LOWER_MEMO[next(iter(_LOWER_MEMO))]
    return trace


def canonical_uops(prog: UProgram) -> list:
    """``prog.flatten()`` in the normal form lowering preserves (see
    :meth:`LoweredTrace.decode`)."""
    return [normalize_uop(u) for u in prog.flatten()]


# ---------------------------------------------------------------------------
# The μProgram Memory: an instantiable compile/lower cache
# ---------------------------------------------------------------------------


class TraceCache:
    """A μProgram Memory: compile + lower once per ``(op, n_bits, optimize)``.

    The paper's control unit keeps the finished μPrograms in a small
    scratchpad (Fig. 7); this class mirrors it as a bounded LRU cache over
    ``(UProgram, LoweredTrace)`` pairs with hit/miss/eviction counters.
    One process-wide instance backs :func:`compile_trace` (and the default
    :class:`~repro.simdram.machine.SimdramMachine`); every other machine
    owns a private instance, so concurrent sessions never share compiles
    or counters.

    ``compile_fn(name, n_bits, optimize) → UProgram`` resolves a miss —
    ``None`` means the process-wide op registry
    (:func:`repro.core.circuits.compile_operation`).  ``capacity=None``
    is unbounded.  ``verify=True`` (default) statically verifies every
    freshly lowered trace (:mod:`repro.core.tracelint`) before it enters
    the cache: a trace with lint errors raises
    :class:`~repro.core.tracelint.TraceLintError` and is never cached, and
    because the report is memoized on the trace the cached hot path never
    pays for verification again.  All access is lock-guarded: hammering one cache from
    many threads keeps counters exact and never compiles a key twice.
    (The lock is deliberately held across the compile itself, so a cold
    miss serializes other misses on the same cache — the workloads this
    models compile a handful of keys once and then only hit; exactly-once
    compiles and exact counters are worth more here than cold-path
    concurrency.)
    """

    def __init__(self, capacity: int | None = None, compile_fn=None,
                 verify: bool = True,
                 replay_capacity: int | None = 512,
                 schedule_capacity: int | None = 256) -> None:
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        if replay_capacity is not None and replay_capacity < 1:
            raise ValueError(f"replay_capacity must be >= 1 or None, "
                             f"got {replay_capacity}")
        if schedule_capacity is not None and schedule_capacity < 1:
            raise ValueError(f"schedule_capacity must be >= 1 or None, "
                             f"got {schedule_capacity}")
        self.capacity = capacity
        self.verify = verify
        self._compile_fn = compile_fn
        self._entries: collections.OrderedDict[
            tuple, tuple[UProgram, LoweredTrace]] = collections.OrderedDict()
        # closed-form ReplayResult memo (the μProgram Memory's second
        # table): keyed by (trace.fingerprint, banks, offsets signature,
        # refresh-phase bucket, policy/engine, timing signature) — content
        # hashes, so entries never go stale across recompiles and need no
        # invalidate() hook.  LRU-bounded separately from the compile
        # entries: replay keys fan out per (banks, offsets, phase) and
        # must not evict compiled programs.
        self._replays: collections.OrderedDict[tuple, object] = \
            collections.OrderedDict()
        self.replay_capacity = replay_capacity
        # whole-schedule memo (the μProgram Memory's third table): a
        # BankScheduler busy period is fully determined by its request set
        # — (trace fingerprint, bank placement, stream arrival cycles) per
        # request — plus the controller policies, bank count, refresh
        # phase and timing signature.  A decode server re-issuing the same
        # batch shape every step gets the whole stepped event loop back as
        # a table lookup.  Content-keyed like the replay memo, so entries
        # never go stale across recompiles.
        self._schedules: collections.OrderedDict[tuple, object] = \
            collections.OrderedDict()
        self.schedule_capacity = schedule_capacity
        self._lock = threading.RLock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._replay_hits = 0
        self._replay_misses = 0
        self._schedule_hits = 0
        self._schedule_misses = 0
        _ALL_CACHES.add(self)

    def _compile(self, name: str, n_bits: int, optimize: bool) -> UProgram:
        if self._compile_fn is not None:
            return self._compile_fn(name, n_bits, optimize)
        from .circuits import compile_operation
        return compile_operation(name, n_bits, optimize=optimize)

    def get(self, name: str, n_bits: int, optimize: bool = True,
            verify: bool | None = None) -> tuple[UProgram, LoweredTrace]:
        """Fetch-or-compile the ``(UProgram, LoweredTrace)`` pair.

        ``verify=None`` uses the cache's default (see the class docstring);
        a trace that fails verification raises
        :class:`~repro.core.tracelint.TraceLintError` and never enters the
        cache."""
        key = (name, int(n_bits), bool(optimize))
        # the whole miss path holds the lock: compiling outside it would
        # let two threads synthesize the same key concurrently and tear
        # the hit/miss counters
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self._hits += 1
                self._entries.move_to_end(key)
                if self.verify if verify is None else verify:
                    # memoized on the trace — a no-op unless the entry was
                    # inserted with verify=False and has errors
                    hit[1].lint().raise_for_errors()
                return hit
            self._misses += 1
            prog = self._compile(name, n_bits, bool(optimize))
            trace = lower_program(prog)
            if self.verify if verify is None else verify:
                trace.lint().raise_for_errors()
            entry = (prog, trace)
            self._entries[key] = entry
            while self.capacity is not None and \
                    len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._evictions += 1
            return entry

    def get_chain(self, stages, n_bits: int, optimize: bool = True,
                  verify: bool | None = None, outputs=None,
                  name: str | None = None
                  ) -> tuple[UProgram, LoweredTrace]:
        """Fetch-or-compile a fused cross-op chain (see
        :func:`repro.core.compiler.compile_chain`).

        Keyed by the chain *signature* — the constituent op names plus the
        full value wiring — rather than the display name, and the lowered
        trace records its constituent ops in ``trace.chain.ops``, so
        :meth:`invalidate` on ANY constituent op evicts the fused entry.
        The per-stage compiles resolve through this cache's own compile
        function, so machine-local op definitions fuse correctly."""
        from .compiler import chain_signature, compile_chain
        key = (chain_signature(stages, outputs), int(n_bits), bool(optimize))
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self._hits += 1
                self._entries.move_to_end(key)
                if self.verify if verify is None else verify:
                    hit[1].lint().raise_for_errors()
                return hit
            self._misses += 1
            prog = compile_chain(stages, n_bits, optimize=bool(optimize),
                                 compile_fn=self._compile, outputs=outputs,
                                 name=name)
            trace = lower_program(prog)
            if self.verify if verify is None else verify:
                trace.lint().raise_for_errors()
            entry = (prog, trace)
            self._entries[key] = entry
            while self.capacity is not None and \
                    len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._evictions += 1
            return entry

    def replay_get(self, key: tuple):
        """Fetch a memoized closed-form ReplayResult (None on miss)."""
        with self._lock:
            hit = self._replays.get(key)
            if hit is None:
                self._replay_misses += 1
                return None
            self._replay_hits += 1
            self._replays.move_to_end(key)
            return hit

    def replay_put(self, key: tuple, result) -> None:
        """Memoize one replay outcome under its full stall-structure key."""
        with self._lock:
            self._replays[key] = result
            self._replays.move_to_end(key)
            while self.replay_capacity is not None and \
                    len(self._replays) > self.replay_capacity:
                self._replays.popitem(last=False)

    def schedule_get(self, key: tuple):
        """Fetch a memoized whole-schedule outcome (None on miss)."""
        with self._lock:
            hit = self._schedules.get(key)
            if hit is None:
                self._schedule_misses += 1
                return None
            self._schedule_hits += 1
            self._schedules.move_to_end(key)
            return hit

    def schedule_put(self, key: tuple, result) -> None:
        """Memoize one scheduler busy period under its full request-set
        key (see :meth:`BankScheduler.run`'s memo hook)."""
        with self._lock:
            self._schedules[key] = result
            self._schedules.move_to_end(key)
            while self.schedule_capacity is not None and \
                    len(self._schedules) > self.schedule_capacity:
                self._schedules.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        """{hits, misses, entries, hit_rate, capacity, evictions} plus the
        replay-memo counters (replay_hits, replay_misses, replay_entries)
        and the schedule-memo counters (schedule_hits, schedule_misses,
        schedule_entries)."""
        with self._lock:
            h, m = self._hits, self._misses
            return {"hits": h, "misses": m, "entries": len(self._entries),
                    "hit_rate": h / (h + m) if h + m else 0.0,
                    "capacity": self.capacity, "evictions": self._evictions,
                    "replay_hits": self._replay_hits,
                    "replay_misses": self._replay_misses,
                    "replay_entries": len(self._replays),
                    "schedule_hits": self._schedule_hits,
                    "schedule_misses": self._schedule_misses,
                    "schedule_entries": len(self._schedules)}

    def invalidate(self, name: str) -> int:
        """Drop every cached width/optimize variant of one operation —
        called when an op is (re)registered or unregistered so a stale
        compile can never execute under the new definition.  Fused chain
        entries are evicted when *any* constituent op (``trace.chain.ops``)
        is invalidated, not only on a key match — a chain compiled against
        the old definition is exactly as stale as the op itself.  Returns
        the number of entries dropped."""
        with self._lock:
            victims = []
            for k, (_prog, trace) in self._entries.items():
                chain = getattr(trace, "chain", None)
                if k[0] == name or (chain is not None and name in chain.ops):
                    victims.append(k)
            for k in victims:
                del self._entries[k]
            return len(victims)

    def reset_stats(self) -> None:
        with self._lock:
            self._hits = self._misses = self._evictions = 0
            self._replay_hits = self._replay_misses = 0
            self._schedule_hits = self._schedule_misses = 0

    def clear(self) -> None:
        """Drop entries and counters (in place — aliases stay valid)."""
        with self._lock:
            self._entries.clear()
            self._replays.clear()
            self._schedules.clear()
            self.reset_stats()


# every live TraceCache (weak refs: caches die with their machines) — a
# process-wide op (re)registration must be able to evict stale compiles
# from ALL of them, not just the global cache, because private machine
# memories fall back to the process registry for names they don't define
_ALL_CACHES: "weakref.WeakSet[TraceCache]" = weakref.WeakSet()


def invalidate_everywhere(name: str) -> None:
    """Drop every cached compile of ``name`` from every live TraceCache
    (called by the op registry on re-registration/unregistration)."""
    for cache in list(_ALL_CACHES):
        cache.invalidate(name)


# ---------------------------------------------------------------------------
# Process-wide compile/lower cache (the default machine's μProgram Memory)
# ---------------------------------------------------------------------------

GLOBAL_TRACE_CACHE = TraceCache()
# legacy alias (tests/benchmarks introspect the raw mapping)
_COMPILE_CACHE = GLOBAL_TRACE_CACHE._entries


def compile_trace(name: str, n_bits: int, optimize: bool = True,
                  verify: bool | None = None
                  ) -> tuple[UProgram, LoweredTrace]:
    """Compile + lower an operation once per ``(op, n_bits, optimize)``.

    Returns the cached ``(UProgram, LoweredTrace)`` pair from the
    process-wide :data:`GLOBAL_TRACE_CACHE`; synthesis, row allocation and
    lowering never re-run for a cached key.  Fresh traces are statically
    verified by default (``verify=``, see :mod:`repro.core.tracelint`);
    the memoized report makes this free on every later fetch.
    """
    return GLOBAL_TRACE_CACHE.get(name, n_bits, optimize, verify=verify)


def compile_chain_trace(stages, n_bits: int, optimize: bool = True,
                        verify: bool | None = None, outputs=None,
                        name: str | None = None
                        ) -> tuple[UProgram, LoweredTrace]:
    """Fuse + lower a cross-op chain once per (signature, n_bits, optimize)
    via the process-wide cache (see :meth:`TraceCache.get_chain`)."""
    return GLOBAL_TRACE_CACHE.get_chain(stages, n_bits, optimize,
                                        verify=verify, outputs=outputs,
                                        name=name)


def trace_cache_stats() -> dict:
    """{hits, misses, entries, hit_rate, ...} of the process-wide cache."""
    return GLOBAL_TRACE_CACHE.stats()


def reset_trace_cache_stats() -> None:
    GLOBAL_TRACE_CACHE.reset_stats()


def clear_trace_cache() -> None:
    """Drop every cached compile (and the counters) — benchmarks use this to
    measure a cold compile path.  The lowering memo is dropped too: a
    "cold compile" that still fetched memoized lowerings measured only cold
    synthesis, not the genuinely cold compile-and-lower path."""
    GLOBAL_TRACE_CACHE.clear()
    with _LOWER_LOCK:
        _LOWER_MEMO.clear()
