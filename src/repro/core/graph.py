"""Logic-graph IR for SIMDRAM Step 1 (paper §4.1, Appendix A).

Two directed-acyclic graph forms are used, exactly as in the paper:

* AOIG — AND-OR-Inverter graph: nodes are 2-input AND / OR primitives,
  edges may be complemented (the "inverter" lives on the edge).
* MIG  — Majority-Inverter graph: nodes are 3-input MAJ primitives,
  edges may be complemented.

Both share one node/edge representation here; ``kind`` distinguishes them.
Edges are encoded as signed literals referring to node ids, exactly like an
AIG literal: ``lit = node_id << 1 | complemented``.  Node id 0 is reserved
for the constant FALSE, so literal 0 = const0 and literal 1 = const1 — this
mirrors the paper's C-group rows C0/C1.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Literals
# ---------------------------------------------------------------------------

CONST0 = 0  # literal: constant 0 (C0 row)
CONST1 = 1  # literal: constant 1 (C1 row)


def lit(node_id: int, neg: bool = False) -> int:
    return (node_id << 1) | int(neg)


def lit_node(literal: int) -> int:
    return literal >> 1


def lit_neg(literal: int) -> bool:
    return bool(literal & 1)


def lit_not(literal: int) -> int:
    return literal ^ 1


# ---------------------------------------------------------------------------
# Nodes
# ---------------------------------------------------------------------------

PI = "pi"
AND = "and"
OR = "or"
MAJ = "maj"
CONST = "const"


@dataclasses.dataclass(frozen=True)
class Node:
    kind: str
    fanin: tuple[int, ...] = ()  # literals
    name: str = ""               # for PIs: stable operand name


class LogicGraph:
    """A mutable DAG of logic nodes with structural hashing.

    Node 0 is always the constant-0 node.  Primary inputs are created with
    :meth:`input`; gates with :meth:`gate_and` / :meth:`gate_or` /
    :meth:`gate_maj`.  Outputs are named literals.
    """

    def __init__(self) -> None:
        self.nodes: list[Node] = [Node(CONST)]
        self.outputs: list[tuple[str, int]] = []  # (name, literal)
        self._strash: dict[tuple, int] = {}
        self._input_ids: dict[str, int] = {}

    # -- construction -------------------------------------------------------
    def input(self, name: str) -> int:
        """Create (or fetch) a primary input; returns its literal."""
        if name in self._input_ids:
            return lit(self._input_ids[name])
        nid = len(self.nodes)
        self.nodes.append(Node(PI, name=name))
        self._input_ids[name] = nid
        return lit(nid)

    def _mk(self, kind: str, fanin: tuple[int, ...]) -> int:
        key = (kind, fanin)
        found = self._strash.get(key)
        if found is not None:
            return lit(found)
        nid = len(self.nodes)
        self.nodes.append(Node(kind, fanin=fanin))
        self._strash[key] = nid
        return lit(nid)

    def gate_and(self, a: int, b: int) -> int:
        # constant folding
        if a == CONST0 or b == CONST0:
            return CONST0
        if a == CONST1:
            return b
        if b == CONST1:
            return a
        if a == b:
            return a
        if a == lit_not(b):
            return CONST0
        a, b = min(a, b), max(a, b)
        return self._mk(AND, (a, b))

    def gate_or(self, a: int, b: int) -> int:
        return lit_not(self.gate_and(lit_not(a), lit_not(b)))

    def gate_or_node(self, a: int, b: int) -> int:
        """An explicit OR node (kept distinct for AOIG fidelity)."""
        if a == CONST1 or b == CONST1:
            return CONST1
        if a == CONST0:
            return b
        if b == CONST0:
            return a
        if a == b:
            return a
        if a == lit_not(b):
            return CONST1
        a, b = min(a, b), max(a, b)
        return self._mk(OR, (a, b))

    def gate_xor(self, a: int, b: int) -> int:
        """XOR via AOIG structure: (a|b) & ~(a&b)."""
        return self.gate_and(self.gate_or_node(a, b), lit_not(self.gate_and(a, b)))

    def gate_mux(self, sel: int, t: int, f: int) -> int:
        """sel ? t : f   as AOIG."""
        return self.gate_or_node(self.gate_and(sel, t), self.gate_and(lit_not(sel), f))

    def gate_maj(self, a: int, b: int, c: int) -> int:
        """3-input majority with full local simplification (MIG axioms)."""
        ins = [a, b, c]
        # Ω.M (majority): M(x,x,z)=x ; M(x,~x,z)=z
        for i in range(3):
            for j in range(i + 1, 3):
                if ins[i] == ins[j]:
                    return ins[i]
                if ins[i] == lit_not(ins[j]):
                    return ins[3 - i - j]
        # constants: M(a,b,0)=AND, M(a,b,1)=OR handled by keeping the node —
        # but M with two constants folds above (0,1 are complements).
        # Ω.I (inverter propagation): canonicalize so ≤1 fanin is negated by
        # preferring the form with fewer complemented edges; M(~a,~b,~c)=~M(a,b,c)
        ncomp = sum(lit_neg(x) for x in ins)
        out_neg = False
        if ncomp >= 2:
            # try full complement: only exact when all three flip (Ω.I), so flip
            # all and complement the output.
            ins = [lit_not(x) for x in ins]
            out_neg = True
        ins.sort()
        result = self._mk(MAJ, tuple(ins))
        return lit_not(result) if out_neg else result

    def add_output(self, name: str, literal: int) -> None:
        self.outputs.append((name, literal))

    # -- queries ------------------------------------------------------------
    @property
    def num_gates(self) -> int:
        return sum(1 for n in self.nodes if n.kind in (AND, OR, MAJ))

    def gate_ids(self) -> list[int]:
        return [i for i, n in enumerate(self.nodes) if n.kind in (AND, OR, MAJ)]

    def input_names(self) -> list[str]:
        return [n.name for n in self.nodes if n.kind == PI]

    def topo_order(self) -> list[int]:
        """Topological order of live node ids (outputs' transitive fanin)."""
        seen: set[int] = set()
        order: list[int] = []

        def visit(nid: int) -> None:
            if nid in seen:
                return
            seen.add(nid)
            for f in self.nodes[nid].fanin:
                visit(lit_node(f))
            order.append(nid)

        for _, out in self.outputs:
            visit(lit_node(out))
        return order

    def live_gate_count(self) -> int:
        return sum(1 for nid in self.topo_order() if self.nodes[nid].kind in (AND, OR, MAJ))

    def depth(self) -> int:
        level: dict[int, int] = {}
        for nid in self.topo_order():
            node = self.nodes[nid]
            if node.kind in (PI, CONST):
                level[nid] = 0
            else:
                level[nid] = 1 + max(level[lit_node(f)] for f in node.fanin)
        return max((level[lit_node(o)] for _, o in self.outputs), default=0)

    # -- evaluation (bit-parallel on python ints) ----------------------------
    def evaluate(self, assignment: dict[str, int], mask: int = -1) -> dict[str, int]:
        """Evaluate all outputs.  ``assignment`` maps PI name → int whose bits
        are independent SIMD lanes (bit-parallel evaluation, like bitlines).
        ``mask`` limits the lane width."""
        val: dict[int, int] = {0: 0}
        for nid in self.topo_order():
            node = self.nodes[nid]
            if node.kind == CONST:
                val[nid] = 0
            elif node.kind == PI:
                val[nid] = assignment[node.name] & mask
            else:
                f = [self._litval(val, x, mask) for x in node.fanin]
                if node.kind == AND:
                    val[nid] = f[0] & f[1]
                elif node.kind == OR:
                    val[nid] = f[0] | f[1]
                else:  # MAJ
                    val[nid] = (f[0] & f[1]) | (f[0] & f[2]) | (f[1] & f[2])
        return {name: self._litval(val, o, mask) for name, o in self.outputs}

    @staticmethod
    def _litval(val: dict[int, int], literal: int, mask: int) -> int:
        v = val[lit_node(literal)]
        return (~v & mask) if lit_neg(literal) else (v & mask)

    def clone(self) -> "LogicGraph":
        g = LogicGraph()
        g.nodes = list(self.nodes)
        g.outputs = list(self.outputs)
        g._strash = dict(self._strash)
        g._input_ids = dict(self._input_ids)
        return g
