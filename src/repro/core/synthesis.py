"""Step 1 of the SIMDRAM framework: AOIG → optimized MIG (paper §4.1, App. A).

The transformation has two parts, exactly as the paper describes:

1. *Naive substitution*: every 2-input AND/OR primitive becomes a 3-input MAJ
   with one input tied to C0/C1.  This yields a functionally-correct but
   inefficient MIG (paper Fig. 15b — it equals Ambit's representation).
2. *Greedy optimization*: repeated node-reduction / reshaping passes using the
   MIG axioms of Amarù et al. [7] (paper Table 4): Ω.M (majority), Ω.I
   (inverter propagation) — both applied during reconstruction through
   ``gate_maj`` — plus structural hashing, constant propagation, and
   relevance-driven 3-cut rewriting against a table of size-optimal MIG
   templates (XOR/XNOR/MUX/AOI/AND3/OR3...).  The XOR3 template is the shared
   full-adder structure S = M(M(¬a,b,c), ¬M(a,b,c), a) that App. A derives by
   hand in Fig. 15j; strashing makes the sum and carry outputs share the
   M(a,b,c) node automatically, reproducing the paper's 3-MAJ full adder.

The optimizer is deterministic; ``optimize_mig`` iterates passes until the
live gate count stops improving.
"""
from __future__ import annotations

import itertools

from .graph import (AND, CONST, CONST0, CONST1, MAJ, OR, PI, LogicGraph,
                    lit_neg, lit_node, lit_not)

# ---------------------------------------------------------------------------
# Part 1: naive AOIG → MIG substitution
# ---------------------------------------------------------------------------


def aoig_to_mig_naive(aoig: LogicGraph) -> LogicGraph:
    """AND(a,b) → MAJ(a,b,0); OR(a,b) → MAJ(a,b,1).  (paper Fig. 15b)"""
    return _reconstruct(aoig)


# ---------------------------------------------------------------------------
# Size-optimal templates for 3-input cut functions
# ---------------------------------------------------------------------------
# keyed by 8-bit truth table over (a,b,c), bit index = a + 2b + 4c.


def _tt3(fn) -> int:
    t = 0
    for i in range(8):
        a, b, c = i & 1, (i >> 1) & 1, (i >> 2) & 1
        if fn(a, b, c):
            t |= 1 << i
    return t


def _tmpl_xor3(g: LogicGraph, a: int, b: int, c: int) -> int:
    # 3-node XOR3 via the shared full-adder structure (App. A, Fig. 15j):
    # S = M( M(¬a,b,c), ¬M(a,b,c), a )
    y = g.gate_maj(lit_not(a), b, c)
    k = g.gate_maj(a, b, c)
    return g.gate_maj(y, lit_not(k), a)


def _tmpl_xor2(g: LogicGraph, a: int, b: int, _c: int) -> int:
    return _tmpl_xor3(g, a, b, CONST0)


def _tmpl_mux(g: LogicGraph, s: int, a: int, b: int) -> int:
    # s ? a : b  = M( M(s,a,0), M(¬s,b,0), 1 )   (3 nodes)
    return g.gate_maj(g.gate_maj(s, a, CONST0),
                      g.gate_maj(lit_not(s), b, CONST0), CONST1)


TEMPLATES: dict[int, object] = {}


def _register_templates() -> None:
    def reg(tt, builder):
        TEMPLATES.setdefault(tt & 0xFF, builder)

    # every function realizable by ONE maj node over (±a,±b,±c,0,1)
    base = {"a": lambda a, b, c: a, "b": lambda a, b, c: b, "c": lambda a, b, c: c,
            "0": lambda a, b, c: 0, "1": lambda a, b, c: 1}
    for trio in itertools.combinations_with_replacement(sorted(base), 3):
        for negs in itertools.product((0, 1), repeat=3):
            def f(a, b, c, trio=trio, negs=negs):
                vals = [base[t](a, b, c) ^ n for t, n in zip(trio, negs)]
                return int(sum(vals) >= 2)

            def build(g, a, b, c, trio=trio, negs=negs):
                m = {"a": a, "b": b, "c": c, "0": CONST0, "1": CONST1}
                lits = [lit_not(m[t]) if n else m[t] for t, n in zip(trio, negs)]
                return g.gate_maj(*lits)

            reg(_tt3(f), build)
    # multi-node templates
    reg(_tt3(lambda a, b, c: a ^ b), _tmpl_xor2)
    reg(_tt3(lambda a, b, c: 1 ^ a ^ b),
        lambda g, a, b, c: lit_not(_tmpl_xor2(g, a, b, c)))
    reg(_tt3(lambda a, b, c: a ^ b ^ c), _tmpl_xor3)
    reg(_tt3(lambda a, b, c: 1 ^ a ^ b ^ c),
        lambda g, a, b, c: lit_not(_tmpl_xor3(g, a, b, c)))
    reg(_tt3(lambda a, b, c: b if a else c), _tmpl_mux)
    reg(_tt3(lambda a, b, c: c if a else b), lambda g, a, b, c: _tmpl_mux(g, a, c, b))
    reg(_tt3(lambda a, b, c: a if b else c), lambda g, a, b, c: _tmpl_mux(g, b, a, c))
    reg(_tt3(lambda a, b, c: a if c else b), lambda g, a, b, c: _tmpl_mux(g, c, a, b))
    reg(_tt3(lambda a, b, c: a and (b or c)),
        lambda g, a, b, c: g.gate_maj(a, g.gate_maj(a, b, c), CONST0))
    reg(_tt3(lambda a, b, c: a or (b and c)),
        lambda g, a, b, c: g.gate_maj(a, g.gate_maj(a, b, c), CONST1))
    reg(_tt3(lambda a, b, c: a and b and c),
        lambda g, a, b, c: g.gate_maj(g.gate_maj(a, b, CONST0), c, CONST0))
    reg(_tt3(lambda a, b, c: a or b or c),
        lambda g, a, b, c: g.gate_maj(g.gate_maj(a, b, CONST1), c, CONST1))


def _register_two_node_templates() -> None:
    """Exhaustively enumerate every function realizable by TWO maj nodes
    over (±a, ±b, ±c, 0, 1) and register size-optimal builders for any truth
    table not already covered — making the cut rewriter size-optimal for all
    ≤2-node-realizable 3-input functions."""
    base_tt = {"a": 0b10101010, "b": 0b11001100, "c": 0b11110000,
               "0": 0, "1": 0xFF}
    lits = []  # (tt, builder_fn(g, a, b, c) -> literal)
    for name, tt in base_tt.items():
        def mk(name=name):
            def build(g, a, b, c):
                return {"a": a, "b": b, "c": c, "0": CONST0,
                        "1": CONST1}[name]
            return build
        lits.append((tt, mk()))
        if name in ("a", "b", "c"):
            def mkn(name=name):
                def build(g, a, b, c):
                    return lit_not({"a": a, "b": b, "c": c}[name])
                return build
            lits.append((~tt & 0xFF, mkn()))

    def maj_tt(x, y, z):
        return (x & y) | (x & z) | (y & z)

    import itertools as _it
    # all single-node results (as composable literal sources)
    node1: list[tuple[int, object]] = []
    for (t1, b1), (t2, b2), (t3, b3) in _it.combinations(lits, 3):
        tt = maj_tt(t1, t2, t3)

        def mk1(b1=b1, b2=b2, b3=b3):
            def build(g, a, b, c):
                return g.gate_maj(b1(g, a, b, c), b2(g, a, b, c),
                                  b3(g, a, b, c))
            return build
        node1.append((tt, mk1()))
        node1.append((~tt & 0xFF, (lambda f: lambda g, a, b, c:
                                   lit_not(f(g, a, b, c)))(mk1())))
    # two-node: one operand is a node-1 result
    pool = lits + node1
    two_node: dict[int, object] = {}
    for t_in, b_in in node1:
        for (t1, b1), (t2, b2) in _it.combinations(lits, 2):
            tt = maj_tt(t_in, t1, t2)
            if tt not in TEMPLATES and tt not in two_node:
                def mk2(b_in=b_in, b1=b1, b2=b2):
                    def build(g, a, b, c):
                        return g.gate_maj(b_in(g, a, b, c), b1(g, a, b, c),
                                          b2(g, a, b, c))
                    return build
                two_node[tt] = mk2()
    for tt, build in two_node.items():
        TEMPLATES.setdefault(tt, build)


_register_templates()
_register_two_node_templates()


# ---------------------------------------------------------------------------
# Cut machinery
# ---------------------------------------------------------------------------


def _cut_function(g: LogicGraph, root: int, leaves: tuple[int, ...]) -> int | None:
    """Truth table (over ≤3 leaves) of node ``root``; None if not covered."""
    order = {leaf: i for i, leaf in enumerate(leaves)}
    masks = (0b10101010, 0b11001100, 0b11110000)
    memo: dict[int, int | None] = {}

    def val(nid: int) -> int | None:
        if nid in memo:
            return memo[nid]
        if nid in order:
            memo[nid] = masks[order[nid]]
            return memo[nid]
        node = g.nodes[nid]
        if node.kind == CONST:
            memo[nid] = 0
        elif node.kind == PI:
            memo[nid] = None
        else:
            fs = []
            for f in node.fanin:
                v = val(lit_node(f))
                if v is None:
                    memo[nid] = None
                    return None
                fs.append((~v & 0xFF) if lit_neg(f) else v)
            if node.kind == MAJ:
                memo[nid] = (fs[0] & fs[1]) | (fs[0] & fs[2]) | (fs[1] & fs[2])
            elif node.kind == AND:
                memo[nid] = fs[0] & fs[1]
            else:
                memo[nid] = fs[0] | fs[1]
        return memo[nid]

    return val(root)


def _all_cuts(g: LogicGraph, k: int = 3, max_cuts: int = 10) -> dict[int, list[tuple[int, ...]]]:
    cuts: dict[int, list[frozenset[int]]] = {}
    result: dict[int, list[tuple[int, ...]]] = {}
    for n in g.topo_order():
        node = g.nodes[n]
        if node.kind == CONST:
            cuts[n] = [frozenset()]
            continue
        if node.kind == PI:
            cuts[n] = [frozenset([n])]
            continue
        pools = [cuts.get(lit_node(f), [frozenset([lit_node(f)])]) for f in node.fanin]
        merged: list[frozenset[int]] = []
        for combo in itertools.product(*pools):
            u = frozenset().union(*combo)
            if len(u) <= k and u not in merged:
                merged.append(u)
            if len(merged) >= max_cuts:
                break
        # largest cuts first: templates are size-optimal for the whole cut,
        # so a 3-cut rewrite replaces the most intermediate structure
        result[n] = sorted((tuple(sorted(c)) for c in merged if c),
                           key=len, reverse=True)
        merged.append(frozenset([n]))
        cuts[n] = merged
    return result


# ---------------------------------------------------------------------------
# Optimization passes
# ---------------------------------------------------------------------------


def _reconstruct(g: LogicGraph) -> LogicGraph:
    """Rebuild through gate_maj so local axioms (Ω.M, Ω.I, constant folding)
    and structural hashing apply everywhere; AND/OR become MAJ."""
    out = LogicGraph()
    remap: dict[int, int] = {0: CONST0}

    def mlit(old: int) -> int:
        v = remap[lit_node(old)]
        return lit_not(v) if lit_neg(old) else v

    for nid in range(1, len(g.nodes)):
        node = g.nodes[nid]
        if node.kind == PI:
            remap[nid] = out.input(node.name)
        elif node.kind == MAJ:
            remap[nid] = out.gate_maj(*(mlit(f) for f in node.fanin))
        elif node.kind == AND:
            remap[nid] = out.gate_maj(mlit(node.fanin[0]), mlit(node.fanin[1]), CONST0)
        elif node.kind == OR:
            remap[nid] = out.gate_maj(mlit(node.fanin[0]), mlit(node.fanin[1]), CONST1)
    for name, o in g.outputs:
        out.add_output(name, mlit(o))
    return out


def _cut_rewrite(g: LogicGraph) -> LogicGraph:
    """Topo-order rebuild where each node may be re-expressed by a
    size-optimal template over one of its 3-cuts.  Structural hashing in the
    output graph turns template sharing (e.g. FA sum/carry) into real
    node sharing."""
    out = LogicGraph()
    remap: dict[int, int] = {0: CONST0}
    cuts = _all_cuts(g)

    def mlit(old: int) -> int:
        v = remap[lit_node(old)]
        return lit_not(v) if lit_neg(old) else v

    for nid in g.topo_order():
        node = g.nodes[nid]
        if node.kind == CONST:
            continue
        if node.kind == PI:
            remap[nid] = out.input(node.name)
            continue
        chosen = None
        for leaves in cuts.get(nid, []):
            if not all(leaf in remap for leaf in leaves):
                continue
            tt = _cut_function(g, nid, leaves)
            if tt is None:
                continue
            builder = TEMPLATES.get(tt & 0xFF)
            if builder is None:
                continue
            leaf_lits = [remap[leaf] for leaf in leaves] + [CONST0] * (3 - len(leaves))
            chosen = builder(out, *leaf_lits)
            break
        if chosen is None:
            if node.kind == MAJ:
                chosen = out.gate_maj(*(mlit(f) for f in node.fanin))
            elif node.kind == AND:
                chosen = out.gate_maj(mlit(node.fanin[0]), mlit(node.fanin[1]), CONST0)
            else:
                chosen = out.gate_maj(mlit(node.fanin[0]), mlit(node.fanin[1]), CONST1)
        remap[nid] = chosen
    for name, o in g.outputs:
        out.add_output(name, mlit(o))
    return out


def optimize_mig(mig: LogicGraph, max_rounds: int = 8) -> LogicGraph:
    best = _reconstruct(mig)
    for _ in range(max_rounds):
        cand = _reconstruct(_cut_rewrite(best))
        if cand.live_gate_count() >= best.live_gate_count():
            break
        best = cand
    return best


def synthesize(aoig: LogicGraph, optimize: bool = True) -> LogicGraph:
    """Full Step 1: AOIG → (optimized) MIG."""
    mig = aoig_to_mig_naive(aoig)
    return optimize_mig(mig) if optimize else mig


class SynthesisError(ValueError):
    """Step-1 synthesis produced a MIG that disagrees with its AOIG."""


def check_synthesis(aoig: LogicGraph, name: str = "graph",
                    max_inputs: int = 12) -> None:
    """Exhaustively verify Step-1 synthesis for a (slice-sized) AOIG.

    Both MIG forms — the naive MAJ/NOT substitution (the Ambit baseline)
    and the optimized MIG — are evaluated bit-parallel against the source
    AOIG on *every* input assignment (one SIMD lane per assignment, like
    bitlines).  User-defined operations registered through
    ``SimdramMachine.define_op`` run through here before they reach row
    allocation, so a miscompiled template or a bad axiom rewrite surfaces
    as a clear :class:`SynthesisError` instead of wrong in-DRAM results.

    Slice graphs have a handful of inputs, so exhaustion is cheap; graphs
    wider than ``max_inputs`` are rejected (define such ops via
    ``compile_fn`` and cover them with their own tests).
    """
    names = aoig.input_names()
    if len(names) > max_inputs:
        raise ValueError(
            f"{name!r}: {len(names)} inputs is too wide to verify "
            f"exhaustively (limit {max_inputs}) — register via compile_fn "
            "and validate externally")
    lanes = 1 << len(names)
    mask = (1 << lanes) - 1
    assignment = {}
    for i, pi in enumerate(names):
        pat = 0
        for lane in range(lanes):
            if (lane >> i) & 1:
                pat |= 1 << lane
        assignment[pi] = pat
    ref = aoig.evaluate(assignment, mask)
    naive = aoig_to_mig_naive(aoig)
    for g, form in ((naive, "naive MAJ/NOT substitution"),
                    (optimize_mig(naive), "optimized MIG")):
        got = g.evaluate(assignment, mask)
        if got != ref:
            wrong = sorted(o for o in ref if got.get(o) != ref[o])
            raise SynthesisError(
                f"{name!r}: {form} disagrees with the source AOIG on "
                f"output(s) {wrong}")
