"""TraceLint — static verification of lowered command traces.

The command trace (:class:`~repro.core.trace.LoweredTrace`) is the last
form an operation takes before it reaches a replay FSM or a tenant's bank,
and since ``machine.define_op(compile_fn=...)`` it can come from arbitrary
user code.  A single misallocated row silently computes garbage: real-chip
characterization (arXiv:2402.18736, arXiv:2405.06081) shows that *which*
rows are simultaneously activated, and in what charge state, decides
whether an in-DRAM operation works at all.  This module checks those
structural properties without executing anything.

``lint_trace`` runs a row-liveness dataflow pass over the ``cmds`` array —
def/use chains per physical row — plus a structural pass over the ``seqs``
table.  Diagnostics carry a machine-checkable ``kind``, a severity, the
offending command index and the human row key recovered through the
``row_index`` inverse.  The diagnostic kinds:

====================  ======== ==================================================
kind                  severity what it means
====================  ======== ==================================================
``malformed-seqs``    error    ``seqs`` does not tile ``cmds`` (gap/overlap/
                               out-of-range span), or a sequence's contents do
                               not match its kind (e.g. a multi-source AAP)
``malformed-cmds``    error    unknown opcode in the command array
``copy-src-dup``      warning  a COPY whose ``c`` column does not duplicate its
                               ``b`` (src) column — the encoding invariant
``row-bounds``        error    a row operand outside the reserved ``row_index``
                               region (1-based, ``|code| <= n_rows``)
``bad-neg-port``      error    an n-wordline (negative) reference to a row that
                               is not a dual-contact cell
``tra-operand``       error    a triple-row activation naming a non-B-group row,
                               or fewer than three distinct rows
``use-before-init``   error    a read of a compute cell that was never written —
                               B-group cells power up with garbage
``const-write``       error    a write to the C0/C1 constant rows (read-only)
``operand-clobber``   error    a write to a row of a pure-input operand array —
                               the caller's data is still live there
``destroyed-read``    error    a Case-2 fused AAP copying from a row that the
                               preceding triple-row MAJ did not define — its
                               pre-activation charge is destroyed, not latched
``undefined-output``  error    a declared output row never written by the trace
``seam-clobber``      error    in a fused chain trace, a stage overwriting a row
                               of a predecessor's output value while a later
                               stage (or the chain output) still reads it
``bank-overlap``      warning  two co-scheduled requests from different tenants
                               share a bank and overlap on D-group rows
====================  ======== ==================================================

Fused chain traces (``trace.chain`` — see
:func:`repro.core.compiler.compile_chain`) additionally run the seam pass:
the row-liveness walk already crosses op boundaries because the fused
stream is one command array, and ``check_seams`` models the per-op
output→input handoff on top of it, flagging cross-stage clobbers of
still-live values.  :func:`lint_graph` is the pre-synthesis counterpart:
it verifies a user ``build_graph`` AOIG *before* Step 1 runs, so
malformed graphs fail at :meth:`SimdramMachine.define_op` with a graph
diagnostic instead of a downstream synthesis crash.

Verification is wired into every entry point that accepts a trace:
``compile_trace(..., verify=)`` / :meth:`TraceCache.get` (default-on; the
report is memoized on the trace so the cached hot path never re-lints),
``SimdramMachine.define_op`` (broken user ops are rejected at registration)
and ``BankScheduler.enqueue`` (the cross-trace ``bank-overlap`` pass).
``python -m repro.tools.tracelint`` sweeps every registered op × bit width.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Iterable, Sequence

from .uprogram import CELL_NAMES, DCC_CELLS

if TYPE_CHECKING:  # import cycle: trace.py lints lazily inside TraceCache
    from .trace import LoweredTrace

ERROR = "error"
WARNING = "warning"

# states of one physical row during the liveness walk
_UNDEF = 0       # B-group cell before its first write (power-up garbage)
_DEFINED = 1     # holds a value some command wrote
_ZERO = 2        # D row the runtime zero-fills before execution
_CONST = 3       # C0/C1 (read-only)
_OPERAND = 4     # D row of a pure-input array (caller data, read-only here)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: ``kind`` is machine-checkable, ``row_key`` human."""

    kind: str
    severity: str                 # ERROR or WARNING
    message: str
    cmd_index: int | None = None  # offending row of ``cmds`` (None: global)
    row: int | None = None        # signed row operand as encoded, if any
    row_key: str | None = None    # human name via the row_index inverse

    def __str__(self) -> str:
        where = f"cmd {self.cmd_index}: " if self.cmd_index is not None else ""
        return f"{self.severity}[{self.kind}] {where}{self.message}"


@dataclasses.dataclass
class LintReport:
    """Every diagnostic ``lint_trace`` produced for one trace."""

    name: str
    n_bits: int
    diagnostics: tuple[Diagnostic, ...] = ()

    @property
    def errors(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == WARNING)

    @property
    def ok(self) -> bool:
        """No errors (warnings do not fail verification)."""
        return not self.errors

    def kinds(self) -> set[str]:
        return {d.kind for d in self.diagnostics}

    def render(self) -> str:
        head = (f"TraceLint: {self.name}/{self.n_bits}b — "
                f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        return "\n".join([head] + [f"  {d}" for d in self.diagnostics])

    def raise_for_errors(self) -> "LintReport":
        if not self.ok:
            raise TraceLintError(self)
        return self


class TraceLintError(ValueError):
    """A trace failed static verification; ``.report`` has the findings."""

    def __init__(self, report: LintReport) -> None:
        super().__init__(report.render())
        self.report = report


def row_key_name(key: object) -> str:
    """Human name of one ``row_index`` key: ``T0``/``DCC1``, ``C0``/``C1``,
    or ``array[bit]`` for D-group rows."""
    if isinstance(key, tuple) and len(key) == 2 and key[0] == "cell":
        return CELL_NAMES.get(key[1], f"cell{key[1]}")
    if isinstance(key, tuple) and len(key) == 2:
        return f"{key[0]}[{key[1]}]"
    return str(key)


class _Linter:
    """One lint run; collects diagnostics over a single trace."""

    def __init__(self, trace: "LoweredTrace", max_diagnostics: int) -> None:
        self.trace = trace
        self.max = max_diagnostics
        self.out: list[Diagnostic] = []
        self.inv = {idx: key for key, idx in trace.row_index.items()}
        self.n_rows = len(trace.row_index)
        # row number → liveness state
        self.state: dict[int, int] = {}
        pure_inputs = set(trace.inputs) - set(trace.outputs)
        for key, idx in trace.row_index.items():
            if key in ("C0", "C1"):
                self.state[idx] = _CONST
            elif isinstance(key, tuple) and key[0] == "cell":
                self.state[idx] = _UNDEF
            elif isinstance(key, tuple) and key[0] in pure_inputs:
                self.state[idx] = _OPERAND
            else:
                # outputs/scratch/spills: the runtime zero-fills these rows
                # before the first command (executor ``alloc_operand``)
                self.state[idx] = _ZERO
        self.written: set[int] = set()

    # -- diagnostics ---------------------------------------------------------
    def emit(self, kind: str, severity: str, message: str,
             cmd_index: int | None = None, row: int | None = None) -> None:
        if len(self.out) >= self.max:
            return
        key = self.inv.get(abs(row)) if row is not None else None
        self.out.append(Diagnostic(
            kind=kind, severity=severity, message=message,
            cmd_index=cmd_index, row=row,
            row_key=row_key_name(key) if key is not None else None))

    # -- reference classification -------------------------------------------
    def _key(self, code: int) -> object:
        return self.inv.get(abs(int(code)))

    def _check_ref(self, code: int, i: int, what: str) -> bool:
        """Bounds + polarity of one signed row operand; True when usable."""
        r = abs(int(code))
        if r < 1 or r > self.n_rows:
            self.emit("row-bounds", ERROR,
                      f"{what} row {int(code)} is outside the reserved "
                      f"row_index region 1..{self.n_rows}", i, int(code))
            return False
        key = self._key(code)
        if code < 0 and not (isinstance(key, tuple) and key[0] == "cell"
                             and key[1] in DCC_CELLS):
            self.emit("bad-neg-port", ERROR,
                      f"{what} negates row {row_key_name(key)}, which has no "
                      f"n-wordline (only DCC cells do)", i, int(code))
            return False
        return True

    def _use(self, code: int, i: int, what: str) -> None:
        r = abs(int(code))
        if self.state.get(r) == _UNDEF:
            self.emit("use-before-init", ERROR,
                      f"{what} reads compute cell "
                      f"{row_key_name(self._key(code))} before any write — "
                      f"B-group cells power up with garbage", i, int(code))

    def _def(self, code: int, i: int, what: str) -> None:
        r = abs(int(code))
        st = self.state.get(r)
        key = self._key(code)
        if st == _CONST:
            self.emit("const-write", ERROR,
                      f"{what} writes constant row {row_key_name(key)} "
                      f"(C-group rows are read-only)", i, int(code))
            return
        if st == _OPERAND:
            self.emit("operand-clobber", ERROR,
                      f"{what} clobbers still-live operand row "
                      f"{row_key_name(key)} (array "
                      f"{key[0] if isinstance(key, tuple) else key!r} is a "
                      f"pure input — the caller's data lives there)",
                      i, int(code))
            return
        self.state[r] = _DEFINED
        self.written.add(r)

    # -- passes --------------------------------------------------------------
    def check_shapes(self) -> bool:
        cmds, seqs = self.trace.cmds, self.trace.seqs
        ok = True
        if cmds.ndim != 2 or (cmds.size and cmds.shape[1] != 4):
            self.emit("malformed-cmds", ERROR,
                      f"cmds must be int32[N, 4], got shape {cmds.shape}")
            ok = False
        if seqs.ndim != 2 or (seqs.size and seqs.shape[1] != 3):
            self.emit("malformed-seqs", ERROR,
                      f"seqs must be int32[M, 3], got shape {seqs.shape}")
            ok = False
        return ok

    def check_seqs(self) -> None:
        from .trace import (CMD_COPY, CMD_MAJ, SEQ_AAP, SEQ_AAP_TRA, SEQ_AP)
        cmds, seqs = self.trace.cmds, self.trace.seqs
        n = int(cmds.shape[0])
        cursor = 0
        for kind, start, end in seqs.tolist():
            if start != cursor:
                gap = "overlap" if start < cursor else "gap"
                self.emit("malformed-seqs", ERROR,
                          f"seqs table has a {gap}: sequence starts at "
                          f"cmd {start} but the previous one ended at "
                          f"{cursor}", min(start, cursor))
            if not (0 <= start < end <= n):
                self.emit("malformed-seqs", ERROR,
                          f"sequence span [{start}, {end}) falls outside "
                          f"the {n}-command array", start)
                cursor = max(cursor, end)
                continue
            ops = cmds[start:end, 0].tolist()
            if kind == SEQ_AP:
                if end - start != 1 or ops[0] != CMD_MAJ:
                    self.emit("malformed-seqs", ERROR,
                              f"AP sequence [{start}, {end}) must be exactly "
                              f"one MAJ command", start)
            elif kind == SEQ_AAP:
                srcs = {int(s) for s in cmds[start:end, 2].tolist()}
                if any(op != CMD_COPY for op in ops):
                    self.emit("malformed-seqs", ERROR,
                              f"AAP sequence [{start}, {end}) contains a "
                              f"non-COPY command", start)
                elif len(srcs) > 1:
                    self.emit("malformed-seqs", ERROR,
                              f"AAP sequence [{start}, {end}) copies from "
                              f"{len(srcs)} different source rows — one "
                              f"activation latches one row", start)
            elif kind == SEQ_AAP_TRA:
                if end - start < 2 or ops[0] != CMD_MAJ or \
                        any(op != CMD_COPY for op in ops[1:]):
                    self.emit("malformed-seqs", ERROR,
                              f"fused AAP sequence [{start}, {end}) must be "
                              f"one MAJ followed by COPY commands", start)
                else:
                    tra = {abs(int(c)) for c in cmds[start, 1:4].tolist()}
                    for j in range(start + 1, end):
                        src = int(cmds[j, 2])
                        if abs(src) not in tra:
                            self.emit(
                                "destroyed-read", ERROR,
                                f"fused AAP copies from row "
                                f"{row_key_name(self._key(src))}, which the "
                                f"preceding triple-row MAJ did not define — "
                                f"the sense amps hold the MAJ result and "
                                f"that row's pre-activation charge is "
                                f"destroyed", j, src)
            else:
                self.emit("malformed-seqs", ERROR,
                          f"unknown sequence kind {kind} at span "
                          f"[{start}, {end})", start)
            cursor = max(cursor, end)
        if cursor != n:
            self.emit("malformed-seqs", ERROR,
                      f"seqs table covers commands [0, {cursor}) but the "
                      f"command array has {n} rows", cursor)

    def check_liveness(self) -> None:
        from .trace import CMD_COPY, CMD_MAJ
        for i, (op, a, b, c) in enumerate(self.trace.cmds.tolist()):
            if op == CMD_COPY:
                if self._check_ref(b, i, "COPY src"):
                    self._use(b, i, "COPY")
                if self._check_ref(a, i, "COPY dst"):
                    self._def(a, i, "COPY")
                if c != b:
                    self.emit("copy-src-dup", WARNING,
                              f"COPY c column ({c}) does not duplicate the "
                              f"src column ({b}) — encoding invariant", i, c)
            elif op == CMD_MAJ:
                rows = []
                for code, what in ((a, "TRA port 1"), (b, "TRA port 2"),
                                   (c, "TRA port 3")):
                    if not self._check_ref(code, i, what):
                        continue
                    key = self._key(code)
                    if not (isinstance(key, tuple) and key[0] == "cell"):
                        self.emit("tra-operand", ERROR,
                                  f"{what} activates {row_key_name(key)} — "
                                  f"triple-row activation decodes B-group "
                                  f"cells only", i, int(code))
                        continue
                    rows.append(abs(int(code)))
                    self._use(code, i, what)
                if len(rows) == 3 and len(set(rows)) != 3:
                    self.emit("tra-operand", ERROR,
                              f"TRA activates only {len(set(rows))} distinct "
                              f"rows — a majority of three needs three", i,
                              int(a))
                # the activation overwrites all three cells with MAJ
                for r in set(rows):
                    self.state[r] = _DEFINED
                    self.written.add(r)
            else:
                self.emit("malformed-cmds", ERROR,
                          f"unknown opcode {op} (expected COPY=0 or MAJ=1)",
                          i)

    def check_outputs(self) -> None:
        end = int(self.trace.cmds.shape[0])
        for out in self.trace.outputs:
            rows = [(key, idx) for key, idx in self.trace.row_index.items()
                    if isinstance(key, tuple) and key[0] == out]
            if not rows:
                self.emit("undefined-output", ERROR,
                          f"output array {out!r} has no rows in this trace "
                          f"— nothing ever materializes it", end)
                continue
            for key, idx in rows:
                if idx not in self.written:
                    self.emit("undefined-output", ERROR,
                              f"output row {row_key_name(key)} is never "
                              f"written by the trace", end, idx)

    def check_seams(self) -> None:
        """Cross-op handoff pass for fused chain traces: model which rows
        carry each stage's output value and flag a *different* stage
        overwriting one of them while a later stage or the chain output
        still reads it (``seam-clobber``).  The producer materializing its
        own output rows is of course legal."""
        from .trace import CMD_COPY
        chain = self.trace.chain
        producer: dict[int, tuple[str, int]] = {}   # row → (value, stage)
        for k, stg in enumerate(chain.stages):
            for key, idx in self.trace.row_index.items():
                if isinstance(key, tuple) and key[0] == stg.value:
                    producer[idx] = (stg.value, k)
        if not producer:
            return
        outputs = set(self.trace.outputs)
        cmds = self.trace.cmds.tolist()
        last_read: dict[int, int] = {}
        for i, (op, _a, b, _c) in enumerate(cmds):
            if op == CMD_COPY and abs(int(b)) in producer:
                last_read[abs(int(b))] = i
        end = len(cmds)

        def stage_of(i: int) -> int:
            for k, stg in enumerate(chain.stages):
                if stg.cmd_start <= i < stg.cmd_end:
                    return k
            return -1

        for i, (op, a, _b, _c) in enumerate(cmds):
            if op != CMD_COPY:
                continue   # a MAJ writes B-group cells only, never D rows
            r = abs(int(a))
            hit = producer.get(r)
            if hit is None:
                continue
            value, k_prod = hit
            k_wr = stage_of(i)
            if k_wr == k_prod:
                continue
            live_until = end if value in outputs else last_read.get(r, -1)
            if live_until > i:
                wr_op = chain.stages[k_wr].op if k_wr >= 0 else "?"
                self.emit(
                    "seam-clobber", ERROR,
                    f"fused stage {k_wr} ({wr_op}) overwrites row "
                    f"{row_key_name(self._key(a))} of value {value!r} "
                    f"(produced by stage {k_prod}, "
                    f"{chain.stages[k_prod].op}) while it is still live — "
                    f"a later stage or the chain output still reads it",
                    i, int(a))

    def run(self) -> LintReport:
        if self.check_shapes():
            self.check_seqs()
            self.check_liveness()
            self.check_outputs()
            if getattr(self.trace, "chain", None) is not None:
                self.check_seams()
        return LintReport(name=self.trace.name, n_bits=self.trace.n_bits,
                          diagnostics=tuple(self.out))


def lint_trace(trace: "LoweredTrace",
               max_diagnostics: int = 100) -> LintReport:
    """Statically verify one lowered trace; returns every diagnostic.

    Runs the seqs-table structural pass and the row-liveness def/use pass
    described in the module docstring.  Nothing is executed.  Use
    :meth:`LoweredTrace.lint` for the memoized per-trace report, and
    :meth:`LintReport.raise_for_errors` to turn errors into
    :class:`TraceLintError`.
    """
    return _Linter(trace, max_diagnostics).run()


# ---------------------------------------------------------------------------
# Pre-synthesis pass: user build_graph AOIGs
# ---------------------------------------------------------------------------


def lint_graph(g, name: str = "graph",
               max_diagnostics: int = 100) -> LintReport:
    """Statically verify a user AOIG/MIG *before* synthesis runs.

    ``machine.define_op(build_graph=...)`` accepts arbitrary user code; a
    malformed graph used to surface as a crash deep inside Step-1
    synthesis or row allocation.  This pass checks the graph itself:

    * ``graph-no-outputs`` (error) — no named outputs: the op would
      synthesize to an empty trace;
    * ``graph-dup-output`` (error) — two outputs share a name (the later
      one silently wins downstream);
    * ``graph-bad-literal`` (error) — an output or gate fanin literal
      referencing a node id outside the graph;
    * ``graph-unused-input`` (warning) — a primary input no output
      transitively depends on.
    """
    from .graph import PI, lit_node
    out: list[Diagnostic] = []

    def emit(kind: str, severity: str, message: str) -> None:
        if len(out) < max_diagnostics:
            out.append(Diagnostic(kind=kind, severity=severity,
                                  message=message))

    n = len(g.nodes)
    if not g.outputs:
        emit("graph-no-outputs", ERROR,
             "graph declares no outputs — it would synthesize to an "
             "empty operation")
    seen: set[str] = set()
    for oname, lit_ in g.outputs:
        if oname in seen:
            emit("graph-dup-output", ERROR,
                 f"output {oname!r} is declared twice — the later "
                 f"definition silently wins downstream")
        seen.add(oname)
        if not (0 <= lit_node(lit_) < n):
            emit("graph-bad-literal", ERROR,
                 f"output {oname!r} references node {lit_node(lit_)} "
                 f"outside the {n}-node graph")
    for nid, node in enumerate(g.nodes):
        for f in node.fanin:
            if not (0 <= lit_node(f) < n):
                emit("graph-bad-literal", ERROR,
                     f"node {nid} ({node.kind}) fanin references node "
                     f"{lit_node(f)} outside the {n}-node graph")
    live: set[int] = set()
    stack = [lit_node(lit_) for _, lit_ in g.outputs
             if 0 <= lit_node(lit_) < n]
    while stack:
        nid = stack.pop()
        if nid in live:
            continue
        live.add(nid)
        stack.extend(lit_node(f) for f in g.nodes[nid].fanin
                     if 0 <= lit_node(f) < n)
    for nid, node in enumerate(g.nodes):
        if node.kind == PI and nid not in live:
            emit("graph-unused-input", WARNING,
                 f"primary input {node.name!r} feeds no output")
    return LintReport(name=name, n_bits=0, diagnostics=tuple(out))


# ---------------------------------------------------------------------------
# Cross-trace pass: bank packing
# ---------------------------------------------------------------------------


def row_footprint(trace: "LoweredTrace") -> frozenset:
    """The D-group row keys a trace touches — the rows that persist in a
    subarray between requests (B/C rows are per-op working state)."""
    return frozenset(trace.d_rows)


def lint_packing(
        requests: Sequence[tuple[str, str, frozenset, Iterable[int]]],
        max_diagnostics: int = 100) -> list[Diagnostic]:
    """Flag co-scheduled requests from different tenants that share a bank
    with overlapping D-row footprints.

    ``requests`` rows are ``(name, tenant, footprint, bank_ids)`` in
    submission order (``footprint`` from :func:`row_footprint`).  The
    scheduler serializes streams per bank, but operand/output rows persist
    in the subarray across requests — two tenants packed onto one bank with
    the same row keys read and overwrite each other's data.
    """
    out: list[Diagnostic] = []
    seen: list[tuple[str, str, frozenset, set[int]]] = []
    for name, tenant, fp, bank_ids in requests:
        banks = set(int(b) for b in bank_ids)
        for p_name, p_tenant, p_fp, p_banks in seen:
            if len(out) >= max_diagnostics:
                return out
            if p_tenant == tenant:
                continue
            shared = banks & p_banks
            overlap = fp & p_fp
            if shared and overlap:
                rows = ", ".join(sorted(row_key_name(k) for k in overlap)[:4])
                more = len(overlap) - min(len(overlap), 4)
                out.append(Diagnostic(
                    kind="bank-overlap", severity=WARNING,
                    message=(
                        f"request {name!r} (tenant {tenant!r}) and "
                        f"{p_name!r} (tenant {p_tenant!r}) are co-scheduled "
                        f"on bank(s) {sorted(shared)} with {len(overlap)} "
                        f"overlapping row(s): {rows}"
                        + (f" (+{more} more)" if more else "")),
                    row_key=rows))
        seen.append((name, tenant, fp, banks))
    return out
