"""Trace-time unrolled μProgram execution — the TPU-native fast path.

The faithful executor (``repro.core.executor``) models every AAP/AP against a
stateful subarray.  On TPU, the same μProgram is *unrolled at trace time*
into pure bitwise jnp ops over packed bit-planes:

* an AAP (RowClone copy) becomes a Python-level aliasing of the value — the
  TPU analogue of RowClone's zero-cost in-array copy is a register rename,
  which costs nothing in the compiled HLO;
* an AP (TRA majority) becomes ``(a&b)|(a&c)|(b&c)`` on uint32 words — 32
  SIMD lanes per word per VPU lane;
* dual-contact-cell reads become ``~x``.

Because copies vanish and constant rows fold, the compiled HLO contains only
the live majority dataflow — this is the "beyond-paper" optimized backend.
The Pallas kernel in ``repro.kernels.uprog_executor`` executes the same
command stream inside a VMEM tile for explicitly-managed memory traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .uprogram import AAP, AP, CRow, DRow, Port, UProgram

FULL = jnp.uint32(0xFFFFFFFF)


def _maj(a, b, c):
    return (a & b) | (a & c) | (b & c)


class _Env:
    """Value environment: D rows (by (array,bit)) + B cells.  Values are
    uint32[W] arrays or the python constants 0 / FULL."""

    def __init__(self, operands: dict[str, jax.Array], words: int) -> None:
        self.words = words
        self.d: dict[tuple[str, int], object] = {}
        self.cells: list = [jnp.zeros((words,), jnp.uint32)] * 6
        for name, planes in operands.items():
            for i in range(planes.shape[0]):
                self.d[(name, i)] = planes[i]
        self.zero = jnp.zeros((words,), jnp.uint32)
        self.one = jnp.full((words,), FULL)

    def read(self, ref):
        if isinstance(ref, Port):
            v = self.cells[ref.cell]
            return (~v).astype(jnp.uint32) if ref.neg else v
        if isinstance(ref, CRow):
            return self.one if ref.one else self.zero
        if isinstance(ref, DRow):
            return self.d.get((ref.array, ref.bit), self.zero)
        raise TypeError(ref)

    def write(self, ref, val) -> None:
        if isinstance(ref, Port):
            self.cells[ref.cell] = (~val).astype(jnp.uint32) if ref.neg else val
        elif isinstance(ref, DRow):
            self.d[(ref.array, ref.bit)] = val
        else:
            raise TypeError(ref)


def run_trace_unrolled(trace, operands: dict[str, jax.Array],
                       out_bits: dict[str, int] | None = None,
                       ) -> dict[str, jax.Array]:
    """Execute a :class:`~repro.core.trace.LoweredTrace` by scanning its
    command array at trace time — the registered ``unrolled`` backend.

    Semantically identical to :func:`run_unrolled` on the source μProgram
    (COPY commands alias values, MAJ commands become bitwise majority,
    negative row operands read/write complements through DCC ports), but
    consumes the same lowered IR as the ``pallas`` FSM kernel and the
    ``reference`` decoder instead of re-walking μOp dataclasses per call.
    """
    words = next(iter(operands.values())).shape[1]
    zero = jnp.zeros((words,), jnp.uint32)
    rows: list = [zero] * trace.n_rows
    for key in trace.d_rows:
        arr, bit = key
        if arr in operands and bit < operands[arr].shape[0]:
            rows[trace.row_index[key] - 1] = operands[arr][bit]
    rows[trace.row_index["C1"] - 1] = jnp.full((words,), FULL)

    def read(i: int):
        v = rows[-i - 1 if i < 0 else i - 1]
        return (~v).astype(jnp.uint32) if i < 0 else v

    def write(i: int, val) -> None:
        rows[-i - 1 if i < 0 else i - 1] = \
            (~val).astype(jnp.uint32) if i < 0 else val

    for op, a, b, c in trace.cmds.tolist():
        if op == 1:                      # MAJ (AP / fused-AAP first activate)
            res = _maj(read(a), read(b), read(c))
            write(a, res)
            write(b, res)
            write(c, res)
        else:                            # COPY (one AAP destination)
            write(a, read(b))
    out_bits = out_bits or {}
    outs = {}
    for name in trace.outputs:
        nb = out_bits.get(name, trace.n_bits)
        outs[name] = jnp.stack([rows[i] for i in trace.out_row_ids(name, nb)])
    return outs


def run_unrolled(prog: UProgram, operands: dict[str, jax.Array],
                 out_bits: dict[str, int] | None = None,
                 ) -> dict[str, jax.Array]:
    """Execute a μProgram over jnp bit-plane operands.

    operands: array name → uint32[n_bits, W].
    Returns: output array name → uint32[out_bits, W].
    """
    words = next(iter(operands.values())).shape[1]
    env = _Env(operands, words)
    for u in prog.flatten():
        if isinstance(u, AP):
            vals = [env.read(p) for p in u.ports]
            res = _maj(*vals)
            for p in u.ports:
                env.write(p, res)
        elif isinstance(u, AAP):
            if isinstance(u.src, tuple):
                vals = [env.read(p) for p in u.src]
                bit = _maj(*vals)
                for p in u.src:
                    env.write(p, bit)
            else:
                bit = env.read(u.src)
            for d in u.dsts:
                env.write(d, bit)
        else:
            raise TypeError(u)
    out_bits = out_bits or {}
    outs = {}
    for name in prog.outputs:
        nb = out_bits.get(name, prog.n_bits)
        outs[name] = jnp.stack([env.d.get((name, i), env.zero)
                                for i in range(nb)])
    return outs
