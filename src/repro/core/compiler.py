"""Step 2 of the SIMDRAM framework: MIG → μProgram (paper §4.2, App. B).

Implements

* **Task 1 — row-to-operand allocation** (paper Algorithm 1): a greedy,
  topological traversal of the MIG that binds each MAJ operand to one of the
  six B-group compute rows, honoring the two PuM constraints the paper calls
  out: TRAs destroy all three input rows, and only six compute rows exist.
  Negated operands are routed through dual-contact cells (Case 1 of Alg. 1);
  operands produced by a parent MAJ reuse the rows holding the parent's
  result (Case 2); when no compute row is free the allocator closes the
  current *phase* — in our implementation this surfaces as a preservation
  copy or a spill to a D-group scratch row (Case 3).

* **Task 2 — μOp generation + coalescing** (paper §4.2.3): emission of
  AAP/AP command sequences per MAJ node, followed by the paper's two
  coalescing optimizations — Case 1 (multiple copies from one source fuse
  into a single multi-row AAP using a pair address) and Case 2 (an AP
  followed by an AAP reading the TRA result fuses into one AAP whose first
  ACTIVATE performs the majority) — and generalization of the 1-bit body
  into an n-bit loop (the control unit's addi/bnez/done μOps).

The scheduler runs a *steady-state fixpoint* for loop-carried state (e.g.
the carry row of an adder): the home cell of each state is chosen so that
the value naturally ends the body where the next iteration reads it,
eliminating fix-up copies — this is what lets the compiler reproduce the
paper's Table 5 command counts (e.g. 8n+1 for addition) exactly.
"""
from __future__ import annotations

import bisect
import dataclasses

from .graph import MAJ, PI, LogicGraph, lit_neg, lit_node
from .uprogram import (AAP, AP, C0, C1, CRow, DCC_CELLS, DRow, N_B_CELLS,
                       PAIR_ADDRESSES, Port, T_CELLS, UProgram,
                       dedupe_const_stores, eliminate_dead_writes,
                       rename_uops)

# value ids: int MIG node ids for MAJ results; strings for PI values.
Value = object


@dataclasses.dataclass
class CellInfo:
    value: Value | None = None
    neg: bool = False            # cell stores complement of `value`


class AllocationError(RuntimeError):
    pass


class Scheduler:
    """Schedules one MIG into AAP/AP μOps over the six compute rows."""

    def __init__(self, mig: LogicGraph, binding: dict[str, object],
                 out_targets: dict[str, object], state_outputs: dict[str, str],
                 scratch_prefix: str = "spill") -> None:
        """
        binding: PI name → RowRef (DRow/CRow) or Port (state entry location).
        out_targets: output name → DRow destination (None = keep in cells).
        state_outputs: output name → state name (value must survive to end).
        """
        self.mig = mig
        self.binding = binding
        self.out_targets = out_targets
        self.state_outputs = state_outputs
        self.cells = [CellInfo() for _ in range(N_B_CELLS)]
        self.ops: list = []
        self.spills: dict[Value, DRow] = {}
        self.n_spills = 0
        self.scratch_prefix = scratch_prefix
        self._prepare()

    # ------------------------------------------------------------------ prep
    def _prepare(self) -> None:
        g = self.mig
        self.order = [n for n in g.topo_order() if g.nodes[n].kind == MAJ]
        self.uses: dict[Value, int] = {}
        self.neg_uses: dict[Value, int] = {}
        self.pi_value: dict[int, Value] = {}
        for nid in g.topo_order():
            node = g.nodes[nid]
            if node.kind == PI:
                self.pi_value[nid] = f"pi:{node.name}"
        for nid in self.order:
            for f in self.mig.nodes[nid].fanin:
                v = self._val_of(f)
                if v is None:
                    continue
                self.uses[v] = self.uses.get(v, 0) + 1
                if lit_neg(f):
                    self.neg_uses[v] = self.neg_uses.get(v, 0) + 1
        for name, o in g.outputs:
            v = self._val_of(o)
            if v is None:
                continue
            self.uses[v] = self.uses.get(v, 0) + 1
            if lit_neg(o):
                self.neg_uses[v] = self.neg_uses.get(v, 0) + 1
        # seed state entry locations
        for pi_name, ref in self.binding.items():
            if isinstance(ref, Port):
                cell = ref.cell
                self.cells[cell] = CellInfo(f"pi:{pi_name}", ref.neg)

    def _val_of(self, literal: int) -> Value | None:
        nid = lit_node(literal)
        node = self.mig.nodes[nid]
        if node.kind == PI:
            return self.pi_value[nid]
        if node.kind == MAJ:
            return nid
        return None  # constant

    # ------------------------------------------------------------- utilities
    def _readable_ports(self, v: Value, neg: bool) -> list[Port]:
        """All ports currently reading value (neg ? ¬v : v)."""
        ports = []
        for cell, info in enumerate(self.cells):
            if info.value != v:
                continue
            if cell in DCC_CELLS:
                # positive port reads info stored polarity; neg port flips
                ports.append(Port(cell, neg=(info.neg != neg)))
            elif info.neg == neg:
                ports.append(Port(cell))
        return [p for p in ports if not (p.neg and p.cell not in DCC_CELLS)]

    def _cells_holding(self, v: Value) -> list[int]:
        return [c for c, info in enumerate(self.cells) if info.value == v]

    def _source_for(self, v: Value, neg: bool):
        """A copy source (RowRef) for value v with polarity neg, or None."""
        ports = self._readable_ports(v, neg)
        if ports:
            return ports[0]
        if isinstance(v, str) and v.startswith("const:"):
            one = v.endswith("1")
            return (C0 if one else C1) if neg else (C1 if one else C0)
        if isinstance(v, str) and v.startswith("pi:"):
            ref = self.binding[v[3:]]
            if isinstance(ref, (DRow, CRow)) and not neg:
                return ref
            if isinstance(ref, CRow) and neg:
                return C1 if not ref.one else C0
        if v in self.spills:
            row, spill_neg = self.spills[v]
            if spill_neg == neg:
                return row
        return None

    def _is_recopyable(self, v: Value) -> bool:
        """Values that live in D/C rows can always be re-materialized."""
        if isinstance(v, str) and v.startswith("const:"):
            return True
        if isinstance(v, str) and v.startswith("pi:"):
            return isinstance(self.binding[v[3:]], (DRow, CRow))
        return v in self.spills

    def _reserved_cells(self, protect: set[int]) -> set[int]:
        """One surviving cell per live, non-recopyable value (otherwise two
        cells holding the same value each treat the other as a backup and
        both get reallocated, losing the value entirely)."""
        reserved: set[int] = set()
        by_value: dict[Value, list[int]] = {}
        for cell, info in enumerate(self.cells):
            if info.value is not None:
                by_value.setdefault(info.value, []).append(cell)
        for v, cells in by_value.items():
            if self.uses.get(v, 0) <= 0 or self._is_recopyable(v):
                continue
            keep = [c for c in cells if c not in protect] or cells
            # prefer keeping a DCC copy if the value still has negated uses
            if self.neg_uses.get(v, 0) > 0:
                dcc = [c for c in keep if c in DCC_CELLS]
                keep = dcc or keep
            reserved.add(keep[0])
        return reserved

    def _free_cells(self, protect: set[int]) -> list[int]:
        reserved = self._reserved_cells(protect)
        free = [c for c in range(N_B_CELLS)
                if c not in protect and c not in reserved]

        # prefer truly-dead cells first, T cells before DCC
        def rank(c):
            info = self.cells[c]
            dead = info.value is None or self.uses.get(info.value, 0) <= 0
            return (0 if dead else 1, 0 if c in T_CELLS else 1)
        return sorted(free, key=rank)

    def _emit_copy(self, src, dst_ports: tuple[Port, ...]) -> None:
        self.ops.append(AAP(src, tuple(dst_ports)))

    def _copy_into(self, v: Value, neg: bool, want_dcc: bool,
                   protect: set[int], extra_copies: int = 0) -> Port:
        """Materialize value v (polarity ``neg``) into a fresh cell; returns
        the port to read it from.  ``extra_copies``>0 requests pair-address
        coalescing (paper Case 1) when another copy of the same value will be
        needed."""
        src = self._source_for(v, False)
        src_neg = False
        if src is None:
            src = self._source_for(v, True)
            src_neg = True
        if src is None:
            raise AllocationError(f"value {v} is not materializable")
        # reading src gives (v ⊕ src_neg); we want polarity `neg` at the port.
        # If polarities mismatch and we must flip, route through a DCC cell.
        need_flip = (src_neg != neg)
        must_dcc = need_flip or want_dcc
        free = self._free_cells(protect)
        dcc_free = [c for c in free if c in DCC_CELLS]
        t_free = [c for c in free if c in T_CELLS]
        if must_dcc and not dcc_free:
            if need_flip:
                # a DCC row is mandatory: spill a DCC resident to free one
                self._make_room(protect, need_dcc=True)
                dcc_free = [c for c in self._free_cells(protect)
                            if c in DCC_CELLS]
                if not dcc_free:
                    raise AllocationError("no DCC cell free for negated operand")
            else:
                # fall back: copy through a T cell (no polarity flip needed)
                must_dcc = False
        pool = dcc_free if must_dcc else (t_free or dcc_free)
        if not pool:
            # Alg. 1 Case 3: the phase is full — free a row by spilling the
            # live value with the most distant next use to a D-group scratch
            # row, then retry.
            self._make_room(protect, need_dcc=must_dcc)
            free = self._free_cells(protect)
            dcc_free = [c for c in free if c in DCC_CELLS]
            t_free = [c for c in free if c in T_CELLS]
            pool = dcc_free if must_dcc else (t_free or dcc_free)
            if not pool:
                raise AllocationError("no free compute row (phase overflow)")
        dst = pool[0]
        dsts = [Port(dst)]
        if extra_copies > 0:
            # paper Case-1 coalescing: same source into a fixed pair address
            for pair in PAIR_ADDRESSES:
                cells = {p.cell for p in pair}
                if dst in cells:
                    other = (cells - {dst}).pop()
                    if other in free and other not in protect:
                        dsts = list(pair)
                        break
        self._emit_copy(src, tuple(dsts))
        for p in dsts:
            # cell stores bitline (=v⊕src_neg) through port polarity
            self.cells[p.cell] = CellInfo(v, neg=(src_neg != p.neg))
        ports = self._readable_ports(v, neg)
        ports = [p for p in ports if p.cell in {d.cell for d in dsts}]
        if not ports:
            raise AllocationError("copy did not yield requested polarity")
        return ports[0]

    # ------------------------------------------------------------- main pass
    def run(self) -> None:
        self._cursor = 0
        for i, nid in enumerate(self.order):
            self._cursor = i
            self._schedule_node(nid)
        self._cursor = len(self.order)
        self._emit_outputs()

    def _future_copy_need(self, v: Value, from_node_idx: int) -> int:
        """How many additional positive-polarity materializations of v the
        remaining nodes will need (drives pair coalescing)."""
        need = 0
        for nid in self.order[from_node_idx:]:
            for f in self.mig.nodes[nid].fanin:
                if self._val_of(f) == v and not lit_neg(f):
                    need += 1
        return need

    def _schedule_node(self, nid: int) -> None:
        g = self.mig
        node = g.nodes[nid]
        idx = self.order.index(nid)
        # does this node's RESULT need a future negated read?  if so, one
        # operand should sit in a DCC cell so the result lands there.
        result_needs_neg = self.neg_uses.get(nid, 0) > 0
        ports: list[Port] = []
        used_cells: set[int] = set()
        operands = []
        for f in node.fanin:
            v = self._val_of(f)
            operands.append((v, lit_neg(f)))
        # preservation (Alg.1 Case 3 / phase handling): if this AP will
        # consume the last live copy of a value still needed later and the
        # value cannot be re-copied from a D row, save it first.
        self._preserve_live_values(operands, used_cells)
        have_dcc = False
        # first pass: satisfy from existing cells
        pending = []
        for v, neg in operands:
            if v is None:
                pending.append((v, neg, None))
                continue
            cand = [p for p in self._readable_ports(v, neg) if p.cell not in used_cells]
            if cand:
                # consume a *surplus* copy if possible: reading a cell through
                # a TRA destroys it, so prefer cells that are not the value's
                # reserved survivor (unless this is its final use), and avoid
                # burning a DCC that negated uses still need.
                uses_after = self.uses.get(v, 0) - 1
                reserved = self._reserved_cells(used_cells) if uses_after > 0 else set()
                cand.sort(key=lambda p: (p.cell in reserved, p.cell in DCC_CELLS))
                p = cand[0]
                ports.append(p)
                used_cells.add(p.cell)
                have_dcc = have_dcc or p.cell in DCC_CELLS
                self.uses[v] -= 1
                pending.append(None)
            else:
                pending.append((v, neg, "copy"))
        # second pass: constants and copies — materialize negated operands
        # first (they are the ones that must land in scarce DCC cells)
        pend_order = sorted((k for k, x in enumerate(pending) if x is not None),
                            key=lambda k: not pending[k][1])
        for k in pend_order:
            item = pending[k]
            v, neg, _ = item
            if v is None:  # constant input (C-group row copied into a T row)
                one = lit_neg(node.fanin[k])
                p = self._copy_into(f"const:{int(one)}", False, False, used_cells)
                ports.append(p)
                used_cells.add(p.cell)
                continue
            want_dcc = (result_needs_neg and not have_dcc and not neg)
            extra = self._future_copy_need(v, idx + 1) if not neg else 0
            p = self._copy_into(v, neg, want_dcc or neg, used_cells,
                                extra_copies=extra)
            ports.append(p)
            used_cells.add(p.cell)
            have_dcc = have_dcc or p.cell in DCC_CELLS
            self.uses[v] -= 1
        if len({p.cell for p in ports}) != 3:
            raise AllocationError(f"node {nid}: could not place 3 operands")
        self.ops.append(AP(tuple(ports)))
        for p in ports:
            self.cells[p.cell] = CellInfo(nid, neg=p.neg)

    def _preserve_live_values(self, operands, protect: set[int]) -> None:
        """Before an AP, copy out any value whose last cell copy the AP will
        destroy while later uses remain and no D-row source exists.

        Preservation copies are added to ``protect`` (shared with the node's
        port selection) so that (a) a later operand's preservation cannot
        clobber them and (b) the AP does not consume the survivor."""
        # how many DCC cells must stay available for this node's own negated,
        # non-resident operands (they can only be materialized through a DCC)
        dcc_demand = sum(
            1 for v, neg in operands
            if v is not None and neg and not self._readable_ports(v, True))
        seen: set[Value] = set()
        for v, _neg in operands:
            if v is None or v in seen or self._is_recopyable(v):
                continue
            seen.add(v)
            holding = self._cells_holding(v)
            n_operand_uses = sum(1 for (vv, _) in operands if vv == v)
            uses_after = self.uses.get(v, 0) - n_operand_uses
            if uses_after <= 0:
                continue
            # cells of v that are protected (outside this AP) survive
            survivors = len([c for c in holding if c in protect])
            consumable = len(holding) - survivors
            if survivors >= 1 or consumable > n_operand_uses:
                continue
            free_dcc = sum(1 for c in self._free_cells(set(holding) | protect)
                           if c in DCC_CELLS)
            neg_needed = (self.neg_uses.get(v, 0) > 0
                          and free_dcc > dcc_demand)
            try:
                p = self._copy_into(v, False, want_dcc=neg_needed,
                                    protect=set(holding) | protect,
                                    extra_copies=uses_after - 1)
                protect.add(p.cell)
            except AllocationError:
                self._spill(v, protect=set(holding) | protect)

    def _spill(self, v: Value, protect: set[int]) -> None:
        spill_neg = False
        src = self._source_for(v, False)
        if src is None:
            src = self._source_for(v, True)   # spill the complement instead
            spill_neg = True
        if src is None:
            raise AllocationError(f"cannot spill {v}: no source")
        row = DRow(f"{self.scratch_prefix}{self.n_spills}", 0, fixed=True)
        self.n_spills += 1
        self._emit_copy(src, (row,))
        self.spills[v] = (row, spill_neg)

    def _make_room(self, protect: set[int], need_dcc: bool) -> None:
        """Spill the live, non-recopyable value with the most distant next
        use so one of its cells becomes free (Alg. 1 phase boundary)."""
        victims: list[tuple[int, Value]] = []
        for cell, info in enumerate(self.cells):
            if cell in protect or info.value is None:
                continue
            if need_dcc and cell not in DCC_CELLS:
                continue
            v = info.value
            if self.uses.get(v, 0) <= 0 or self._is_recopyable(v):
                continue
            victims.append((self._next_use_distance(v), v))
        if not victims:
            raise AllocationError("no spill victim available")
        victims.sort(reverse=True)
        self._spill(victims[0][1], protect)

    def _next_use_distance(self, v: Value) -> int:
        for d, nid in enumerate(self.order[getattr(self, "_cursor", 0):]):
            for f in self.mig.nodes[nid].fanin:
                if self._val_of(f) == v:
                    return d
        return 1 << 30

    # ------------------------------------------------------------- outputs
    def _emit_outputs(self) -> None:
        for name, o in self.mig.outputs:
            target = self.out_targets.get(name)
            if target is None:
                continue
            v = self._val_of(o)
            neg = lit_neg(o)
            if v is None:  # constant output
                self._emit_copy(C1 if neg else C0, (target,))
                continue
            src = self._source_for(v, neg)
            if src is None:
                # flip through DCC
                p = self._copy_into(v, neg, want_dcc=True, protect=set())
                src = p
            self._emit_copy(src, (target,))
            self.uses[v] -= 1

    def end_cells_of(self, v: Value) -> list[tuple[int, bool]]:
        return [(c, info.neg) for c, info in enumerate(self.cells) if info.value == v]


# ---------------------------------------------------------------------------
# Peephole: paper Case-2 coalescing (AP followed by AAP reading the result)
# ---------------------------------------------------------------------------

def coalesce_case2(ops: list) -> list:
    out: list = []
    for u in ops:
        if (isinstance(u, AAP) and isinstance(u.src, Port) and out
                and isinstance(out[-1], AP)):
            ap = out[-1]
            match = [q for q in ap.ports if q.cell == u.src.cell]
            if match and match[0].neg == u.src.neg:
                # the AAP reads exactly the TRA bitline → fuse
                out[-1] = AAP(ap.ports, u.dsts)
                continue
        out.append(u)
    return out


# ---------------------------------------------------------------------------
# Slice-op compilation driver (n-bit loop generalization)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SliceSpec:
    """A 1-bit slice of an n-bit operation (paper: 'MIG represents a
    1-bit-wide computation')."""
    name: str
    build: object                  # fn(g: LogicGraph) -> None
    arrays_in: tuple[str, ...]     # PIs bound to DRow(array, i)
    invariants: dict = dataclasses.field(default_factory=dict)  # PI → DRow fixed
    states: dict = dataclasses.field(default_factory=dict)      # state → init (0/1)
    out_array: str | None = "out"  # per-bit output PI target array
    epilogue_outputs: dict = dataclasses.field(default_factory=dict)
    # output name → (array, bit): written once after the loop (e.g. borrow)


STATE_HOME_GUESS = [3, 2, 1, 0]  # T3, T2, T1, T0


def compile_slice(spec: SliceSpec, n_bits: int, optimize: bool = True,
                  mig: LogicGraph | None = None) -> UProgram:
    """Compile a slice MIG into an n-bit μProgram with steady-state homes."""
    from .synthesis import aoig_to_mig_naive, optimize_mig

    g = LogicGraph()
    spec.build(g)
    # Step 1: optimize=True is the SIMDRAM pipeline; optimize=False keeps the
    # naive AND/OR→MAJ substitution (the paper's Ambit baseline).
    g = optimize_mig(g) if optimize else aoig_to_mig_naive(g)
    state_names = list(spec.states)

    def schedule(homes: dict[str, tuple[int, bool]]):
        binding: dict[str, object] = {}
        for a in spec.arrays_in:
            binding[a] = DRow(a, 0)
        for pi, row in spec.invariants.items():
            binding[pi] = row
        for s in state_names:
            cell, neg = homes[s]
            binding[s] = Port(cell, neg=neg and cell in DCC_CELLS)
        out_targets: dict[str, object] = {}
        state_out_map: dict[str, str] = {}
        for name, _ in g.outputs:
            if name in spec.states:
                state_out_map[name] = name
                out_targets[name] = None
            elif spec.out_array is not None and name not in spec.epilogue_outputs:
                out_targets[name] = DRow(spec.out_array, 0)
        sched = Scheduler(g, binding, out_targets, state_out_map,
                          scratch_prefix=f"{spec.name}_sp")
        sched.run()
        return sched

    # fixpoint on state home cells: the body is rescheduled until each
    # loop-carried value naturally ends the iteration in the cell the next
    # iteration reads it from; if the fixpoint does not converge, explicit
    # fix-up copies are appended to the body instead.
    def state_end_locs(sched):
        locs = {}
        taken: set[int] = set()
        for name, o in g.outputs:
            if name not in spec.states:
                continue
            v = sched._val_of(o)
            want_neg = lit_neg(o)
            cands = []
            for c, cell_neg in sched.end_cells_of(v):
                if c in taken:
                    continue
                eff_neg = cell_neg != want_neg  # True → cell stores ¬state
                if not eff_neg or c in DCC_CELLS:
                    cands.append((c, eff_neg))
            locs[name] = cands
        return locs

    homes = {s: (STATE_HOME_GUESS[i % 4], False) for i, s in enumerate(state_names)}
    sched = schedule(homes)
    for _ in range(4):
        locs = state_end_locs(sched)
        new_homes = dict(homes)
        taken: set[int] = set()
        converged = True
        for name in state_names:
            cands = [c for c in locs.get(name, []) if c[0] not in taken]
            if not cands:
                converged = False
                continue
            best = homes[name] if homes[name] in cands else cands[0]
            taken.add(best[0])
            new_homes[name] = best
            if best != homes[name]:
                converged = False
        if converged:
            break
        homes = new_homes
        sched = schedule(homes)
    # verify; append fix-up copies for any state not ending at its home
    fixups: list = []
    locs = state_end_locs(sched)
    for name in state_names:
        home = homes[name]
        cands = locs.get(name, [])
        if home in cands:
            continue
        if not cands:
            raise AllocationError(f"state {name} does not survive the body")
        c, eff_neg = cands[0]
        src = Port(c, neg=False) if not eff_neg else Port(c, neg=True)
        cell, want_store_neg = home
        dst = Port(cell, neg=want_store_neg and cell in DCC_CELLS)
        if want_store_neg and cell not in DCC_CELLS:
            raise AllocationError(f"state {name}: fix-up needs DCC home")
        fixups.append(AAP(src, (dst,)))

    body = coalesce_case2(sched.ops) + fixups
    # prologue: state init (from C-group constant rows, or from a D row for
    # data-dependent initial state such as abs' sign-extend carry)
    prologue: list = []
    for s in state_names:
        cell, neg = homes[s]
        init = spec.states[s]
        if isinstance(init, DRow):
            if neg and cell not in DCC_CELLS:
                raise AllocationError(f"state {s}: negated init needs a DCC home")
            prologue.append(AAP(init, (Port(cell, neg=neg),)))
        else:
            src = (C0 if init else C1) if neg else (C1 if init else C0)
            prologue.append(AAP(src, (Port(cell),)))
    epilogue: list = []
    for name, (arr, bit) in spec.epilogue_outputs.items():
        o = dict(g.outputs)[name]
        v = sched._val_of(o)
        locs = sched.end_cells_of(v)
        want_neg = lit_neg(o)
        port = None
        for c, cell_neg in locs:
            if cell_neg == want_neg:
                port = Port(c)
                break
            if c in DCC_CELLS:
                port = Port(c, neg=True)
                break
        if port is None and locs:
            # bounce through a dual-contact cell to obtain the complement
            c, cell_neg = locs[0]
            bounce = DCC_CELLS[0] if locs[0][0] != DCC_CELLS[0] else DCC_CELLS[1]
            epilogue.append(AAP(Port(c), (Port(bounce),)))
            port = Port(bounce, neg=(cell_neg == want_neg) is False)
        if port is None:
            raise AllocationError(f"epilogue output {name} unreadable")
        epilogue.append(AAP(port, (DRow(arr, bit, fixed=True),)))

    scratch = tuple(sorted({r.array for u in body + prologue + epilogue
                            for r in _drows(u) if r.array.endswith("_sp0") or
                            "_sp" in r.array}))
    inputs = tuple(spec.arrays_in) + tuple(
        r.array for r in (spec.invariants or {}).values() if isinstance(r, DRow))
    return UProgram(name=spec.name, n_bits=n_bits, prologue=prologue,
                    body=body, epilogue=epilogue, inputs=inputs,
                    outputs=(spec.out_array,) if spec.out_array else
                    tuple(a for a, _ in spec.epilogue_outputs.values()),
                    scratch=scratch)


def _drows(u) -> list[DRow]:
    rows = []
    if isinstance(u, AAP):
        if isinstance(u.src, DRow):
            rows.append(u.src)
        rows.extend(d for d in u.dsts if isinstance(d, DRow))
    return rows


def compile_flat(name: str, g: LogicGraph, binding: dict[str, object],
                 out_targets: dict[str, object], n_bits: int,
                 optimize: bool = True) -> UProgram:
    """Compile a full (non-looped) MIG: used by tree-structured ops
    (reductions, bitcount) and as a building block for class-3 ops."""
    from .synthesis import aoig_to_mig_naive, optimize_mig
    g = optimize_mig(g) if optimize else aoig_to_mig_naive(g)
    sched = Scheduler(g, binding, out_targets, {}, scratch_prefix=f"{name}_sp")
    sched.run()
    ops = coalesce_case2(sched.ops)
    return UProgram(name=name, n_bits=n_bits, prologue=ops, body=[],
                    epilogue=[], body_reps=0)


# ---------------------------------------------------------------------------
# Cross-op trace fusion (ROADMAP item 5): whole pipelines → one μProgram
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChainStage:
    """One op application inside a fused pipeline, in SSA form.

    ``op`` names a registered operation; ``inputs`` are value names —
    chain-external operands or earlier stages' outputs — bound
    *positionally* to the op's declared operand arrays; ``output`` names
    the value this stage produces (single-assignment: no value may be
    defined twice)."""
    op: str
    inputs: tuple[str, ...]
    output: str


def _as_stage(s) -> ChainStage:
    """Coerce ``ChainStage`` | ``(op, inputs, output)`` (inputs may be a
    bare string for unary ops) to a normalized :class:`ChainStage`."""
    if isinstance(s, ChainStage):
        return ChainStage(str(s.op), tuple(str(i) for i in s.inputs),
                          str(s.output))
    op, ins, out = s
    if isinstance(ins, str):
        ins = (ins,)
    return ChainStage(str(op), tuple(str(i) for i in ins), str(out))


def chain_signature(stages, outputs=None) -> str:
    """Canonical cache-key string for a fused chain: the constituent op
    names plus the full value wiring (and the requested outputs, when
    explicit) — everything :func:`compile_chain` consumes besides the
    width/optimize pair that completes the cache key."""
    stages = [_as_stage(s) for s in stages]
    sig = "chain:" + "|".join(
        f"{st.op}({','.join(st.inputs)})->{st.output}" for st in stages)
    if outputs is not None:
        sig += ">>" + ",".join(outputs)
    return sig


def _check_value_name(v: str) -> None:
    if v == "cell" or v.startswith("_fuse"):
        raise ValueError(f"chain value name {v!r} is reserved ('cell' "
                         "collides with B-group row keys; '_fuse*' is the "
                         "fused per-stage scratch namespace)")


def compile_chain(stages, n_bits: int, optimize: bool = True,
                  compile_fn=None, outputs=None,
                  name: str | None = None) -> UProgram:
    """Fuse a pipeline of registered ops into ONE μProgram (the cross-op
    half of SIMDRAM Step 2, which the paper runs per operation only).

    Each stage's μProgram is compiled (``compile_fn(op, n_bits, optimize)``
    — default: the process-wide registry), flattened, and renamed into a
    shared value namespace: the stage's declared operand arrays become the
    stage's input value names, its output array becomes the stage's output
    value, and every other array it touches moves to a private
    ``_fuse{k}_*`` namespace.  Row-allocation reuse falls out of the
    renaming — a producer's output rows and its consumer's input rows are
    now the *same* symbolic rows, so one :func:`~repro.core.trace
    .lower_program` call binds them to the same physical rows and no
    inter-op copy (no LISA hop) remains at the seam.  Two seam
    optimizations then run over the concatenated stream:
    :func:`~repro.core.uprogram.dedupe_const_stores` (a stage
    re-initializing a B-cell to a constant the boundary already left
    there) and :func:`~repro.core.uprogram.eliminate_dead_writes` (rows
    only the per-op contract kept alive, e.g. an unconsumed epilogue
    output).

    ``outputs=None`` keeps the chain's *leaves* (values produced but never
    consumed); pass an explicit tuple to keep intermediates readable too.
    The returned program carries ``chain`` metadata (per-stage μOp spans,
    constituent ops, elision counters) that lowering converts to
    :class:`~repro.core.trace.ChainInfo` seam metadata.
    """
    stages = tuple(_as_stage(s) for s in stages)
    if not stages:
        raise ValueError("compile_chain needs at least one stage")
    if compile_fn is None:
        from .circuits import compile_operation as compile_fn
    # SSA validation + external-input discovery (first-use order)
    produced: list[str] = []
    external: list[str] = []
    for st in stages:
        for v in st.inputs:
            _check_value_name(v)
            if v not in produced and v not in external:
                external.append(v)
        _check_value_name(st.output)
        if st.output in produced or st.output in external:
            raise ValueError(f"chain value {st.output!r} is redefined — "
                             "chain values are single-assignment")
        produced.append(st.output)
    consumed = {v for st in stages for v in st.inputs}
    if outputs is None:
        outs = tuple(v for v in produced if v not in consumed)
    else:
        outs = tuple(outputs)
        unknown = [o for o in outs if o not in produced]
        if unknown:
            raise ValueError(f"requested chain outputs {unknown} are not "
                             "produced by any stage")
    # per-stage compile → flatten → rename into the shared value namespace
    streams: list[list] = []
    unfused_rows = 0        # Σ per-stage row footprints (per-op lowering)
    for k, st in enumerate(stages):
        prog = compile_fn(st.op, n_bits, optimize)
        names = tuple(dict.fromkeys(prog.inputs))
        if len(st.inputs) != len(names):
            raise ValueError(
                f"chain stage {k} ({st.op!r}) takes {len(names)} operands "
                f"{names}, got {len(st.inputs)}")
        if len(prog.outputs) != 1:
            raise ValueError(
                f"chain stage {k} ({st.op!r}) has outputs {prog.outputs} — "
                "fusion chains single-output ops")
        ops = prog.flatten()
        renames = dict(zip(names, st.inputs))
        renames[prog.outputs[0]] = st.output
        for u in ops:
            for r in _drows(u):
                if r.array not in renames:
                    renames[r.array] = f"_fuse{k}_{r.array}"
        unfused_rows += len({(r.array, r.bit) for u in ops
                             for r in _drows(u)})
        streams.append(rename_uops(ops, renames))
    # concatenate + seam optimizations, tracking original indices so the
    # per-stage spans survive into the optimized stream
    starts = [0]
    for ops in streams:
        starts.append(starts[-1] + len(ops))
    flat = [u for ops in streams for u in ops]
    n_raw = len(flat)
    flat, k1 = dedupe_const_stores(flat)
    flat, k2 = eliminate_dead_writes(flat, outs + tuple(external))
    kept = [k1[j] for j in k2]
    spans = tuple(
        (st.op, st.output,
         bisect.bisect_left(kept, starts[k]),
         bisect.bisect_left(kept, starts[k + 1]))
        for k, st in enumerate(stages))
    arrays = {(r.array, r.bit) for u in flat for r in _drows(u)}
    chain_meta = {
        "stages": spans,
        "ops": tuple(dict.fromkeys(st.op for st in stages)),
        "elided_rows": unfused_rows - len(arrays),
        "elided_seqs": n_raw - len(flat),
    }
    scratch = tuple(sorted({a for a, _ in arrays}
                           - set(external) - set(outs)))
    cname = name or "chain(" + "+".join(st.op for st in stages) + ")"
    return UProgram(name=cname, n_bits=n_bits, prologue=flat, body=[],
                    epilogue=[], body_reps=0, inputs=tuple(external),
                    outputs=outs, scratch=scratch, chain=chain_meta)


def fuse_chain(specs, n_bits: int, optimize: bool = True, compile_fn=None,
               outputs=None, name: str | None = None):
    """Compile a pipeline spec straight to one executable
    :class:`~repro.core.trace.LoweredTrace` (``compile_chain`` +
    ``lower_program``); the trace carries
    :class:`~repro.core.trace.ChainInfo` seam metadata.  Cached variants
    live in :meth:`~repro.core.trace.TraceCache.get_chain`."""
    from .trace import lower_program
    return lower_program(compile_chain(specs, n_bits, optimize=optimize,
                                       compile_fn=compile_fn,
                                       outputs=outputs, name=name))
