"""SIMDRAM μOp ISA, subarray row organization, and μProgram container.

Mirrors the paper's §3.1 (subarray organization) and §4.2.1 (μOps):

* Subarray rows are split into the D-group (data rows), C-group (constant
  rows C0/C1) and B-group (compute rows T0–T3 plus the two dual-contact-cell
  rows DCC0/DCC1 with negated wordline ports ¬DCC0/¬DCC1).
* Command-sequence μOps: ``AAP`` (ACTIVATE-ACTIVATE-PRECHARGE = in-DRAM row
  copy, possibly to a multi-row B-group address) and ``AP`` (triple-row
  activation + precharge = destructive 3-input majority).
* Control/arithmetic μOps (addi/subi/comp/module/bnez/done) generalize the
  1-bit loop body to n-bit operands; we keep them at the μProgram level as a
  (prologue, body×n, epilogue) structure, which is exactly what the control
  unit's loop counter + μPC implement in Fig. 7.

Addressing model.  The B-group row decoder supports *multi-row* addresses:
single-row ports, fixed two-row pairs, and triple-row (TRA) addresses.  The
paper exposes these through μRegisters B0–B17.  We implement the same budget:
8 single ports, 4 pair addresses, and a configurable set of TRA triples; the
compiler records which triples each μProgram uses so that decoder cost can be
audited (``UProgram.used_triples``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# ---------------------------------------------------------------------------
# B-group cells & ports
# ---------------------------------------------------------------------------

# physical B-group cells (six compute rows, paper §3.1)
CELL_T0, CELL_T1, CELL_T2, CELL_T3, CELL_DCC0, CELL_DCC1 = range(6)
N_B_CELLS = 6
T_CELLS = (CELL_T0, CELL_T1, CELL_T2, CELL_T3)
DCC_CELLS = (CELL_DCC0, CELL_DCC1)

CELL_NAMES = {CELL_T0: "T0", CELL_T1: "T1", CELL_T2: "T2", CELL_T3: "T3",
              CELL_DCC0: "DCC0", CELL_DCC1: "DCC1"}


@dataclasses.dataclass(frozen=True, order=True)
class Port:
    """A wordline view of a B-group cell.  ``neg`` selects the n-wordline of
    a dual-contact cell (read: complement; write: stores complement)."""
    cell: int
    neg: bool = False

    def __post_init__(self) -> None:
        if self.neg and self.cell not in DCC_CELLS:
            raise ValueError("only DCC cells have negated ports")

    def __str__(self) -> str:
        return ("~" if self.neg else "") + CELL_NAMES[self.cell]


# the 8 single-row ports (μRegisters B0–B7 in Fig. 6)
P_T0, P_T1, P_T2, P_T3 = (Port(c) for c in T_CELLS)
P_DCC0, P_DCC1 = Port(CELL_DCC0), Port(CELL_DCC1)
P_NDCC0, P_NDCC1 = Port(CELL_DCC0, True), Port(CELL_DCC1, True)
SINGLE_PORTS = (P_T0, P_T1, P_T2, P_T3, P_DCC0, P_NDCC0, P_DCC1, P_NDCC1)

# fixed pair addresses (multi-row copy destinations, cf. paper's B10 example
# "activating μRegister B10 allows the AAP to copy array A into both rows T2
# and T3 at once")
PAIR_ADDRESSES: tuple[tuple[Port, ...], ...] = (
    (P_T0, P_T3),
    (P_T1, P_T2),
    (P_T2, P_T3),
    (P_DCC0, P_DCC1),
)


# ---------------------------------------------------------------------------
# Row references
# ---------------------------------------------------------------------------

# D-group/C-group rows are referenced symbolically: (array name, bit offset).
# The control unit's μRegister Addressing Unit resolves ``base + bit`` at
# runtime (paper §4.3); C0/C1 are the constant rows.

@dataclasses.dataclass(frozen=True)
class DRow:
    """A D-group row: bit ``bit`` of the operand array named ``array``.

    ``array`` indexes μRegisters B18–B22 (source/dest base addresses);
    scratch arrays (for multi-step ops) use additional D-group allocations.
    ``fixed`` rows do not shift with the loop induction variable (used for
    loop-invariant operands such as predication masks or sign rows).
    """
    array: str
    bit: int = 0
    fixed: bool = False

    def __str__(self) -> str:
        return f"{self.array}[{self.bit}{'!' if self.fixed else ''}]"


@dataclasses.dataclass(frozen=True)
class CRow:
    """A C-group constant row (C0 = all zeros, C1 = all ones)."""
    one: bool

    def __str__(self) -> str:
        return "C1" if self.one else "C0"


C0 = CRow(False)
C1 = CRow(True)

RowRef = object  # Port | DRow | CRow


# ---------------------------------------------------------------------------
# μOps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AAP:
    """ACTIVATE(src) → ACTIVATE(dst…) → PRECHARGE: copy src row into one or
    more destination rows.  If ``src`` is a tuple of 3 ports, the first
    ACTIVATE is itself a TRA: this is the paper's *Case 2 coalescing* (an AP
    immediately followed by an AAP from the TRA address fuses into one AAP
    whose source activation performs the majority)."""
    src: object                      # RowRef or tuple[Port, Port, Port]
    dsts: tuple                      # tuple of RowRef (ports or D rows)

    def __str__(self) -> str:
        s = ("MAJ(" + ",".join(map(str, self.src)) + ")"
             if isinstance(self.src, tuple) else str(self.src))
        return f"AAP {','.join(map(str, self.dsts))} <- {s}"


@dataclasses.dataclass(frozen=True)
class AP:
    """Triple-row activation + precharge: in-place 3-input majority.  All
    three cells end up holding the majority (through their port polarity)."""
    ports: tuple                      # tuple[Port, Port, Port]

    def __str__(self) -> str:
        return f"AP  MAJ({','.join(map(str, self.ports))})"


UOp = object  # AAP | AP


def is_command_sequence(u: UOp) -> bool:
    return isinstance(u, (AAP, AP))


# ---------------------------------------------------------------------------
# μProgram
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UProgram:
    """A compiled SIMDRAM operation.

    ``body`` μOps reference operand bits relative to the loop induction
    variable: a ``DRow(array, k)`` inside the body denotes bit ``i + k`` of
    ``array`` at loop iteration ``i``.  This is what the control unit's
    addi/bnez μOps implement; we keep the structured form (the +1 "done"
    accounting per paper Table 5 is ``n_loop_overhead``).
    """
    name: str
    n_bits: int
    prologue: list = dataclasses.field(default_factory=list)
    body: list = dataclasses.field(default_factory=list)      # repeated n times
    epilogue: list = dataclasses.field(default_factory=list)
    body_reps: int | None = None      # defaults to n_bits
    inputs: tuple[str, ...] = ()
    outputs: tuple[str, ...] = ()
    scratch: tuple[str, ...] = ()     # D-group scratch arrays (name, n_bits implied)
    # cross-op fusion metadata (None for ordinary programs; set by
    # compile_chain): {"stages": ((op, value, start, end), ...),
    # "elided_rows": int, "elided_seqs": int} — start/end index the
    # flattened μOp stream, so lowering can recover per-stage seam spans
    chain: dict | None = None

    # -- accounting ---------------------------------------------------------
    @property
    def reps(self) -> int:
        return self.n_bits if self.body_reps is None else self.body_reps

    def flatten(self) -> list:
        """Concrete μOp stream for one row-chunk of elements."""
        out = list(self.prologue)
        for i in range(self.reps):
            for u in self.body:
                out.append(_shift_uop(u, i))
        out.extend(self.epilogue)
        return out

    def command_count(self) -> int:
        """Total AAP+AP command sequences (the paper's Table 5 metric)."""
        return (sum(is_command_sequence(u) for u in self.prologue)
                + self.reps * sum(is_command_sequence(u) for u in self.body)
                + sum(is_command_sequence(u) for u in self.epilogue))

    def command_mix(self) -> dict:
        """(n_AAP, n_AP, n_TRA) — every AP is a TRA; an AAP whose source is a
        triple also performs a TRA on its first ACTIVATE."""
        n_aap = n_ap = n_tra = 0
        for u in self.flatten():
            if isinstance(u, AAP):
                n_aap += 1
                if isinstance(u.src, tuple):
                    n_tra += 1
            elif isinstance(u, AP):
                n_ap += 1
                n_tra += 1
        return {"AAP": n_aap, "AP": n_ap, "TRA": n_tra}

    def used_triples(self) -> set:
        """Distinct TRA addresses used — decoder-cost audit (§3.1)."""
        triples = set()
        for u in self.flatten():
            if isinstance(u, AP):
                triples.add(tuple(sorted(u.ports)))
            elif isinstance(u, AAP) and isinstance(u.src, tuple):
                triples.add(tuple(sorted(u.src)))
        return triples

    def pretty(self, max_ops: int = 40) -> str:
        lines = [f"; μProgram {self.name} (n={self.n_bits}, "
                 f"{self.command_count()} command sequences)"]
        for tag, ops in (("prologue", self.prologue), ("body", self.body),
                         ("epilogue", self.epilogue)):
            if ops:
                lines.append(f";; {tag}" + (f" ×{self.reps}" if tag == "body" else ""))
                lines.extend(f"  {u}" for u in ops[:max_ops])
                if len(ops) > max_ops:
                    lines.append(f"  ... ({len(ops) - max_ops} more)")
        return "\n".join(lines)


def normalize_uop(u: UOp):
    """Canonical form of a *flattened* μOp: D-row references drop their
    ``fixed`` loop-invariance mark.  ``fixed`` steers :func:`_shift_uop`
    during flattening but names the same physical row afterwards, so the
    lowered command-trace IR (``repro.core.trace``) cannot — and need not —
    preserve it; round-trip comparisons go through this form."""
    def n(r):
        if isinstance(r, DRow) and r.fixed:
            return DRow(r.array, r.bit)
        return r

    if isinstance(u, AAP):
        src = u.src if isinstance(u.src, tuple) else n(u.src)
        return AAP(src, tuple(n(d) for d in u.dsts))
    return u


def _shift_uop(u: UOp, i: int):
    """Rebase DRow bit offsets by the loop induction variable ``i``."""
    def sh(r):
        if isinstance(r, DRow) and not r.fixed:
            return DRow(r.array, r.bit + i)
        return r

    if isinstance(u, AAP):
        src = u.src if isinstance(u.src, tuple) else sh(u.src)
        return AAP(src, tuple(sh(d) for d in u.dsts))
    return u


def rename_uops(uops: Sequence, renames: dict) -> list:
    """Rename D-row array names throughout a *flattened* μOp stream.

    The cross-op fusion pass uses this to stitch μPrograms together: one
    program's output array is renamed to the value name the next program
    reads, so both resolve to the *same* physical rows after lowering — the
    row-allocation reuse that eliminates the inter-op LISA hop.  Ports and
    C-group rows are untouched; ``bit``/``fixed`` are preserved.
    """
    if not renames:
        return list(uops)

    def fix(r):
        if isinstance(r, DRow) and r.array in renames:
            return DRow(renames[r.array], r.bit, r.fixed)
        return r

    out = []
    for u in uops:
        if isinstance(u, AAP):
            src = u.src if isinstance(u.src, tuple) else fix(u.src)
            out.append(AAP(src, tuple(fix(d) for d in u.dsts)))
        else:
            out.append(u)
    return out


def _cells_written(u) -> set:
    """B-group cells a μOp overwrites (TRA results + AAP port destinations)."""
    cells = set()
    if isinstance(u, AP):
        cells.update(p.cell for p in u.ports)
    elif isinstance(u, AAP):
        if isinstance(u.src, tuple):
            cells.update(p.cell for p in u.src)
        cells.update(d.cell for d in u.dsts if isinstance(d, Port))
    return cells


def dedupe_const_stores(uops: Sequence) -> tuple[list, list]:
    """Drop AAP constant loads that restate a B-cell's current constant.

    A forward pass tracking, per compute cell, the constant it is known to
    hold (written from C0/C1 through some port polarity and not overwritten
    since).  A later ``AAP C-row → port`` storing the same constant is a
    redundant init — across a fusion seam this is the next op's state-init
    prologue restating what the previous op left behind.  Only provably
    redundant destinations are dropped; any other write invalidates the
    cell's known constant.  Returns ``(kept_uops, kept_indices)`` so chain
    compilation can keep per-stage spans aligned.
    """
    known: dict[int, bool] = {}       # cell → constant currently stored
    out: list = []
    kept: list[int] = []
    for i, u in enumerate(uops):
        if (isinstance(u, AAP) and isinstance(u.src, CRow)
                and u.dsts and all(isinstance(d, Port) for d in u.dsts)):
            val = u.src.one
            fresh = tuple(d for d in u.dsts
                          if known.get(d.cell) != (val != d.neg))
            if not fresh:
                continue              # every destination already holds it
            for d in fresh:
                known[d.cell] = (val != d.neg)
            out.append(u if fresh == u.dsts else AAP(u.src, fresh))
            kept.append(i)
            continue
        for c in _cells_written(u):
            known.pop(c, None)
        out.append(u)
        kept.append(i)
    return out, kept


def eliminate_dead_writes(uops: Sequence, live_arrays) -> tuple[list, list]:
    """Backward dead-store elimination over a flattened μOp stream.

    ``live_arrays`` names the D-group arrays whose rows must survive to the
    end (the outputs).  Walking backwards, an AAP destination row that is
    never read downstream and is not an output is pruned; an AAP left with
    no destinations is dropped entirely (a single-row ACTIVATE read is
    non-destructive) — unless its source is a TRA triple, in which case the
    majority side effect on the cells is preserved as a plain AP.  Port
    destinations are always kept (cell liveness is not tracked backwards,
    so every cell write is conservatively live).  Returns
    ``(kept_uops, kept_indices)``.
    """
    full_live = set(live_arrays)
    live: set[tuple[str, int]] = set()

    def row_live(r: DRow) -> bool:
        return r.array in full_live or (r.array, r.bit) in live

    out: list = []
    kept: list[int] = []
    for i in range(len(uops) - 1, -1, -1):
        u = uops[i]
        if not isinstance(u, AAP):
            out.append(u)
            kept.append(i)
            continue
        dsts = tuple(d for d in u.dsts
                     if not isinstance(d, DRow) or row_live(d))
        if not dsts:
            if isinstance(u.src, tuple):
                out.append(AP(u.src))
                kept.append(i)
            continue
        for d in dsts:
            if isinstance(d, DRow) and d.array not in full_live:
                live.discard((d.array, d.bit))
        if isinstance(u.src, DRow):
            live.add((u.src.array, u.src.bit))
        out.append(u if dsts == u.dsts else AAP(u.src, dsts))
        kept.append(i)
    out.reverse()
    kept.reverse()
    return out, kept


def concat_programs(name: str, progs: Sequence[UProgram], n_bits: int,
                    inputs=(), outputs=(), scratch=(),
                    renames: Sequence[dict] | None = None,
                    optimize_seams: bool = False) -> UProgram:
    """Compose μPrograms sequentially (used for class-3 ops like mul/div that
    chain adder/mux μPrograms with shifted row bases).

    ``renames`` optionally supplies one array-rename map per program
    (:func:`rename_uops`) so consecutive programs can share rows — the
    cross-op fusion building block.  ``optimize_seams=True`` additionally
    runs :func:`dedupe_const_stores` and :func:`eliminate_dead_writes`
    (live set = ``outputs``) over the concatenated stream, removing the
    redundant init copies and dead handoff rows a seam leaves behind.
    """
    flat: list = []
    for k, p in enumerate(progs):
        ops = p.flatten()
        if renames is not None and renames[k]:
            ops = rename_uops(ops, renames[k])
        flat.extend(ops)
    if optimize_seams:
        flat, _ = dedupe_const_stores(flat)
        flat, _ = eliminate_dead_writes(flat, outputs)
    return UProgram(name=name, n_bits=n_bits, prologue=flat, body=[],
                    epilogue=[], body_reps=0, inputs=tuple(inputs),
                    outputs=tuple(outputs), scratch=tuple(scratch))
