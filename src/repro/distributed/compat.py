"""Version compatibility shims for the distributed layer.

The repo targets both the modern ``jax.shard_map`` API (axis_names /
check_vma) and the older ``jax.experimental.shard_map.shard_map`` API
(auto / check_rep) that ships with jax 0.4.x.  ``shard_map_compat`` exposes
the modern surface and lowers to whichever implementation is present.
"""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, axis_names, in_specs, out_specs,
                     check_vma: bool = False):
    """``jax.shard_map`` with partial-manual axes, on any supported jax.

    ``axis_names`` is the set of mesh axes the function is *manual* over;
    remaining mesh axes stay under the automatic partitioner where the jax
    version supports it.  jax 0.4.x partial-manual lowering is broken for
    nontrivial bodies (XLA fatally aborts with ``Check failed:
    sharding.IsManualSubgroup()`` on collectives and even plain model
    forwards when an auto axis has size > 1), so on the legacy API we fall
    back to fully-manual: the non-manual axes see replicated operands
    (in_specs PS() ⇒ full arrays per device) and the body runs redundantly
    across them.  Semantics are identical; tensor-parallel sharding inside
    the mapped body is sacrificed on legacy jax only.
    """
    axis_names = frozenset(axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=axis_names,
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma, auto=frozenset())


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a per-device *list* of dicts on
    jax 0.4.x and a plain dict on newer releases — normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
