"""Fault-tolerant training driver: checkpoint/restart, step retry,
straggler detection, and elastic re-meshing on (simulated) node loss.

On a real cluster the failure signals come from the coordination service
(jax.distributed heartbeats); here the driver exposes the same control flow
with injectable failure hooks so the logic is testable on one host:

  * every step runs under retry: a transient exception re-runs the step from
    the last committed state (steps are pure functions of (state, batch), so
    retry is exact);
  * a persistent failure triggers restore-from-checkpoint, optionally onto a
    *smaller* mesh (elastic downscale) — re-sharding is handled by the
    checkpoint manager;
  * per-step wall times feed a straggler monitor: any step slower than
    ``straggler_factor`` × the running median is logged and counted; on a
    real deployment this triggers hot-spare swap-in (hook provided).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from .checkpoint import CheckpointManager


@dataclasses.dataclass
class FailoverConfig:
    checkpoint_every: int = 50
    max_retries: int = 2
    straggler_factor: float = 3.0
    keep_times: int = 64


class StragglerMonitor:
    def __init__(self, factor: float, keep: int = 64) -> None:
        self.factor = factor
        self.times: list[float] = []
        self.keep = keep
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                is_straggler = True
        self.times.append(dt)
        self.times = self.times[-self.keep:]
        return is_straggler


class FailoverRunner:
    """Drives (state, batch) → state steps with checkpoint/restart."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 cfg: FailoverConfig | None = None,
                 on_straggler: Callable[[int], None] | None = None,
                 failure_injector: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg or FailoverConfig()
        self.monitor = StragglerMonitor(self.cfg.straggler_factor,
                                        self.cfg.keep_times)
        self.on_straggler = on_straggler or (lambda step: None)
        self.failure_injector = failure_injector
        self.events: list[str] = []

    def run(self, state, batch_fn: Callable[[int], Any], start_step: int,
            num_steps: int, mesh=None, shardings=None):
        """Run ``num_steps`` steps with retry + periodic checkpointing.
        Returns (state, metrics_history)."""
        history = []
        step = start_step
        while step < start_step + num_steps:
            batch = batch_fn(step)
            t0 = time.monotonic()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(step)
                    new_state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(
                        jax.tree.leaves(metrics)[0]
                        if jax.tree.leaves(metrics) else new_state.opt.step)
                    break
                except Exception as e:   # noqa: BLE001 — retry then restore
                    self.events.append(f"step {step} attempt {attempt} "
                                       f"failed: {type(e).__name__}")
                    if attempt >= self.cfg.max_retries:
                        state = self._restore(state, mesh, shardings)
                        step = int(np.asarray(state.opt.step))
                        self.events.append(f"restored at step {step}")
                        new_state, metrics = None, None
                        break
            if new_state is None:
                continue
            state = new_state
            dt = time.monotonic() - t0
            if self.monitor.record(step, dt):
                self.events.append(f"straggler at step {step}: {dt:.3f}s")
                self.on_straggler(step)
            history.append({k: float(np.asarray(v))
                            for k, v in metrics.items()})
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, state, mesh)
        self.ckpt.save(step, state, mesh, blocking=True)
        return state, history

    def _restore(self, like_state, mesh, shardings):
        latest = self.ckpt.latest_step()
        if latest is None:
            raise RuntimeError("no checkpoint to restore from")
        return self.ckpt.restore(latest, like_state, shardings)
