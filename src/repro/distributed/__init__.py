"""Distributed runtime: sharding, checkpointing, gradient compression,
pipeline parallelism, elastic scaling and failover."""
