"""GPipe-style pipeline parallelism over an explicit mesh axis.

``pipeline_apply`` runs a homogeneous stage function over ``P`` pipeline
stages held on a ``pipe`` mesh axis, streaming ``M`` microbatches with the
classic (M + P − 1)-tick schedule; activations move between stages with
``ppermute`` (point-to-point, the TPU-native inter-stage transfer).  The
whole schedule is differentiable, so ``jax.grad`` through it yields correct
pipeline-parallel training (GPipe semantics: no weight staleness).

Stage parameters are stacked on a leading axis of size P and sharded
``P(axis)`` so each device holds exactly its stage's weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn, stage_params, x, *, mesh: Mesh,
                   axis: str = "pipe", microbatches: int | None = None):
    """Run ``stage_fn(params_p, x) -> y`` over P pipeline stages.

    stage_params: pytree with leading stacked axis P (sharded over ``axis``).
    x: (M, mb, ...) microbatched input (replicated; stage 0 consumes it).
    Returns (M, mb, ...) outputs from the last stage.
    """
    p = mesh.shape[axis]
    m = x.shape[0] if microbatches is None else microbatches
    t_total = m + p - 1

    def per_stage(params_stacked, xs):
        # inside shard_map: params_stacked has leading dim 1 (this stage)
        params = jax.tree.map(lambda a: a[0], params_stacked)
        stage_id = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)      # current activation
        outputs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t; others take the permuted input
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, m - 1), 0, keepdims=False)
            x_in = jnp.where(stage_id == 0, inject, state)
            active = (t - stage_id >= 0) & (t - stage_id < m)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, state)
            # last stage writes its result for microbatch (t - P + 1)
            out_idx = jnp.clip(t - p + 1, 0, m - 1)
            write = (stage_id == p - 1) & (t - (p - 1) >= 0)
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(write, y, cur), out_idx, 0)
            # shift activations one stage down the ring
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % p) for i in range(p)])
            return (nxt, outputs), None

        (_, outputs), _ = jax.lax.scan(tick, (state, outputs),
                                       jnp.arange(t_total))
        # every stage returns its buffer; only the last stage's slice is
        # meaningful.  Returning per-stage (out_specs PS(axis)) rather than
        # broadcasting keeps the backward pass exact: a replicated output
        # would scale parameter cotangents by 1/P.
        return outputs

    in_specs = (jax.tree.map(lambda _: PS(axis), stage_params), PS())
    out_specs = PS(axis)
    fn = shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    stacked = fn(stage_params, x)          # (P·M, mb, ...)
    return stacked[(p - 1) * m:]


def stack_stage_params(per_stage_params: list):
    """List of P per-stage pytrees → stacked pytree with leading P axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage_params)
