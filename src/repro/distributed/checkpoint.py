"""Sharded, asynchronous, *elastic* checkpointing.

Design (single-host implementation of the multi-host protocol):

* each pytree leaf is saved as one or more ``.npy`` chunk files along its
  first sharded axis, with a JSON manifest recording the tree structure,
  global shapes, chunk grid, step, and the mesh it was saved under;
* saves are asynchronous (background thread over host copies) and atomic
  (write to ``<dir>.tmp`` then rename), so a crash mid-save never corrupts
  the latest checkpoint;
* restore is **elastic**: the target mesh may have a different shape/axis
  layout than the save mesh — chunks are stitched to full arrays and
  re-placed under the new mesh's shardings (checkpoints saved on N pods
  restore onto M);
* ``latest_step`` + ``restore`` give crash-recovery semantics for the
  failover driver.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

_SEP = "__"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 chunk_bytes: int = 1 << 28) -> None:
        self.dir = directory
        self.max_to_keep = max_to_keep
        self.chunk_bytes = chunk_bytes
        self._pool = ThreadPoolExecutor(max_workers=4)
        self._pending: list = []
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state, mesh=None, blocking: bool = False):
        """Snapshot to host memory synchronously, write asynchronously."""
        leaves = _flatten_with_paths(state)
        host = {k: np.asarray(v) for k, v in leaves.items()
                if v is not None}
        fut = self._pool.submit(self._write, step, host,
                                list(mesh.axis_names) if mesh else [])
        self._pending.append(fut)
        if blocking:
            fut.result()
        return fut

    def _write(self, step: int, host: dict, mesh_axes: list) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        # unique tmp dir per writer: concurrent saves of the same step (e.g.
        # a periodic save racing the final blocking save) must not clobber
        # each other's in-progress files
        tmp = final + f".tmp{threading.get_ident()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "mesh_axes": mesh_axes, "leaves": {}}
        for key, arr in host.items():
            chunks = self._chunk(arr)
            manifest["leaves"][key] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "n_chunks": len(chunks),
            }
            for i, c in enumerate(chunks):
                np.save(os.path.join(tmp, f"{key}.{i}.npy"), c)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _chunk(self, arr: np.ndarray) -> list[np.ndarray]:
        if arr.ndim == 0 or arr.nbytes <= self.chunk_bytes:
            return [arr]
        n = max(1, min(arr.shape[0], arr.nbytes // self.chunk_bytes))
        return np.array_split(arr, n, axis=0)

    def wait(self) -> None:
        for f in self._pending:
            f.result()
        self._pending.clear()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching tree of NamedShardings
        for the *target* mesh (elastic re-placement); None → host arrays."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        loaded: dict[str, np.ndarray] = {}
        for key, meta in manifest["leaves"].items():
            parts = [np.load(os.path.join(path, f"{key}.{i}.npy"))
                     for i in range(meta["n_chunks"])]
            arr = parts[0] if len(parts) == 1 else np.concatenate(parts, 0)
            loaded[key] = arr.reshape(meta["shape"])

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        out = []
        for (pth, leaf), shard in zip(flat, shard_flat):
            key = _SEP.join(_path_str(p) for p in pth)
            if key not in loaded:
                out.append(leaf)   # e.g. optional fields absent at save time
                continue
            arr = loaded[key]
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
