"""Logical-axis → mesh-axis sharding resolution.

Model code names *logical* axes (see ``repro.models.params``); this module
maps them to physical mesh axes with a divisibility-safe fallback: an axis
whose dimension does not divide by the mesh axis size replicates instead
(e.g. granite's single KV head, qwen2-vl's 12 heads on a 16-way model axis).
Data-parallel batch axes span ``('pod', 'data')`` when the pod axis exists.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# tensor-parallel rules: logical axis → mesh axis
LOGICAL_RULES: dict[str | None, str | None] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",       # expert parallelism over the model axis
    "inner": "model",         # SSM inner channels
    "embed": None,
    "state": None,
    "lora": None,
    "layers": None,
    None: None,
}


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= mesh.shape[a]
        return n
    return mesh.shape[name]


def resolve_pspec(axes: tuple, shape: tuple[int, ...], mesh: Mesh) -> PS:
    """Logical axes tuple → PartitionSpec with divisibility fallback."""
    spec = []
    for dim, logical in zip(shape, axes):
        mesh_axis = LOGICAL_RULES.get(logical, None)
        if mesh_axis is not None and dim % _axis_size(mesh, mesh_axis) == 0 \
                and _axis_size(mesh, mesh_axis) > 1:
            spec.append(mesh_axis)
        else:
            spec.append(None)
    while spec and spec[-1] is None:
        spec.pop()
    return PS(*spec)


def tree_pspecs(defs, mesh: Mesh):
    """ParamDef tree → PartitionSpec tree."""
    from ..models.params import ParamDef
    return jax.tree.map(
        lambda d: resolve_pspec(d.axes, d.shape, mesh),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def tree_shardings(defs, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        tree_pspecs(defs, mesh))


def data_pspec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> PS:
    """Batch sharding over (pod, data) with divisibility fallback."""
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if axes and global_batch % n == 0:
        return PS(axes if len(axes) > 1 else axes[0],
                  *([None] * extra_dims))
    return PS(*([None] * (extra_dims + 1)))


def batch_shardings(mesh: Mesh, batch_tree):
    """ShapeDtypeStruct tree for a data batch → NamedSharding tree."""
    def one(sds):
        return NamedSharding(mesh,
                             data_pspec(mesh, sds.shape[0], len(sds.shape) - 1))
    return jax.tree.map(one, batch_tree)


def cache_pspec(sds, mesh: Mesh) -> PS:
    """Decode caches: (layers, batch, seq, heads..) — shard batch over data
    axes and the head/feature axis over 'model' when divisible."""
    shape = sds.shape
    if len(shape) == 0:
        return PS()
    if len(shape) == 1:                       # per-layer scalars
        return PS(None)
    axes: list = [None] * len(shape)
    baxes = batch_axes(mesh)
    n = 1
    for a in baxes:
        n *= mesh.shape[a]
    if len(shape) >= 2 and shape[1] % max(n, 1) == 0 and baxes:
        axes[1] = baxes if len(baxes) > 1 else baxes[0]
    # shard KV heads / state heads over model when divisible
    if len(shape) >= 4:
        m = mesh.shape.get("model", 1)
        if m > 1 and shape[3] % m == 0:
            axes[3] = "model"
    return PS(*axes)


def cache_shardings(mesh: Mesh, cache_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, cache_pspec(s, mesh)),
                        cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PS())
