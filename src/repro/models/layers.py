"""Core layers: RMSNorm, RoPE/M-RoPE, GQA & MLA attention, dense & MoE MLPs,
Mamba2/SSD mixer — everything the 10 assigned architectures compose from.

Conventions
-----------
* every layer provides ``<layer>_defs(cfg) → ParamDef tree`` and
  ``<layer>(params, x, ...) → y`` (pure functions, no classes);
* compute runs in ``cfg.compute_dtype`` (bf16), params stored f32;
* decode paths take/return explicit caches (KV, MLA latent, SSM state);
* attention uses a causal mask; decode attends to the full cache prefix.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .params import ParamDef

P = ParamDef


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(d: int):
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * params["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl: 3-section rotary over (t, h, w) positions;
# with text-only inputs all three sections see the same position index, which
# reduces to standard RoPE — the vision frontend stub supplies t/h/w ids)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 1e4, mrope_sections=None):
    """x: (..., S, H, hd); positions: (..., S) or (..., S, 3) for M-RoPE."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    if positions.ndim == x.ndim - 2:                   # plain RoPE
        ang = positions[..., :, None].astype(jnp.float32) * freqs
    else:                                              # M-RoPE (S, 3)
        sections = mrope_sections or (hd // 6, hd // 6, hd // 2 - 2 * (hd // 6))
        parts = []
        for s, sec in enumerate(sections):
            pos = positions[..., s]
            parts.append(pos[..., :, None].astype(jnp.float32)
                         * freqs[sum(sections[:s]):sum(sections[:s]) + sec])
        ang = jnp.concatenate(parts, -1)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos.astype(x.dtype) - x2 * sin.astype(x.dtype)
    y2 = x2 * cos.astype(x.dtype) + x1 * sin.astype(x.dtype)
    return jnp.stack([y1, y2], -1).reshape(x.shape)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig):
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    defs = {
        "wq": P((d, nh, hd), ("embed", "heads", None)),
        "wk": P((d, nkv, hd), ("embed", "kv_heads", None)),
        "wv": P((d, nkv, hd), ("embed", "kv_heads", None)),
        "wo": P((nh, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = P((nh, hd), ("heads", None), init="zeros")
        defs["bk"] = P((nkv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = P((nkv, hd), ("kv_heads", None), init="zeros")
    return defs


# block sizes for the online-softmax (flash) attention path
FLASH_BLOCK_Q = 1024
FLASH_BLOCK_K = 1024
FLASH_THRESHOLD = 4096       # naive path below this many score elements²


def _sdpa_naive(q, k, v, causal: bool, q_offset=0):
    """q: (B,S,H,hd); k/v: (B,T,KV,hd) — grouped by broadcasting."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    q = q.reshape(b, s, kv, group, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if causal:
        qpos = jnp.arange(s)[:, None] + q_offset
        kpos = jnp.arange(t)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, -1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, hd)


def _flash_attn(q, k, v, causal: bool, q_offset=0, kv_len=None,
                block_q: int = FLASH_BLOCK_Q, block_k: int = FLASH_BLOCK_K):
    """Online-softmax attention: O(S·hd) memory instead of O(S·T).

    q: (B,S,H,hd); k/v: (B,T,KV,hd).  ``kv_len``: optional scalar — only
    cache positions < kv_len + current block are attendable (decode).
    The double loop is (scan over q blocks) × (scan over kv blocks), which
    XLA pipelines; this is the memory-term optimization that makes the 32k
    prefill and 500k decode cells compile within HBM.
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(block_q, s)
    bk = min(block_k, t)
    nq, nk = -(-s // bq), -(-t // bk)
    pad_q, pad_k = nq * bq - s, nk * bk - t
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    hd_v = v.shape[-1]
    qb = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, bk, kv, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, bk, kv, hd_v).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / math.sqrt(hd)

    def q_block(qi, q_blk):
        # q_blk: (B, KV, G, bq, hd)
        qpos = qi * bq + jnp.arange(bq) + q_offset

        def kv_block(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            kpos = ki * bk + jnp.arange(bk)
            sc = jnp.einsum("bkgqd,bktd->bkgqt", q_blk, k_blk) * scale
            sc = sc.astype(jnp.float32)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if kv_len is not None:
                mask &= kpos[None, :] <= (kv_len + qpos[:, None])
            mask &= (kpos < t)[None, :]
            sc = jnp.where(mask[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bkgqt,bktd->bkgqd",
                                    p.astype(v_blk.dtype), v_blk))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, bq, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb))
    # out: (nq, B, KV, G, bq, hd_v) → (B, S, H, hd_v)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * bq, h, hd_v)
    return out[:, :s].astype(v.dtype)


def _sdpa(q, k, v, causal: bool, q_offset=0):
    s, t = q.shape[1], k.shape[1]
    if s * t <= FLASH_THRESHOLD * FLASH_THRESHOLD:
        return _sdpa_naive(q, k, v, causal, q_offset)
    return _flash_attn(q, k, v, causal, q_offset)


def attention(params, cfg: ModelConfig, x, positions, cache=None,
              mrope_positions=None):
    """Returns (y, new_cache).  cache = dict(k, v, pos) for decode."""
    dt = _dt(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    pos = mrope_positions if (cfg.rope == "mrope" and mrope_positions
                              is not None) else positions
    if cfg.rope != "none":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if cache is None:
        y = _sdpa(q, k, v, causal=True)
        new_cache = None
    elif "k_scale" in cache:
        # int8-quantized KV cache: store int8 + per-(token, head) scale;
        # the HBM stream for the dominant decode read halves vs bf16
        def quant(x):
            s = jnp.max(jnp.abs(x), -1, keepdims=True) / 127.0 + 1e-8
            return jnp.round(x / s).astype(jnp.int8), s[..., 0].astype(
                jnp.float32)
        kq, ks = quant(k)
        vq, vs = quant(v)
        upd = lambda c, u: jax.lax.dynamic_update_slice_in_dim(
            c, u.astype(c.dtype), cache["pos"], 1)
        ck, cks = upd(cache["k"], kq), upd(cache["k_scale"], ks)
        cv, cvs = upd(cache["v"], vq), upd(cache["v_scale"], vs)
        kd = ck.astype(dt) * cks.astype(dt)[..., None]
        vd = cv.astype(dt) * cvs.astype(dt)[..., None]
        y = _masked_decode_attn(q, kd, vd, cache["pos"])
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                     "pos": cache["pos"] + q.shape[1]}
    else:
        # decode: scatter this step's k/v at cache['pos']
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache["pos"], 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache["pos"], 1)
        y = _masked_decode_attn(q, ck.astype(dt), cv.astype(dt), cache["pos"])
        new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + q.shape[1]}
    y = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt))
    return y, new_cache


def _masked_decode_attn(q, k, v, q_pos):
    """Cached attention: query i (global position q_pos+i) attends to cache
    positions ≤ its own (supports both 1-token decode and multi-token
    cache-populating prefill).  Long caches take the online-softmax path."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    if s * t > FLASH_THRESHOLD * FLASH_THRESHOLD or t > 16384:
        return _flash_attn(q, k, v, causal=True, q_offset=q_pos)
    q = q.reshape(b, s, kv, h // kv, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) / math.sqrt(hd)
    qpos = jnp.arange(s)[:, None] + q_pos
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos                                   # (s, t)
    scores = jnp.where(mask[None, None, None, :, :],
                       scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, -1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_defs(cfg: ModelConfig):
    d, hd, nh = cfg.d_model, cfg.hd, cfg.n_heads
    r, rq, rh = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
    defs = {
        "w_dkv": P((d, r + rh), ("embed", "lora")),        # joint kv + rope-k
        "w_uk": P((r, nh, hd), ("lora", "heads", None)),
        "w_uv": P((r, nh, hd), ("lora", "heads", None)),
        "wo": P((nh, hd, d), ("heads", None, "embed")),
    }
    if rq:
        defs["w_dq"] = P((d, rq), ("embed", "lora"))
        defs["w_uq"] = P((rq, nh, hd + rh), ("lora", "heads", None))
    else:
        defs["w_q"] = P((d, nh, hd + rh), ("embed", "heads", None))
    return defs


def mla_attention(params, cfg: ModelConfig, x, positions, cache=None):
    """Latent attention; decode caches the compressed kv latent only."""
    dt = _dt(cfg)
    r, rh, nh, hd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.n_heads, cfg.hd
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    c, k_rope = ckv[..., :r], ckv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    if cfg.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt))
        q = jnp.einsum("bsr,rhk->bshk", q, params["w_uq"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"].astype(dt))
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(hd + rh)
    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c, params["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", c, params["w_uv"].astype(dt))
        s = x.shape[1]
        if s > FLASH_THRESHOLD:
            # concatenate the nope/rope score components into one head dim
            # and take the online-softmax path (32k prefill)
            q_cat = jnp.concatenate([q_nope, q_rope], -1)
            k_cat = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rh,))],
                -1)
            y = _flash_attn(q_cat, k_cat, v, causal=True)
        else:
            scores = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
                      + jnp.einsum("bshk,btzk->bhst", q_rope, k_rope)) * scale
            mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
            scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
            w = jax.nn.softmax(scores, -1).astype(dt)
            y = jnp.einsum("bhst,bthk->bshk", w, v)
        new_cache = None
    else:
        # absorbed decode: score against the latent cache directly
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c"], c.astype(cache["c"].dtype), cache["pos"], 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
            cache["pos"], 1)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
        scores = (jnp.einsum("bshr,btr->bhst", q_abs, cc.astype(dt))
                  + jnp.einsum("bshk,btk->bhst", q_rope, cr.astype(dt))) * scale
        s = x.shape[1]
        qpos = jnp.arange(s)[:, None] + cache["pos"]
        mask = jnp.arange(cc.shape[1])[None, :] <= qpos       # (s, t)
        scores = jnp.where(mask[None, None, :, :],
                           scores.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(scores, -1).astype(dt)
        y_lat = jnp.einsum("bhst,btr->bshr", w, cc.astype(dt))
        y = jnp.einsum("bshr,rhk->bshk", y_lat, params["w_uv"].astype(dt))
        new_cache = {"c": cc, "k_rope": cr, "pos": cache["pos"] + x.shape[1]}
    y = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs (SwiGLU) — dense, MoE, and the PuM (bit-serial) variant
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {"w_gate": P((d, f), ("embed", "mlp")),
            "w_up": P((d, f), ("embed", "mlp")),
            "w_down": P((f, d), ("mlp", "embed"))}


def mlp(params, cfg: ModelConfig, x):
    dt = _dt(cfg)
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      params["w_down"].astype(dt))


def pum_mlp(params, cfg: ModelConfig, x):
    """SIMDRAM-backed binarized MLP: weights/activations sign-binarized and
    contracted with the XNOR-popcount identity (the paper's XNOR-NET app
    class), with straight-through gradients.  Numerically this equals
    sign(x)·sign(W) matmul — the Pallas ``bitserial_matmul`` kernel computes
    the same contraction from packed bit-planes (asserted in tests)."""
    dt = _dt(cfg)

    @jax.custom_vjp
    def sign_ste(v):
        return jnp.sign(v) + (v == 0).astype(v.dtype)

    def fwd(v):
        return sign_ste(v), v

    def bwd(v, g):
        return (g * (jnp.abs(v) <= 1).astype(g.dtype),)  # clipped STE

    sign_ste.defvjp(fwd, bwd)

    scale = jnp.mean(jnp.abs(x), -1, keepdims=True)
    xb = sign_ste(x)
    g = jnp.einsum("bsd,df->bsf", xb, sign_ste(params["w_gate"]).astype(dt))
    u = jnp.einsum("bsd,df->bsf", xb, sign_ste(params["w_up"]).astype(dt))
    h = jax.nn.silu(g * scale) * (u * scale)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))


def moe_defs(cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    defs = {
        "router": P((d, e), ("embed", None)),
        "w_gate": P((e, d, f), ("experts", "embed", None)),
        "w_up": P((e, d, f), ("experts", "embed", None)),
        "w_down": P((e, f, d), ("experts", None, "embed")),
    }
    if cfg.n_shared_experts:
        defs["shared"] = mlp_defs(cfg, cfg.d_expert * cfg.n_shared_experts)
    return defs


MOE_GROUP = 256        # tokens per routing group (bounds dispatch memory)


def moe(params, cfg: ModelConfig, x):
    """Top-k capacity-based MoE (Switch-style dispatch/combine einsums, the
    standard TPU formulation).  Tokens route in groups of ``MOE_GROUP`` so
    the dispatch tensor is O(T·E·cf·k·GROUP/E) instead of O(T²) — this is
    what lets the 1M-token train_4k cells compile; tokens beyond a group's
    per-expert capacity drop (standard behavior)."""
    dt = _dt(cfg)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    t_total = b * s
    gs = min(MOE_GROUP, t_total)
    while t_total % gs:
        gs //= 2
    ng = t_total // gs
    tokens = x.reshape(ng, gs, d)
    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (G, S, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    capacity = max(1, int(cfg.capacity_factor * gs * k / e))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (G, S, k, E)
    assign = onehot.sum(2)                                    # (G, S, E)
    pos = jnp.cumsum(assign, 1) - assign                      # slot per (G,S,E)
    within = (pos < capacity) * assign
    dispatch = within[..., None] * jax.nn.one_hot(pos, capacity,
                                                  dtype=jnp.float32)
    combine = jnp.einsum("gske,gsk->gse", onehot, gate_vals)
    combine = combine[..., None] * dispatch                   # (G, S, E, C)
    xs = jnp.einsum("gsec,gsd->gecd", dispatch.astype(dt), tokens)
    g = jnp.einsum("gecd,edf->gecf", xs, params["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xs, params["w_up"].astype(dt))
    ys = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u,
                    params["w_down"].astype(dt))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(dt), ys)
    if cfg.n_shared_experts:
        out = out.reshape(b, s, d) + mlp(params["shared"], cfg, x)
        out = out.reshape(ng, gs, d)
    # auxiliary load-balance loss
    me = probs.mean((0, 1))
    ce = assign.mean((0, 1)) / k
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba2 / SSD mixer
# ---------------------------------------------------------------------------

def ssm_defs(cfg: ModelConfig):
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    return {
        "w_in": P((d, 2 * di + 2 * st + nh), ("embed", "inner")),
        "conv_w": P((4, di + 2 * st), (None, "inner"), scale=0.5),
        "a_log": P((nh,), (None,), init="ones"),
        "d_skip": P((nh,), (None,), init="ones"),
        "dt_bias": P((nh,), (None,), init="zeros"),
        "norm": rmsnorm_defs(di),
        "w_out": P((di, d), ("inner", "embed")),
    }


def _ssd_chunked(xh, a, b, c, chunk: int, f32: bool = True):
    """SSD scan.  xh: (B,S,nh,hd) inputs ·dt;  a: (B,S,nh) per-step decay in
    (0,1);  b,c: (B,S,N).  Returns (B,S,nh,hd) contraction with state dim N.

    Quadratic-within-chunk + carried state across chunks (Mamba2 SSD).
    ``f32=False`` keeps the big einsum operands in bf16 with f32 accumulation
    (decay/cumsum stay f32) — the memory-term optimization for SSM cells.
    """
    bs, s, nh, hd = xh.shape
    n = b.shape[-1]
    nc = s // chunk
    mm = jnp.float32 if f32 else jnp.bfloat16
    acc = dict(preferred_element_type=jnp.float32)
    xh = xh.reshape(bs, nc, chunk, nh, hd)
    a = a.reshape(bs, nc, chunk, nh)
    b = b.reshape(bs, nc, chunk, n)
    c = c.reshape(bs, nc, chunk, n)
    la = jnp.log(a + 1e-20)
    cum = jnp.cumsum(la, 2)                       # (B,NC,Q,nh)
    # intra-chunk: G[t,s] = exp(cum[t]-cum[s]) for s<=t
    gd = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,NC,Q,Q,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    g = jnp.where(mask[None, None, :, :, None], jnp.exp(gd), 0.0)
    cb = jnp.einsum("bzqn,bzsn->bzqs", c.astype(mm), b.astype(mm), **acc)
    y_intra = jnp.einsum("bzqs,bzqsh,bzshd->bzqhd", cb.astype(mm),
                         g.astype(mm), xh.astype(mm), **acc)
    # chunk summaries: state contribution of each chunk
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,NC,Q,nh)
    chunk_state = jnp.einsum("bzsn,bzsh,bzshd->bzhdn",
                             b.astype(mm), decay_to_end.astype(mm),
                             xh.astype(mm), **acc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,NC,nh)

    def scan_fn(h, inp):
        st_z, dec_z = inp
        h_new = h * dec_z[:, :, None, None] + st_z
        return h_new, h

    h0 = jnp.zeros((bs, nh, hd, n), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn, h0,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # (B,NC,nh,hd,N)
    y_inter = jnp.einsum("bzqn,bzqh,bzhdn->bzqhd", c.astype(mm),
                         jnp.exp(cum).astype(mm), h_prev.astype(mm), **acc)
    y = (y_intra + y_inter).reshape(bs, s, nh, hd)
    return y


def ssm_mixer(params, cfg: ModelConfig, x, cache=None):
    """Mamba2 block.  cache = dict(conv (B,3,ch), state (B,nh,hd,N), pos)."""
    dt_ = _dt(cfg)
    b_, s, d = x.shape
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = di // nh
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dt_))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * st]
    dt_raw = zxbcdt[..., 2 * di + 2 * st:]
    # depthwise causal conv over xbc (width 4)
    conv_w = params["conv_w"].astype(dt_)
    if cache is None:
        pad = jnp.zeros((b_, 3, xbc.shape[-1]), xbc.dtype)
        xpad = jnp.concatenate([pad, xbc], 1)
        conv = sum(xpad[:, i:i + s] * conv_w[i] for i in range(4))
        new_conv_state = None
    else:
        xpad = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], 1)
        conv = sum(xpad[:, i:i + s] * conv_w[i] for i in range(4))
        new_conv_state = xpad[:, -3:]
    conv = jax.nn.silu(conv)
    xin, bmat, cmat = (conv[..., :di], conv[..., di:di + st],
                       conv[..., di + st:])
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-jnp.exp(params["a_log"].astype(jnp.float32)) * dtv)  # (B,S,nh)
    xh = (xin.reshape(b_, s, nh, hd).astype(jnp.float32)
          * dtv[..., None])
    if cache is None:
        chunk = min(cfg.ssm_chunk, s)
        assert s % chunk == 0
        y = _ssd_chunked(xh, a, bmat.astype(jnp.float32),
                         cmat.astype(jnp.float32), chunk, f32=cfg.ssd_f32)
        new_state = None
    else:
        # single-token recurrence: h' = a·h + x⊗B ; y = h'·C
        h = cache["state"]
        h = (h * a[:, 0, :, None, None]
             + jnp.einsum("bhd,bn->bhdn", xh[:, 0], bmat[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bhdn,bn->bhd", h, cmat[:, 0].astype(jnp.float32))[:, None]
        y = y.reshape(b_, 1, nh, hd)
        new_state = h
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b_, s, di).astype(dt_)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    new_cache = (None if cache is None else
                 {"conv": new_conv_state, "state": new_state,
                  "pos": cache["pos"] + s})
    return out, new_cache


def ssm_cache_shape(cfg: ModelConfig, batch: int):
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    return {"conv": (batch, 3, di + 2 * st),
            "state": (batch, nh, di // nh, st)}
