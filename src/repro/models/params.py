"""Parameter definition framework: one source of truth for shapes, logical
sharding axes, and initialization — so ``init``, ``jax.eval_shape`` (dry-run)
and ``PartitionSpec`` trees never drift apart.

Logical axes → mesh axes resolution happens in ``repro.distributed.sharding``;
model code only names logical axes:

  embed     d_model dims                (replicated)
  vocab     vocabulary                  → 'model'
  heads     attention-head dims         → 'model'
  kv_heads  kv-head dims                → 'model' (replicates if indivisible)
  mlp       FFN hidden                  → 'model'
  experts   MoE expert dim              → 'model'  (expert parallelism)
  inner     SSM inner channels          → 'model'
  state     SSM state dim               (replicated)
  lora      low-rank bottlenecks        (replicated)
  layers    scan-stacked layer dim      (replicated)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"            # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layer dim to every ParamDef (scan-over-layers)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init,
                           d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(defs, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            out.append(jax.random.normal(k, d.shape, dtype) * d.scale)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — the dry-run path (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def logical_specs(defs):
    """Tree of logical-axis tuples, mirroring the params tree."""
    return jax.tree.map(
        lambda d: d.axes,
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def param_bytes(defs, bytes_per: int = 4) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)):
        n = 1
        for s in d.shape:
            n *= s
        total += n * bytes_per
    return total
