"""Model composition: decoder LMs, MoE LMs, enc-dec (whisper), hybrid
(zamba2) and pure-SSM (mamba2) stacks, with scan-over-layers for compile
scalability, per-family decode caches, and logical sharding specs.

Entry points
------------
``model_defs(cfg)``      ParamDef tree (init / eval_shape / specs)
``forward(params, cfg, batch, caches=None)`` → (logits, aux, new_caches)
``init_cache_shapes(cfg, batch, seq)``       decode-cache ShapeDtypeStructs
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (ParamDef, _dt, attention, attention_defs, mla_attention,
                     mla_defs, mlp, mlp_defs, moe, moe_defs, pum_mlp,
                     rmsnorm, rmsnorm_defs, ssm_cache_shape, ssm_defs,
                     ssm_mixer)
from .params import stack_defs

P = ParamDef


# ---------------------------------------------------------------------------
# Block definitions
# ---------------------------------------------------------------------------

def attn_block_defs(cfg: ModelConfig, cross: bool = False):
    defs = {
        "ln1": rmsnorm_defs(cfg.d_model),
        "attn": mla_defs(cfg) if cfg.mla else attention_defs(cfg),
        "ln2": rmsnorm_defs(cfg.d_model),
        "ffn": moe_defs(cfg) if cfg.moe else mlp_defs(cfg),
    }
    if cross:
        defs["ln_x"] = rmsnorm_defs(cfg.d_model)
        defs["xattn"] = attention_defs(cfg)
    return defs


def ssm_block_defs(cfg: ModelConfig):
    return {"ln": rmsnorm_defs(cfg.d_model), "mixer": ssm_defs(cfg)}


def attn_block(params, cfg: ModelConfig, x, positions, cache=None,
               mrope_positions=None, encoder_out=None):
    # a cache dict may carry 'xk'/'xv' (precomputed cross-attention K/V) —
    # split them out before the self-attention cache is used
    cross_kv = None
    if cache is not None and "xk" in cache:
        cross_kv = (cache["xk"], cache["xv"])
        cache = {k: v for k, v in cache.items() if k not in ("xk", "xv")}
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        y, new_cache = mla_attention(params["attn"], cfg, h, positions, cache)
    else:
        y, new_cache = attention(params["attn"], cfg, h, positions, cache,
                                 mrope_positions)
    x = x + y
    if encoder_out is not None or cross_kv is not None:
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        y, _ = _cross_attention(params["xattn"], cfg, h, encoder_out,
                                kv=cross_kv)
        x = x + y
    if cross_kv is not None and new_cache is not None:
        new_cache = dict(new_cache, xk=cross_kv[0], xv=cross_kv[1])
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if cfg.moe:
        y, aux = moe(params["ffn"], cfg, h)
    else:
        y = pum_mlp(params["ffn"], cfg, h) if cfg.pum_mlp else mlp(
            params["ffn"], cfg, h)
        aux = jnp.zeros((), jnp.float32)
    return x + y, aux, new_cache


def _cross_attention(params, cfg: ModelConfig, x, encoder_out, kv=None):
    """Non-causal attention over encoder frames (whisper decoder).

    ``kv``: precomputed (k, v) from the cross-KV cache — avoids recomputing
    the encoder-side projections for all frames on every decode step."""
    from .layers import _sdpa
    dt = _dt(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if kv is not None:
        k, v = kv[0].astype(dt), kv[1].astype(dt)
    else:
        k = jnp.einsum("btd,dhk->bthk", encoder_out, params["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", encoder_out, params["wv"].astype(dt))
    y = _sdpa(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt)), None


def ssm_block(params, cfg: ModelConfig, x, cache=None):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    y, new_cache = ssm_mixer(params["mixer"], cfg, h, cache)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Whole-model defs
# ---------------------------------------------------------------------------

def model_defs(cfg: ModelConfig):
    d, v = cfg.d_model, cfg.vocab
    defs: dict = {"embed": P((v, d), ("vocab", "embed"), scale=0.01)}
    pattern = cfg.pattern()
    n_attn = sum(k == "attn" for k in pattern)
    n_ssm = sum(k == "ssm" for k in pattern)
    if cfg.family == "hybrid":
        defs["ssm_blocks"] = stack_defs(ssm_block_defs(cfg), n_ssm)
        defs["shared_attn"] = attn_block_defs(cfg)          # weight-tied
    elif cfg.family == "ssm":
        defs["ssm_blocks"] = stack_defs(ssm_block_defs(cfg), n_ssm)
    else:
        defs["blocks"] = stack_defs(
            attn_block_defs(cfg, cross=cfg.enc_dec), n_attn)
    if cfg.enc_dec:
        enc_cfg = dataclasses.replace(cfg, moe=False, mla=False)
        defs["enc_blocks"] = stack_defs(attn_block_defs(enc_cfg),
                                        cfg.n_encoder_layers)
        defs["enc_norm"] = rmsnorm_defs(d)
    defs["final_norm"] = rmsnorm_defs(d)
    if not cfg.tie_embeddings:
        defs["lm_head"] = P((d, v), ("embed", "vocab"), scale=0.01)
    return defs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _scan_blocks(stacked_params, fn, x, caches):
    """lax.scan over stacked layer params (+ per-layer caches)."""
    def body(carry, layer):
        x, aux = carry
        lp, lcache = layer
        x, a, new_cache = fn(lp, x, lcache)
        return (x, aux + a), new_cache

    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        (stacked_params, caches))
    return x, aux, new_caches


def forward(params, cfg: ModelConfig, batch: dict, caches=None,
            return_hidden: bool = False):
    """batch: tokens (B,S) [+ positions, mrope_positions, encoder_frames].

    Returns (logits, aux_loss, new_caches).  ``caches=None`` → train/prefill
    (full causal attention); otherwise single-token decode against caches.
    ``return_hidden=True`` returns the final-norm hidden states instead of
    logits (chunked-vocab loss path).
    """
    dt = _dt(cfg)
    tokens = batch["tokens"]
    x = params["embed"].astype(dt)[tokens]
    positions = batch.get("positions")
    if positions is None:
        start = caches["pos"] if caches is not None else 0
        positions = jnp.arange(tokens.shape[1])[None, :] + start
    mrope = batch.get("mrope_positions")

    encoder_out = None
    if cfg.enc_dec:
        encoder_out = _encode(params, cfg, batch, caches)

    remat = cfg.remat != "none"
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {} if caches is not None else None

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def block_fn(lp, x, lcache):
            return attn_block(lp, cfg, x, positions, lcache, mrope,
                              encoder_out)
        fn = jax.checkpoint(block_fn) if remat and caches is None else block_fn
        n = sum(k == "attn" for k in cfg.pattern())
        if cfg.scan_layers:
            layer_caches = caches["layers"] if caches is not None else None
            if layer_caches is None:
                layer_caches = jnp.zeros((n,), jnp.float32)  # dummy scan input
                x, aux_total, _ = _scan_blocks(
                    params["blocks"],
                    lambda lp, x, _lc: fn(lp, x, None), x, layer_caches)
            else:
                x, aux_total, lc = _scan_blocks(params["blocks"], fn, x,
                                                layer_caches)
                new_caches["layers"] = lc
        else:
            # python-unrolled stack (cost-probe path; also usable for small
            # models where unrolling compiles faster than scan)
            lcs = []
            for i in range(n):
                lp = jax.tree.map(lambda a: a[i], params["blocks"])
                lcache = (jax.tree.map(lambda a: a[i], caches["layers"])
                          if caches is not None else None)
                x, a, nc = fn(lp, x, lcache)
                aux_total = aux_total + a
                lcs.append(nc)
            if caches is not None:
                new_caches["layers"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *lcs)
    elif cfg.family == "ssm":
        def sfn(lp, x, lcache):
            y, c = ssm_block(lp, cfg, x, lcache)
            return y, jnp.zeros((), jnp.float32), c
        sfn2 = jax.checkpoint(sfn) if remat and caches is None else sfn
        n = sum(k == "ssm" for k in cfg.pattern())
        if cfg.scan_layers:
            layer_caches = (caches["layers"] if caches is not None
                            else jnp.zeros((n,), jnp.float32))
            if caches is None:
                x, _, _ = _scan_blocks(params["ssm_blocks"],
                                       lambda lp, x, _lc: sfn2(lp, x, None),
                                       x, layer_caches)
            else:
                x, _, lc = _scan_blocks(params["ssm_blocks"], sfn2, x,
                                        layer_caches)
                new_caches["layers"] = lc
        else:
            lcs = []
            for i in range(n):
                lp = jax.tree.map(lambda a: a[i], params["ssm_blocks"])
                lcache = (jax.tree.map(lambda a: a[i], caches["layers"])
                          if caches is not None else None)
                x, _, nc = sfn2(lp, x, lcache)
                lcs.append(nc)
            if caches is not None:
                new_caches["layers"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *lcs)
    elif cfg.family == "hybrid":
        x, aux_total, hc = _hybrid_stack(params, cfg, x, positions, caches)
        if caches is not None:
            new_caches.update(hc)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if caches is not None:
        new_caches["pos"] = caches["pos"] + tokens.shape[1]
        if cfg.enc_dec:
            new_caches["encoder_out"] = encoder_out
    if return_hidden:
        return x, aux_total, new_caches
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt)).astype(jnp.float32)
    return logits, aux_total, new_caches


def _encode(params, cfg: ModelConfig, batch, caches):
    """Whisper encoder over precomputed frame embeddings (conv frontend is a
    stub per the assignment: ``input_specs`` supplies frame embeddings)."""
    if caches is not None and "encoder_out" in caches:
        return caches["encoder_out"]
    frames = batch["encoder_frames"].astype(_dt(cfg))
    pos = jnp.arange(frames.shape[1])[None, :]

    def body(carry, lp):
        x, aux = carry
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, _ = _noncausal_self_attn(lp["attn"], cfg, h, pos)
        x = x + y
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp(lp["ffn"], cfg, h)
        return (x, aux), None

    if cfg.scan_layers:
        (x, _), _ = jax.lax.scan(body, (frames, jnp.zeros(())),
                                 params["enc_blocks"])
    else:
        x = frames
        for i in range(cfg.n_encoder_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            (x, _), _ = body((x, jnp.zeros(())), lp)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _noncausal_self_attn(params, cfg, x, positions):
    from .layers import _sdpa, apply_rope
    dt = _dt(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    y = _sdpa(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt)), None


def _hybrid_stack(params, cfg: ModelConfig, x, positions, caches):
    """Zamba2-style: a scan over Mamba2 layers with a single *weight-tied*
    attention block applied after every ``shared_every`` SSM layers."""
    pattern = cfg.pattern()
    n_ssm = sum(k == "ssm" for k in pattern)
    shared_after = jnp.array(
        [1.0 if (i + 1) % 6 == 0 else 0.0 for i in range(n_ssm)])
    shared_params = params["shared_attn"]
    aux = jnp.zeros((), jnp.float32)

    if caches is None:
        def body(x, layer):
            lp, is_shared = layer
            x, _ = ssm_block(lp, cfg, x, None)

            def with_shared(x):
                y, _, _ = attn_block(shared_params, cfg, x, positions, None)
                return y

            x = jax.lax.cond(is_shared > 0, with_shared, lambda x: x, x)
            return x, None

        body = jax.checkpoint(body) if cfg.remat != "none" else body
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, (params["ssm_blocks"], shared_after))
        else:
            for i in range(n_ssm):
                lp = jax.tree.map(lambda a: a[i], params["ssm_blocks"])
                x, _ = body(x, (lp, shared_after[i]))
        return x, aux, None

    # decode: carry (x, shared-invocation index); per-invocation attn caches
    ssm_caches = caches["ssm"]
    attn_caches = caches["shared_attn"]   # stacked over invocations

    def body(carry, layer):
        x, inv = carry
        lp, lcache, is_shared = layer
        x, new_ssm = ssm_block(lp, cfg, x, lcache)

        def with_shared(x):
            c = {"k": attn_caches["k"][inv], "v": attn_caches["v"][inv],
                 "pos": caches["pos"]}
            y, _, nc = attn_block(shared_params, cfg, x, positions, c)
            return y, nc["k"], nc["v"]

        def without(x):
            return (x, attn_caches["k"][inv], attn_caches["v"][inv])

        x, nk, nv = jax.lax.cond(is_shared > 0, with_shared, without, x)
        return (x, inv + (is_shared > 0).astype(jnp.int32)), (new_ssm, nk, nv, inv)

    (x, _), (new_ssm, nks, nvs, invs) = jax.lax.scan(
        body, (x, jnp.int32(0)),
        (params["ssm_blocks"], ssm_caches, shared_after))
    # scatter updated shared caches back by invocation index
    sel = shared_after > 0
    new_attn = {
        "k": _scatter_shared(attn_caches["k"], nks, invs, sel),
        "v": _scatter_shared(attn_caches["v"], nvs, invs, sel),
    }
    return x, aux, {"ssm": new_ssm, "shared_attn": new_attn}


def _scatter_shared(orig, updates, invs, sel):
    """orig: (I, ...); updates: (L, ...) per ssm layer; keep updates where the
    layer ran the shared block."""
    def upd(acc, item):
        u, inv, s = item
        acc = jax.lax.cond(s, lambda a: a.at[inv].set(u), lambda a: a, acc)
        return acc, None

    acc, _ = jax.lax.scan(upd, orig, (updates, invs, sel))
    return acc


def prime_encdec_caches(params, cfg: ModelConfig, batch, caches):
    """Serving-time priming for enc-dec models: run the encoder once and
    precompute every decoder layer's cross-attention K/V into the cache."""
    enc = _encode(params, cfg, batch, None)
    caches = dict(caches)
    caches["encoder_out"] = enc
    if cfg.cross_kv_cache:
        dt = _dt(cfg)

        def kv_of(xattn):
            k = jnp.einsum("btd,dhk->bthk", enc, xattn["wk"].astype(dt))
            v = jnp.einsum("btd,dhk->bthk", enc, xattn["wv"].astype(dt))
            return k.astype(dt), v.astype(dt)

        xk, xv = jax.vmap(kv_of)(params["blocks"]["xattn"])
        layers = dict(caches["layers"])
        layers["xk"], layers["xv"] = xk, xv
        caches["layers"] = layers
    return caches


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the decode caches (also used to allocate)."""
    pattern = cfg.pattern()
    out: dict = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        n = sum(k == "attn" for k in pattern)
        if cfg.mla:
            out["layers"] = {
                "c": jax.ShapeDtypeStruct(
                    (n, batch, max_seq, cfg.kv_lora_rank), dtype),
                "k_rope": jax.ShapeDtypeStruct(
                    (n, batch, max_seq, cfg.rope_head_dim), dtype),
                "pos": jax.ShapeDtypeStruct((n,), jnp.int32),
            }
        else:
            kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
            kv = jax.ShapeDtypeStruct(
                (n, batch, max_seq, cfg.n_kv_heads, cfg.hd), kv_dt)
            out["layers"] = {"k": kv, "v": kv,
                             "pos": jax.ShapeDtypeStruct((n,), jnp.int32)}
            if cfg.kv_cache_dtype == "int8":
                sc = jax.ShapeDtypeStruct(
                    (n, batch, max_seq, cfg.n_kv_heads), jnp.float32)
                out["layers"]["k_scale"] = sc
                out["layers"]["v_scale"] = sc
        if cfg.enc_dec:
            out["encoder_out"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), dtype)
            if cfg.cross_kv_cache:
                xkv = jax.ShapeDtypeStruct(
                    (n, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                    dtype)
                out["layers"]["xk"] = xkv
                out["layers"]["xv"] = xkv
    elif cfg.family == "ssm":
        n = sum(k == "ssm" for k in pattern)
        shapes = ssm_cache_shape(cfg, batch)
        out["layers"] = {
            "conv": jax.ShapeDtypeStruct((n,) + shapes["conv"], dtype),
            "state": jax.ShapeDtypeStruct((n,) + shapes["state"], jnp.float32),
            "pos": jax.ShapeDtypeStruct((n,), jnp.int32),
        }
    elif cfg.family == "hybrid":
        n = sum(k == "ssm" for k in pattern)
        n_inv = sum(1 for i in range(n) if (i + 1) % 6 == 0)
        shapes = ssm_cache_shape(cfg, batch)
        out["ssm"] = {
            "conv": jax.ShapeDtypeStruct((n,) + shapes["conv"], dtype),
            "state": jax.ShapeDtypeStruct((n,) + shapes["state"], jnp.float32),
            "pos": jax.ShapeDtypeStruct((n,), jnp.int32),
        }
        out["shared_attn"] = {
            "k": jax.ShapeDtypeStruct(
                (n_inv, batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jax.ShapeDtypeStruct(
                (n_inv, batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
        }
    return out
