"""SIMDRAM substrate: DRAM timing/energy model, reliability Monte-Carlo,
vertical-layout transposition, control unit, data-movement model, and the
Ambit baseline."""
