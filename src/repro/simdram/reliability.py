"""Charge-sharing reliability Monte-Carlo (paper §7.5, Table 3).

The paper runs SPICE over the Rambus 55 nm DRAM model scaled by ITRS to
45/32/22 nm and measures TRA / back-to-back-TRA / QRA failure rates under
±0/5/10/20% manufacturing process variation.  We replace SPICE with a direct
charge-sharing model of the sensing operation:

  * k cells (k=3 for TRA, 5 for QRA) share charge with the bitline:
        V_BL = (Σ_i c_i·V_i + C_BL·V_DD/2) / (Σ_i c_i + C_BL)
    where c_i ~ N(C_cell, σ·C_cell) is each cell's capacitance under process
    variation and V_i ∈ {V_DD·r_i, (1−r_i)·0} its (retention-degraded) stored
    level.
  * the sense amplifier resolves V_BL against V_DD/2 with a node-dependent
    offset ~ N(0, σ_SA); smaller nodes have lower C_cell/C_BL ratio and
    larger relative offset, which is what makes QRA collapse at 22 nm.

Failure = sensed value ≠ ideal majority.  Back-to-back TRA additionally
degrades the restored cell level before the second TRA (incomplete restore),
doubling the exposure — reproducing the paper's TRAb2b ≈ 2×TRA trend.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NodeParams:
    """Technology-node electrical parameters (ITRS-scaled trends).

    ``sa_offset_mv`` is the intrinsic sense-amp offset σ; process variation
    adds ``SA_VAR_SLOPE``·variation on top (peripheral transistors vary with
    the same process).  ``min_overdrive_mv`` is the deterministic sensing
    threshold: a bitline swing below it cannot be resolved at all — this is
    what makes QRA 'error' at 22 nm in the paper (§7.5: 'charge sharing
    between five capacitors does not lead to enough voltage')."""
    name: str
    c_cell_ff: float       # storage capacitance
    c_bitline_ff: float    # bitline capacitance
    sa_offset_mv: float    # intrinsic sense-amp offset σ
    restore_frac: float    # charge restored by a (truncated) TRA restore
    min_overdrive_mv: float


SA_VAR_SLOPE = 185.0       # mV of extra offset σ per unit (100%) variation

NODES = {
    "45nm": NodeParams("45nm", c_cell_ff=24.0, c_bitline_ff=85.0,
                       sa_offset_mv=10.0, restore_frac=0.95,
                       min_overdrive_mv=50.0),
    "32nm": NodeParams("32nm", c_cell_ff=19.0, c_bitline_ff=78.0,
                       sa_offset_mv=11.0, restore_frac=0.93,
                       min_overdrive_mv=55.0),
    "22nm": NodeParams("22nm", c_cell_ff=15.5, c_bitline_ff=72.0,
                       sa_offset_mv=12.0, restore_frac=0.91,
                       min_overdrive_mv=65.0),
}

VDD = 1.2  # V


def simulate_multi_row_activation(
        node: NodeParams, k_rows: int, variation: float,
        iters: int = 10_000, back_to_back: bool = False,
        seed: int = 0) -> float:
    """Monte-Carlo failure rate of a k-row simultaneous activation.

    ``variation`` is the ±fraction of process variation (e.g. 0.10 = ±10%);
    we treat it as the half-width of a uniform spread, matching the paper's
    "±X%" presentation, applied to cell capacitance; the SA offset scales
    with variation as peripheral transistors vary alongside cells.
    """
    rng = np.random.default_rng(seed)
    fails = 0
    half = VDD / 2
    for _ in range(iters):
        stored = rng.integers(0, 2, size=k_rows)
        ideal = int(stored.sum() * 2 > k_rows)
        caps = node.c_cell_ff * (1 + rng.uniform(-variation, variation, k_rows))
        caps = np.maximum(caps, 1e-3)
        v_cell = stored * VDD
        if back_to_back:
            # first TRA consumed/restored the charge imperfectly
            v_cell = np.where(stored == 1,
                              VDD * node.restore_frac,
                              VDD * (1 - node.restore_frac))
        q = (caps * v_cell).sum() + node.c_bitline_ff * half
        v_bl = q / (caps.sum() + node.c_bitline_ff)
        sigma = (node.sa_offset_mv + SA_VAR_SLOPE * variation) / 1e3
        offset = rng.normal(0.0, sigma)
        sensed = int(v_bl + offset > half)
        if sensed != ideal:
            fails += 1
    return fails / iters


def qra_margin_collapsed(node: NodeParams) -> bool:
    """Deterministic check of the paper's 22 nm QRA finding: with 3 of 5
    cells charged and nominal capacitances, is the bitline swing below the
    sense amplifier's minimum overdrive?  (paper: 'MAJ(11100) always leads
    to the incorrect outcome 0')."""
    k = 5
    q = (3 * node.c_cell_ff * VDD) + node.c_bitline_ff * VDD / 2
    v_bl = q / (k * node.c_cell_ff + node.c_bitline_ff)
    swing_mv = (v_bl - VDD / 2) * 1e3
    return swing_mv < node.min_overdrive_mv


def reliability_table(iters: int = 10_000, seed: int = 0) -> dict:
    """Reproduce paper Table 3: failure % for TRA / TRAb2b / QRA across
    nodes × variation."""
    out: dict = {}
    for node_name, node in NODES.items():
        rows = {}
        for var in (0.0, 0.05, 0.10, 0.20):
            tra = simulate_multi_row_activation(node, 3, var, iters, seed=seed)
            b2b = simulate_multi_row_activation(node, 3, var, iters,
                                                back_to_back=True, seed=seed + 1)
            if node_name == "22nm" and qra_margin_collapsed(node):
                qra: float | str = "error"   # matches the paper's 22 nm QRA row
            else:
                qra = simulate_multi_row_activation(node, 5, var, iters,
                                                    seed=seed + 2)
            rows[var] = {"TRA": tra, "TRAb2b": b2b, "QRA": qra}
        out[node_name] = rows
    return out
