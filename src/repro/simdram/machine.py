"""SimdramMachine — a session-scoped end-to-end SIMDRAM instance.

The paper's contribution is a *framework*: "a flexible mechanism to support
the implementation of arbitrary user-defined operations", three steps from
an AND/OR/NOT description to in-DRAM execution.  :class:`SimdramMachine`
is that framework as one object.  A machine owns the complete end-to-end
configuration —

* the DRAM substrate: a :class:`~repro.simdram.timing.DRAMTiming` (and the
  :class:`~repro.simdram.timing.SimdramPerfModel` built from it), a bank
  count, and an execution-backend choice;
* its **μProgram Memory**: a private, capacity-bounded
  :class:`~repro.core.trace.TraceCache` holding the compiled + lowered
  ``(UProgram, LoweredTrace)`` pairs of every operation the session runs;
* its **operation registry**: the 16 built-ins plus any operation the user
  defines with :meth:`define_op` (paper Steps 1–2: AOIG → MAJ/NOT synthesis
  → row allocation → μProgram → lowered command trace);
* its own :class:`~repro.core.backends.PerfStats` accumulator and its own
  transpose/movement hook lists, scoped to work executed under this
  machine.

Two machines with different timings, banks, backends or cache capacities
coexist in one process without sharing any of the above — the configuration
is explicit and isolated instead of ambient process globals.

The three paper steps as API::

    m = SimdramMachine(timing=DRAMTiming(...), banks=4, backend="pallas")

    def build_gated_sub(g):                       # Step 1: the AOIG
        a, b, gate, w = (g.input(n) for n in ("a", "b", "gate", "borrow"))
        bg = g.gate_and(b, gate)
        axb = g.gate_xor(a, bg)
        g.add_output("out", g.gate_xor(axb, w))
        g.add_output("borrow", g.gate_or_node(
            g.gate_and(lit_not(a), bg), g.gate_and(w, lit_not(axb))))

    gated_sub = m.define_op(                      # Steps 1-2: synthesize,
        "gated_sub", build_gated_sub,             # allocate rows, lower
        invariants={"gate": DRow("gate", 0, fixed=True)},
        states={"borrow": 0})

    out = gated_sub(a, b, gmask, n_bits=8)        # Step 3: execute — on
    out = m.op("gated_sub")(a, b, gmask, n_bits=8)  # any registered backend

The **default machine** (:func:`default_machine`) is the machine behind the
ambient module-level surface: its μProgram Memory *is* the process-wide
compile/lower cache (``repro.core.trace.GLOBAL_TRACE_CACHE``), its registry
is the process-wide op table (``repro.core.circuits``), and its backend
resolves to the process default, so ``bbop_*`` / ``simdram_pipeline`` /
``timed()`` keep working unchanged as thin delegates of it.
"""
from __future__ import annotations

import contextlib
import threading

import jax.numpy as jnp

from ..core.backends import PerfStats, execute_lowered
from ..core.backends import timed as _timed_execution
from ..core.compiler import SliceSpec, compile_slice
from ..core.graph import LogicGraph
from ..core.trace import GLOBAL_TRACE_CACHE, TraceCache
from .layout import (BitplaneArray, register_movement_hook,
                     register_transpose_hook)
from .timing import DRAMEnergy, DRAMTiming, SimdramPerfModel

# innermost-last, per-thread stack of machines whose session scope is
# open; bbop_* and the layout hooks consult it so work inside ``with
# machine.session():`` (or a machine pipeline) routes through that
# machine's μProgram Memory, backend and scoped hooks.  Thread-local:
# one thread's open session must never leak into another thread's ops —
# that is the isolation this API exists to provide.
_SCOPE = threading.local()


def _scope_stack() -> list:
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    return stack


def current_machine() -> "SimdramMachine | None":
    """The innermost machine with an open session scope on this thread
    (None outside any session)."""
    stack = _scope_stack()
    return stack[-1] if stack else None


# layout-traffic forwarders: scoped hooks observe the work attributed to
# the *innermost* open session only (the same attribution rule PerfStats
# owner-filtering uses) — re-entered sessions therefore fire each hook
# exactly once per pass, and nested foreign sessions don't cross-observe
def _forward_transpose(kind: str, n_bits: int, lanes: int) -> None:
    m = current_machine()
    if m is not None:
        for hook in m._transpose_hooks:
            hook(kind, n_bits, lanes)


def _forward_movement(kind: str, n_rows: int, banks: int | None = None,
                      planes=None) -> None:
    m = current_machine()
    if m is not None:
        for hook in m._movement_hooks:
            hook(kind, n_rows, banks)


register_transpose_hook(_forward_transpose)
register_movement_hook(_forward_movement)

# let the timed execution layer attribute work to the innermost open
# machine session without importing this module eagerly
from ..core import backends as _backends  # noqa: E402

_backends._current_machine = current_machine


class BoundOp:
    """A machine operation bound for execution (what :meth:`SimdramMachine.op`
    returns).  Calling it runs paper Step 3: fetch the compiled trace from
    the machine's μProgram Memory and dispatch it to the machine's backend.

    Positional operands bind to the μProgram's declared input arrays in
    order; each may be a horizontal array (transposed in, transposed out —
    the compat path) or a plane-resident
    :class:`~repro.simdram.layout.BitplaneArray` (planes in, planes out).
    """

    def __init__(self, machine: "SimdramMachine", name: str) -> None:
        self.machine = machine
        self.name = name

    def __repr__(self) -> str:
        return f"<BoundOp {self.name!r} on {self.machine!r}>"

    def program(self, n_bits: int = 8, optimize: bool = True):
        """The cached ``(UProgram, LoweredTrace)`` pair for this width."""
        return self.machine.memory.get(self.name, n_bits, optimize)

    def __call__(self, *operands, n_bits: int = 8, out_bits: int | None = None,
                 signed_out: bool = False, optimize: bool = True,
                 backend: str | None = None):
        from ..ops.bbops import _run_op
        with self.machine.session():
            # one μProgram-Memory access per call (the fetched pair rides
            # through to execution), and operand layout conversion happens
            # inside the session so the machine's scoped hooks observe the
            # input transposition passes too
            compiled = self.program(n_bits, optimize)
            prog = compiled[0]
            if len(operands) != len(prog.inputs):
                raise TypeError(
                    f"{self.name} takes {len(prog.inputs)} operands "
                    f"{prog.inputs}, got {len(operands)}")
            keep = any(isinstance(x, BitplaneArray) for x in operands)
            bound = {}
            for arr_name, x in zip(prog.inputs, operands):
                if not isinstance(x, BitplaneArray):
                    x = BitplaneArray.from_values(jnp.asarray(x), n_bits)
                bound[arr_name] = x
            return _run_op(self.name, bound, n_bits, signed_out=signed_out,
                           out_bits=out_bits, optimize=optimize,
                           backend=backend, keep_planes=keep,
                           machine=self.machine, compiled=compiled)


class SimdramMachine:
    """One isolated, fully-configured SIMDRAM session (see module docstring).

    Parameters
    ----------
    timing / energy : the DRAM substrate (defaults: DDR4-2400 per paper
        Table 2).  ``model`` overrides both with a complete
        :class:`SimdramPerfModel`.
    banks : default bank count for :meth:`pipeline` (1 = unbanked).
    backend : default execution backend for every op this machine runs
        (``None`` = follow the process default).
    cache_capacity : μProgram Memory bound (LRU entries; ``None`` =
        unbounded).  The paper's scratchpad holds few compiled programs;
        a bounded cache makes eviction behavior explicit and testable.
    mode : ``"analytic"`` or ``"replay"`` — how this machine's
        :attr:`stats` accumulator meters execution.
    refresh_phase : replay mode only — thread the accumulated replay clock
        through the refresh-window grid across ops (cross-op refresh
        phase) instead of re-anchoring each op at t=0.
    """

    def __init__(self, timing: DRAMTiming | None = None,
                 energy: DRAMEnergy | None = None,
                 model: SimdramPerfModel | None = None,
                 banks: int = 1, backend: str | None = None,
                 cache_capacity: int | None = 64,
                 mode: str = "analytic", refresh_phase: bool = False,
                 memory: TraceCache | None = None) -> None:
        if model is not None and (timing is not None or energy is not None):
            raise ValueError("pass either a complete model or its "
                             "timing/energy parts, not both")
        if banks < 1:
            raise ValueError(f"banks must be >= 1, got {banks}")
        self.model = model or SimdramPerfModel(timing=timing, energy=energy)
        self.timing = self.model.timing
        self.banks = int(banks)
        self.backend = backend
        self.stats = PerfStats(model=self.model, mode=mode,
                               refresh_phase=refresh_phase, owner=self)
        self._ops: dict[str, object] = {}   # name → compile_fn(n_bits, opt)
        if memory is not None:
            # advanced: adopt an existing μProgram Memory.  Its own bound
            # applies (cache_capacity is not consulted), and its compile
            # hook is wired to this machine's registry if unset so
            # define_op'd ops resolve — a cache already wired to another
            # machine keeps that machine's registry (shared-memory setups
            # share the first owner's op table).
            if memory._compile_fn is None:
                memory._compile_fn = self._compile
            self.memory = memory
        else:
            self.memory = TraceCache(capacity=cache_capacity,
                                     compile_fn=self._compile)
        self._transpose_hooks: list = []
        self._movement_hooks: list = []

    def __repr__(self) -> str:
        be = self.backend or "default"
        return (f"SimdramMachine(banks={self.banks}, backend={be!r}, "
                f"ops={len(self._ops)} user-defined)")

    # -- Step 1+2: operation definition -------------------------------------
    def _compile(self, name: str, n_bits: int, optimize: bool):
        fn = self._ops.get(name)
        if fn is not None:
            return fn(n_bits, optimize)
        from ..core.circuits import compile_operation
        return compile_operation(name, n_bits, optimize=optimize)

    def define_op(self, name: str, build_graph=None, spec=None, *,
                  invariants: dict | None = None, states: dict | None = None,
                  arrays_in: tuple | None = None, out_array: str | None = "out",
                  epilogue_outputs: dict | None = None, compile_fn=None,
                  validate: bool = True, override: bool = False) -> BoundOp:
        """Register a user-defined operation with this machine (Steps 1–2).

        Three entry points, from highest- to lowest-level:

        * ``build_graph(g: LogicGraph)`` — the paper's Step-1 input: an
          AND/OR/NOT description of the op's 1-bit slice.  Primary inputs
          not named in ``invariants``/``states`` become the op's operand
          arrays (``arrays_in`` overrides the inferred order); ``states``
          are loop-carried values (e.g. a carry) with their initial value,
          ``invariants`` bind PIs to fixed rows, ``out_array`` /
          ``epilogue_outputs`` place results — the same vocabulary as
          :class:`~repro.core.compiler.SliceSpec`.
        * ``spec=SliceSpec(...)`` — a pre-built slice spec.
        * ``compile_fn=(n_bits, optimize) -> UProgram`` — full control for
          composite/tree ops (build with ``compile_slice`` /
          ``compile_flat`` / ``rebase`` / ``concat_programs``).

        The op is synthesized (AOIG → optimized MIG), row-allocated,
        lowered to the command-trace IR on first use, cached in this
        machine's μProgram Memory, and immediately executable on **all**
        registered backends — including replay timing — with no other code
        change.  ``validate=True`` checks the Step-1 synthesis: the
        optimized MIG must be functionally equivalent to the naive
        MAJ/NOT substitution on every input assignment.

        On the :func:`default_machine`, definition lands in the
        process-wide op registry so the ambient ``bbop``-style surface
        sees it too.  Returns the bound op, ready to call.
        """
        n_entry = sum(x is not None for x in (build_graph, spec, compile_fn))
        if n_entry != 1:
            raise TypeError("define_op needs exactly one of build_graph, "
                            "spec or compile_fn")
        if spec is None and build_graph is not None:
            g = LogicGraph()
            build_graph(g)
            if not g.outputs:
                raise ValueError(f"{name!r}: build_graph declared no outputs")
            if validate:
                from ..core.synthesis import check_synthesis
                check_synthesis(g, name=name)
            bound_names = set(invariants or {}) | set(states or {})
            missing = bound_names - set(g.input_names())
            if missing:
                raise ValueError(
                    f"{name!r}: invariants/states name unknown inputs "
                    f"{sorted(missing)} (graph inputs: {g.input_names()})")
            if arrays_in is None:
                arrays_in = tuple(n for n in g.input_names()
                                  if n not in bound_names)
            spec = SliceSpec(name, build_graph, tuple(arrays_in),
                             invariants=dict(invariants or {}),
                             states=dict(states or {}),
                             out_array=out_array,
                             epilogue_outputs=dict(epilogue_outputs or {}))
        if compile_fn is None:
            the_spec = spec

            def compile_fn(n_bits, optimize=True, _spec=the_spec):
                return compile_slice(_spec, n_bits, optimize=optimize)

        self._register(name, compile_fn, override=override)
        return self.op(name)

    def _register(self, name: str, compile_fn, override: bool) -> None:
        if not override and name in self.ops():
            raise ValueError(f"operation {name!r} already defined on this "
                             "machine (pass override=True to replace it)")
        self._ops[name] = compile_fn
        # a redefinition must not serve the old definition's compiles
        self.memory.invalidate(name)

    def ops(self) -> tuple[str, ...]:
        """Every operation this machine can execute (registry + local)."""
        from ..core.circuits import list_operations
        return tuple(sorted(set(list_operations()) | set(self._ops)))

    # -- Step 3: execution ---------------------------------------------------
    def op(self, name: str) -> BoundOp:
        """Bind a registered operation for execution: ``m.op("x")(a, b)``."""
        if name not in self.ops():
            raise KeyError(f"unknown operation {name!r}; this machine "
                           f"knows {self.ops()}")
        return BoundOp(self, name)

    @contextlib.contextmanager
    def session(self):
        """Open this machine's scope (on this thread): every ``bbop_*``
        call inside routes through this machine's μProgram Memory and
        backend, and this machine's scoped transpose/movement hooks
        observe the layout traffic attributed to it (innermost session
        wins).  Re-entrant; machine pipelines and bound ops open it
        implicitly."""
        stack = _scope_stack()
        stack.append(self)
        try:
            yield self
        finally:
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] is self:
                    del stack[i]
                    break

    def _stats_for(self, mode: str | None,
                   refresh_phase: bool | None) -> PerfStats:
        """The machine accumulator, or a fresh one when the requested
        timing mode disagrees with it (an accumulator cannot switch
        mid-flight)."""
        want_mode = mode or self.stats.mode
        want_phase = self.stats.refresh_phase if refresh_phase is None \
            else refresh_phase
        if want_mode == self.stats.mode and \
                want_phase == self.stats.refresh_phase:
            return self.stats
        return PerfStats(model=self.model, mode=want_mode,
                         refresh_phase=want_phase, owner=self)

    @contextlib.contextmanager
    def timed(self, mode: str | None = None, stats: PerfStats | None = None,
              refresh_phase: bool | None = None):
        """Timed execution under this machine: like
        :func:`repro.core.backends.timed` but charging the machine's own
        accumulator (with the machine's model) by default, inside the
        machine's session scope.  An explicit ``stats`` accumulator whose
        mode/refresh-phase disagrees with the requested one raises, same
        as the core ``timed()``."""
        st = stats if stats is not None else \
            self._stats_for(mode, refresh_phase)
        with self.session():
            with _timed_execution(stats=st, mode=mode,
                                  refresh_phase=refresh_phase) as s:
                yield s

    def pipeline(self, banks: int | None = None, backend: str | None = None,
                 **kw):
        """A plane-resident :class:`~repro.ops.bbops.simdram_pipeline`
        bound to this machine: ops inside fetch from this machine's
        μProgram Memory, execute on its backend, and (``timed=True``)
        charge its PerfStats.  ``banks`` defaults to the machine's."""
        from ..ops.bbops import simdram_pipeline
        if banks is None and self.banks > 1:
            banks = self.banks
        return simdram_pipeline(banks=banks, backend=backend, machine=self,
                                **kw)

    # -- scoped instrumentation ----------------------------------------------
    def register_transpose_hook(self, hook) -> None:
        """``hook(kind, n_bits, lanes)`` fires for transposition-unit passes
        inside this machine's session scope only."""
        if hook not in self._transpose_hooks:
            self._transpose_hooks.append(hook)

    def register_movement_hook(self, hook) -> None:
        """``hook(kind, n_rows, banks)`` fires for in-DRAM row relocations
        inside this machine's session scope only."""
        if hook not in self._movement_hooks:
            self._movement_hooks.append(hook)

    def cache_stats(self) -> dict:
        """μProgram Memory counters: {hits, misses, entries, hit_rate,
        capacity, evictions}."""
        return self.memory.stats()

    def perf_report(self) -> str:
        """Render the machine accumulator (see :meth:`PerfStats.report`)."""
        return self.stats.report()


class _DefaultMachine(SimdramMachine):
    """The machine behind the ambient module-level surface.

    Its μProgram Memory is the process-wide compile/lower cache and its op
    registry is the process-wide table in :mod:`repro.core.circuits`, so
    ``bbop_*`` / ``simdram_pipeline`` / ``timed()`` (which consult those
    globals directly) are thin delegates of this machine by construction.
    Ops defined here are visible process-wide.
    """

    def __init__(self) -> None:
        super().__init__(backend=None, banks=1, memory=GLOBAL_TRACE_CACHE)

    def _register(self, name: str, compile_fn, override: bool) -> None:
        from ..core.circuits import register_operation
        register_operation(name, compile_fn, override=override)


_DEFAULT_MACHINE: SimdramMachine | None = None


def default_machine() -> SimdramMachine:
    """The process-default :class:`SimdramMachine` (created on first use)."""
    global _DEFAULT_MACHINE
    if _DEFAULT_MACHINE is None:
        _DEFAULT_MACHINE = _DefaultMachine()
    return _DEFAULT_MACHINE
