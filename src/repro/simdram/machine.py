"""SimdramMachine — a session-scoped end-to-end SIMDRAM instance.

The paper's contribution is a *framework*: "a flexible mechanism to support
the implementation of arbitrary user-defined operations", three steps from
an AND/OR/NOT description to in-DRAM execution.  :class:`SimdramMachine`
is that framework as one object.  A machine owns the complete end-to-end
configuration —

* the DRAM substrate: a :class:`~repro.simdram.timing.DRAMTiming` (and the
  :class:`~repro.simdram.timing.SimdramPerfModel` built from it), a bank
  count, and an execution-backend choice;
* its **μProgram Memory**: a private, capacity-bounded
  :class:`~repro.core.trace.TraceCache` holding the compiled + lowered
  ``(UProgram, LoweredTrace)`` pairs of every operation the session runs;
* its **operation registry**: the 16 built-ins plus any operation the user
  defines with :meth:`define_op` (paper Steps 1–2: AOIG → MAJ/NOT synthesis
  → row allocation → μProgram → lowered command trace);
* its own :class:`~repro.core.backends.PerfStats` accumulator and its own
  transpose/movement hook lists, scoped to work executed under this
  machine.

Two machines with different timings, banks, backends or cache capacities
coexist in one process without sharing any of the above — the configuration
is explicit and isolated instead of ambient process globals.

A machine also serves *concurrent workloads*: :meth:`SimdramMachine.submit`
queues an operation for a named tenant and returns a :class:`SimdramFuture`;
:meth:`SimdramMachine.drain` packs every pending request across banks with
a :class:`~repro.simdram.scheduler.BankScheduler` (FR-FCFS issue under the
shared rank constraints, refresh-aware by default), executes them, and
resolves each future with its result plus its modeled per-request timing.
Per-tenant :class:`~repro.core.backends.PerfStats` attribution rides the
owner filter (:meth:`SimdramMachine.tenant_stats`).

The three paper steps as API::

    m = SimdramMachine(timing=DRAMTiming(...), banks=4, backend="pallas")

    def build_gated_sub(g):                       # Step 1: the AOIG
        a, b, gate, w = (g.input(n) for n in ("a", "b", "gate", "borrow"))
        bg = g.gate_and(b, gate)
        axb = g.gate_xor(a, bg)
        g.add_output("out", g.gate_xor(axb, w))
        g.add_output("borrow", g.gate_or_node(
            g.gate_and(lit_not(a), bg), g.gate_and(w, lit_not(axb))))

    gated_sub = m.define_op(                      # Steps 1-2: synthesize,
        "gated_sub", build_gated_sub,             # allocate rows, lower
        invariants={"gate": DRow("gate", 0, fixed=True)},
        states={"borrow": 0})

    out = gated_sub(a, b, gmask, n_bits=8)        # Step 3: execute — on
    out = m.op("gated_sub")(a, b, gmask, n_bits=8)  # any registered backend

The **default machine** (:func:`default_machine`) is the machine behind the
ambient module-level surface: its μProgram Memory *is* the process-wide
compile/lower cache (``repro.core.trace.GLOBAL_TRACE_CACHE``), its registry
is the process-wide op table (``repro.core.circuits``), and its backend
resolves to the process default, so ``bbop_*`` / ``simdram_pipeline`` /
``timed()`` keep working unchanged as thin delegates of it.
"""
from __future__ import annotations

import contextlib
import itertools
import threading

import jax.numpy as jnp

from ..core.backends import (PerfStats, execute_heterogeneous,
                             execute_lowered)
from ..core.backends import timed as _timed_execution
from ..core.compiler import SliceSpec, compile_slice
from ..core.graph import LogicGraph
from ..core.trace import GLOBAL_TRACE_CACHE, TraceCache
from .layout import (LANE_WORD, BitplaneArray, register_movement_hook,
                     register_transpose_hook)
from .scheduler import BankScheduler, RequestTiming, ScheduleResult
from .timing import DRAMEnergy, DRAMTiming, ReplayResult, SimdramPerfModel

# innermost-last, per-thread stack of machines whose session scope is
# open; bbop_* and the layout hooks consult it so work inside ``with
# machine.session():`` (or a machine pipeline) routes through that
# machine's μProgram Memory, backend and scoped hooks.  Thread-local:
# one thread's open session must never leak into another thread's ops —
# that is the isolation this API exists to provide.
_SCOPE = threading.local()


def _scope_stack() -> list:
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    return stack


def current_machine() -> "SimdramMachine | None":
    """The innermost machine with an open session scope on this thread
    (None outside any session)."""
    stack = _scope_stack()
    return stack[-1] if stack else None


# layout-traffic forwarders: scoped hooks observe the work attributed to
# the *innermost* open session only (the same attribution rule PerfStats
# owner-filtering uses) — re-entered sessions therefore fire each hook
# exactly once per pass, and nested foreign sessions don't cross-observe
def _forward_transpose(kind: str, n_bits: int, lanes: int) -> None:
    m = current_machine()
    if m is not None:
        for hook in m._transpose_hooks:
            hook(kind, n_bits, lanes)


def _forward_movement(kind: str, n_rows: int, banks: int | None = None,
                      planes=None) -> None:
    m = current_machine()
    if m is not None:
        for hook in m._movement_hooks:
            hook(kind, n_rows, banks)


register_transpose_hook(_forward_transpose)
register_movement_hook(_forward_movement)

# let the timed execution layer attribute work to the innermost open
# machine session without importing this module eagerly
from ..core import backends as _backends  # noqa: E402

_backends._current_machine = current_machine


class BoundOp:
    """A machine operation bound for execution (what :meth:`SimdramMachine.op`
    returns).  Calling it runs paper Step 3: fetch the compiled trace from
    the machine's μProgram Memory and dispatch it to the machine's backend.

    Positional operands bind to the μProgram's declared input arrays in
    order; each may be a horizontal array (transposed in, transposed out —
    the compat path) or a plane-resident
    :class:`~repro.simdram.layout.BitplaneArray` (planes in, planes out).
    """

    def __init__(self, machine: "SimdramMachine", name: str) -> None:
        self.machine = machine
        self.name = name

    def __repr__(self) -> str:
        return f"<BoundOp {self.name!r} on {self.machine!r}>"

    def program(self, n_bits: int = 8, optimize: bool = True):
        """The cached ``(UProgram, LoweredTrace)`` pair for this width."""
        return self.machine.memory.get(self.name, n_bits, optimize)

    def __call__(self, *operands, n_bits: int = 8, out_bits: int | None = None,
                 signed_out: bool = False, optimize: bool = True,
                 backend: str | None = None):
        from ..ops.bbops import _run_op
        with self.machine.session():
            # one μProgram-Memory access per call (the fetched pair rides
            # through to execution), and operand layout conversion happens
            # inside the session so the machine's scoped hooks observe the
            # input transposition passes too
            compiled = self.program(n_bits, optimize)
            prog = compiled[0]
            # inputs may repeat a name (e.g. relu reads 'a' twice) — one
            # operand binds each distinct input array
            names = tuple(dict.fromkeys(prog.inputs))
            if len(operands) != len(names):
                raise TypeError(
                    f"{self.name} takes {len(names)} operands "
                    f"{names}, got {len(operands)}")
            keep = any(isinstance(x, BitplaneArray) for x in operands)
            bound = {}
            for arr_name, x in zip(names, operands):
                if not isinstance(x, BitplaneArray):
                    x = BitplaneArray.from_values(jnp.asarray(x), n_bits)
                bound[arr_name] = x
            return _run_op(self.name, bound, n_bits, signed_out=signed_out,
                           out_bits=out_bits, optimize=optimize,
                           backend=backend, keep_planes=keep,
                           machine=self.machine, compiled=compiled)


class _Submission:
    """One queued :meth:`SimdramMachine.submit` request awaiting drain."""

    __slots__ = ("future", "name", "operands", "n_bits", "out_bits",
                 "signed_out", "optimize", "backend", "tenant", "priority",
                 "arrival_ns")

    def __init__(self, future, name, operands, n_bits, out_bits,
                 signed_out, optimize, backend, tenant, priority,
                 arrival_ns) -> None:
        self.future = future
        self.name = name
        self.operands = operands
        self.n_bits = n_bits
        self.out_bits = out_bits
        self.signed_out = signed_out
        self.optimize = optimize
        self.backend = backend
        self.tenant = tenant
        self.priority = priority
        self.arrival_ns = arrival_ns


class SimdramFuture:
    """Handle to one scheduled operation (what :meth:`SimdramMachine.submit`
    returns).

    The future resolves when the machine drains: :meth:`result` returns
    the operation's value (running :meth:`SimdramMachine.drain` first if
    needed), and the modeled timing surfaces alongside it — ``timing`` is
    the scheduler's per-request :class:`~repro.simdram.scheduler
    .RequestTiming` (arrival / first-activation / completion, queue vs
    service split, stall attribution), ``replay`` re-expresses it as a
    :class:`~repro.simdram.timing.ReplayResult`, and ``finish_ns`` is the
    modeled completion time on the shared rank clock.  ``tenant`` names
    the workload stream the request was attributed to (its share of the
    machine's PerfStats lives in ``machine.tenant_stats(tenant)``).
    """

    def __init__(self, machine: "SimdramMachine", name: str, tenant: str,
                 index: int) -> None:
        self.machine = machine
        self.name = name
        self.tenant = tenant
        self.index = index          # submission order on this machine
        self._value = None
        self._timing: RequestTiming | None = None
        self._done = False

    def __repr__(self) -> str:
        state = "done" if self._done else "pending"
        return (f"<SimdramFuture #{self.index} {self.name!r} "
                f"tenant={self.tenant!r} {state}>")

    def done(self) -> bool:
        """True once the machine has drained this submission."""
        return self._done

    def result(self):
        """The operation's result, draining the machine's queue first if
        this submission is still pending (same default scheduling as a
        bare :meth:`SimdramMachine.drain`)."""
        if not self._done:
            self.machine.drain()
        return self._value

    @property
    def timing(self) -> RequestTiming | None:
        """Scheduler timing for this request (None until drained)."""
        return self._timing

    @property
    def replay(self) -> ReplayResult | None:
        """This request's scheduled service time as a ReplayResult."""
        return self._timing.replay_result() if self._timing else None

    @property
    def finish_ns(self) -> float | None:
        """Modeled completion time on the drain's rank clock."""
        return self._timing.finish_ns if self._timing else None


class SimdramMachine:
    """One isolated, fully-configured SIMDRAM session (see module docstring).

    Parameters
    ----------
    timing / energy : the DRAM substrate (defaults: DDR4-2400 per paper
        Table 2).  ``model`` overrides both with a complete
        :class:`SimdramPerfModel`.
    banks : default bank count for :meth:`pipeline` (1 = unbanked).
    backend : default execution backend for every op this machine runs
        (``None`` = follow the process default).
    cache_capacity : μProgram Memory bound (LRU entries; ``None`` =
        unbounded).  The paper's scratchpad holds few compiled programs;
        a bounded cache makes eviction behavior explicit and testable.
    mode : ``"analytic"`` or ``"replay"`` — how this machine's
        :attr:`stats` accumulator meters execution.
    refresh_phase : replay mode only — thread the accumulated replay clock
        through the refresh-window grid across ops (cross-op refresh
        phase) instead of re-anchoring each op at t=0.
    """

    def __init__(self, timing: DRAMTiming | None = None,
                 energy: DRAMEnergy | None = None,
                 model: SimdramPerfModel | None = None,
                 banks: int = 1, backend: str | None = None,
                 cache_capacity: int | None = 64,
                 mode: str = "analytic", refresh_phase: bool = False,
                 memory: TraceCache | None = None) -> None:
        if model is not None and (timing is not None or energy is not None):
            raise ValueError("pass either a complete model or its "
                             "timing/energy parts, not both")
        if banks < 1:
            raise ValueError(f"banks must be >= 1, got {banks}")
        self.model = model or SimdramPerfModel(timing=timing, energy=energy)
        self.timing = self.model.timing
        self.banks = int(banks)
        self.backend = backend
        self.stats = PerfStats(model=self.model, mode=mode,
                               refresh_phase=refresh_phase, owner=self)
        self._ops: dict[str, object] = {}   # name → compile_fn(n_bits, opt)
        if memory is not None:
            # advanced: adopt an existing μProgram Memory.  Its own bound
            # applies (cache_capacity is not consulted), and its compile
            # hook is wired to this machine's registry if unset so
            # define_op'd ops resolve — a cache already wired to another
            # machine keeps that machine's registry (shared-memory setups
            # share the first owner's op table).
            if memory._compile_fn is None:
                memory._compile_fn = self._compile
            self.memory = memory
        else:
            self.memory = TraceCache(capacity=cache_capacity,
                                     compile_fn=self._compile)
        self._transpose_hooks: list = []
        self._movement_hooks: list = []
        self._pending: list[_Submission] = []
        self._submit_lock = threading.Lock()
        self._n_submitted = 0

    def __repr__(self) -> str:
        be = self.backend or "default"
        return (f"SimdramMachine(banks={self.banks}, backend={be!r}, "
                f"ops={len(self._ops)} user-defined)")

    # -- Step 1+2: operation definition -------------------------------------
    def _compile(self, name: str, n_bits: int, optimize: bool):
        fn = self._ops.get(name)
        if fn is not None:
            return fn(n_bits, optimize)
        from ..core.circuits import compile_operation
        return compile_operation(name, n_bits, optimize=optimize)

    def define_op(self, name: str, build_graph=None, spec=None, *,
                  invariants: dict | None = None, states: dict | None = None,
                  arrays_in: tuple | None = None, out_array: str | None = "out",
                  epilogue_outputs: dict | None = None, compile_fn=None,
                  validate: bool = True, verify: bool | int = True,
                  override: bool = False) -> BoundOp:
        """Register a user-defined operation with this machine (Steps 1–2).

        Three entry points, from highest- to lowest-level:

        * ``build_graph(g: LogicGraph)`` — the paper's Step-1 input: an
          AND/OR/NOT description of the op's 1-bit slice.  Primary inputs
          not named in ``invariants``/``states`` become the op's operand
          arrays (``arrays_in`` overrides the inferred order); ``states``
          are loop-carried values (e.g. a carry) with their initial value,
          ``invariants`` bind PIs to fixed rows, ``out_array`` /
          ``epilogue_outputs`` place results — the same vocabulary as
          :class:`~repro.core.compiler.SliceSpec`.
        * ``spec=SliceSpec(...)`` — a pre-built slice spec.
        * ``compile_fn=(n_bits, optimize) -> UProgram`` — full control for
          composite/tree ops (build with ``compile_slice`` /
          ``compile_flat`` / ``rebase`` / ``concat_programs``).

        The op is synthesized (AOIG → optimized MIG), row-allocated,
        lowered to the command-trace IR on first use, cached in this
        machine's μProgram Memory, and immediately executable on **all**
        registered backends — including replay timing — with no other code
        change.  ``validate=True`` checks the Step-1 synthesis: the
        optimized MIG must be functionally equivalent to the naive
        MAJ/NOT substitution on every input assignment.

        ``verify`` statically verifies the op's *lowered command trace*
        (:mod:`repro.core.tracelint`) at registration: the op is compiled
        once at a probe width (8 bits, or pass ``verify=<n_bits>`` for
        compile paths that only support other widths; ``verify=False``
        skips), and a trace with lint errors — a read of an uninitialized
        compute cell, a clobbered operand row, an undefined output row, a
        malformed seqs table — rolls the registration back and raises
        :class:`~repro.core.tracelint.TraceLintError` with the full
        report, so a broken ``compile_fn`` can never reach a backend or a
        tenant's bank.  The probe compiles outside the μProgram Memory, so
        registration never perturbs cache entries or hit/miss counters.

        On the :func:`default_machine`, definition lands in the
        process-wide op registry so the ambient ``bbop``-style surface
        sees it too.  Returns the bound op, ready to call.
        """
        n_entry = sum(x is not None for x in (build_graph, spec, compile_fn))
        if n_entry != 1:
            raise TypeError("define_op needs exactly one of build_graph, "
                            "spec or compile_fn")
        if spec is None and build_graph is not None:
            g = LogicGraph()
            build_graph(g)
            if not g.outputs:
                raise ValueError(f"{name!r}: build_graph declared no outputs")
            if verify:
                # pre-synthesis graph lint: malformed user AOIGs fail here
                # with a graph diagnostic, not deep inside Step-1 synthesis
                from ..core.tracelint import lint_graph
                lint_graph(g, name=name).raise_for_errors()
            if validate:
                from ..core.synthesis import check_synthesis
                check_synthesis(g, name=name)
            bound_names = set(invariants or {}) | set(states or {})
            missing = bound_names - set(g.input_names())
            if missing:
                raise ValueError(
                    f"{name!r}: invariants/states name unknown inputs "
                    f"{sorted(missing)} (graph inputs: {g.input_names()})")
            if arrays_in is None:
                arrays_in = tuple(n for n in g.input_names()
                                  if n not in bound_names)
            spec = SliceSpec(name, build_graph, tuple(arrays_in),
                             invariants=dict(invariants or {}),
                             states=dict(states or {}),
                             out_array=out_array,
                             epilogue_outputs=dict(epilogue_outputs or {}))
        if compile_fn is None:
            the_spec = spec

            def compile_fn(n_bits, optimize=True, _spec=the_spec):
                return compile_slice(_spec, n_bits, optimize=optimize)

        self._register(name, compile_fn, override=override)
        if verify:
            from ..core.trace import lower_program
            from ..core.tracelint import TraceLintError
            probe_bits = 8 if verify is True else int(verify)
            try:
                # probe outside the μProgram Memory: registration must not
                # perturb cache entries/counters for ops never executed
                trace = lower_program(self._compile(name, probe_bits, True))
                trace.lint().raise_for_errors()
            except TraceLintError:
                # reject at registration: a broken op must not stay callable
                self._unregister(name)
                raise
        return self.op(name)

    def define_chain(self, name: str, stages, *, outputs=None,
                     verify: bool | int = True,
                     override: bool = False) -> BoundOp:
        """Register a fused cross-op pipeline as a first-class operation.

        ``stages`` is a sequence of :class:`~repro.core.compiler.ChainStage`
        (or ``(op, inputs, output)`` tuples) in SSA form; each stage's op
        resolves through this machine, so user-defined ops fuse like
        built-ins and a stage may itself name another registered chain.
        The whole pipeline compiles to ONE μProgram / one
        :class:`~repro.core.trace.LoweredTrace` per width (see
        :func:`~repro.core.compiler.compile_chain`) — producer output rows
        are allocated where the consumer reads them, so no inter-op
        movement remains at the seams — and the machine treats it exactly
        like any other op: ``m.op(name)(...)`` executes it, and
        :meth:`submit` / :meth:`drain` schedule it as a SINGLE FR-FCFS
        request (one atomic unit on one bank set, never interleaved
        per-op; the future resolves the chain's first output).  The
        μProgram Memory keys it like any op, but the trace's
        ``chain.ops`` make :meth:`TraceCache.invalidate` of ANY
        constituent op evict it.

        ``verify`` probe-compiles the fused trace (width 8, or pass
        ``verify=<n_bits>``) and statically lints it — including the
        chain seam checks — rolling the registration back on any error.
        """
        from ..core.compiler import _as_stage, compile_chain
        norm = tuple(_as_stage(s) for s in stages)
        if not norm:
            raise ValueError(f"{name!r}: define_chain needs >= 1 stage")
        if any(st.op == name for st in norm):
            raise ValueError(f"{name!r}: a chain cannot name itself as a "
                             "stage op")
        chain_outs = tuple(outputs) if outputs is not None else None

        def compile_fn(n_bits, optimize=True, _stages=norm,
                       _outs=chain_outs, _name=name):
            return compile_chain(_stages, n_bits, optimize=optimize,
                                 compile_fn=self._compile, outputs=_outs,
                                 name=_name)

        self._register(name, compile_fn, override=override)
        if verify:
            from ..core.trace import lower_program
            probe_bits = 8 if verify is True else int(verify)
            try:
                # probe outside the μProgram Memory, like define_op: a
                # broken chain (unknown stage op, arity mismatch, lint
                # errors) must not stay callable
                trace = lower_program(self._compile(name, probe_bits, True))
                trace.lint().raise_for_errors()
            except Exception:
                self._unregister(name)
                raise
        return self.op(name)

    def _register(self, name: str, compile_fn, override: bool) -> None:
        if not override and name in self.ops():
            raise ValueError(f"operation {name!r} already defined on this "
                             "machine (pass override=True to replace it)")
        self._ops[name] = compile_fn
        # a redefinition must not serve the old definition's compiles
        self.memory.invalidate(name)

    def _unregister(self, name: str) -> None:
        self._ops.pop(name, None)
        self.memory.invalidate(name)

    def ops(self) -> tuple[str, ...]:
        """Every operation this machine can execute (registry + local)."""
        from ..core.circuits import list_operations
        return tuple(sorted(set(list_operations()) | set(self._ops)))

    # -- Step 3: execution ---------------------------------------------------
    def op(self, name: str) -> BoundOp:
        """Bind a registered operation for execution: ``m.op("x")(a, b)``."""
        if name not in self.ops():
            raise KeyError(f"unknown operation {name!r}; this machine "
                           f"knows {self.ops()}")
        return BoundOp(self, name)

    @contextlib.contextmanager
    def session(self):
        """Open this machine's scope (on this thread): every ``bbop_*``
        call inside routes through this machine's μProgram Memory and
        backend, and this machine's scoped transpose/movement hooks
        observe the layout traffic attributed to it (innermost session
        wins).  Re-entrant; machine pipelines and bound ops open it
        implicitly."""
        stack = _scope_stack()
        stack.append(self)
        try:
            yield self
        finally:
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] is self:
                    del stack[i]
                    break

    def _stats_for(self, mode: str | None,
                   refresh_phase: bool | None) -> PerfStats:
        """The machine accumulator, or a fresh one when the requested
        timing mode disagrees with it (an accumulator cannot switch
        mid-flight)."""
        want_mode = mode or self.stats.mode
        want_phase = self.stats.refresh_phase if refresh_phase is None \
            else refresh_phase
        if want_mode == self.stats.mode and \
                want_phase == self.stats.refresh_phase:
            return self.stats
        return PerfStats(model=self.model, mode=want_mode,
                         refresh_phase=want_phase, owner=self)

    @contextlib.contextmanager
    def timed(self, mode: str | None = None, stats: PerfStats | None = None,
              refresh_phase: bool | None = None):
        """Timed execution under this machine: like
        :func:`repro.core.backends.timed` but charging the machine's own
        accumulator (with the machine's model) by default, inside the
        machine's session scope.  An explicit ``stats`` accumulator whose
        mode/refresh-phase disagrees with the requested one raises, same
        as the core ``timed()``."""
        st = stats if stats is not None else \
            self._stats_for(mode, refresh_phase)
        with self.session():
            with _timed_execution(stats=st, mode=mode,
                                  refresh_phase=refresh_phase) as s:
                yield s

    def pipeline(self, banks: int | None = None, backend: str | None = None,
                 **kw):
        """A plane-resident :class:`~repro.ops.bbops.simdram_pipeline`
        bound to this machine: ops inside fetch from this machine's
        μProgram Memory, execute on its backend, and (``timed=True``)
        charge its PerfStats.  ``banks`` defaults to the machine's."""
        from ..ops.bbops import simdram_pipeline
        if banks is None and self.banks > 1:
            banks = self.banks
        return simdram_pipeline(banks=banks, backend=backend, machine=self,
                                **kw)

    # -- scheduled execution: submit / drain ---------------------------------
    def tenant_stats(self, tenant: str = "default") -> PerfStats:
        """The per-tenant :class:`PerfStats` accumulator (created on first
        use, stored in ``self.stats.tenants``).  Tenant accumulators share
        this machine as owner — interleaved *foreign* machine sessions
        never cross-charge them — and are active only while their own
        tenant's submissions prepare and execute, so concurrent tenants
        never cross-charge each other either.  Summing any meter over
        ``stats.tenants`` reproduces the machine total for work that went
        through submit/drain."""
        st = self.stats.tenants.get(tenant)
        if st is None:
            st = PerfStats(model=self.model, mode=self.stats.mode,
                           refresh_phase=self.stats.refresh_phase,
                           owner=self)
            self.stats.tenants[tenant] = st
        return st

    def submit(self, op: str, *operands, n_bits: int = 8,
               tenant: str = "default", out_bits: int | None = None,
               signed_out: bool = False, optimize: bool = True,
               backend: str | None = None, priority: int = 0,
               arrival_ns: float = 0.0) -> SimdramFuture:
        """Queue one operation for scheduled execution; returns a
        :class:`SimdramFuture`.

        Submissions accumulate until :meth:`drain` runs them through a
        :class:`~repro.simdram.scheduler.BankScheduler` — heterogeneous
        requests packed across banks under the shared rank constraints —
        and executes them on this machine's backend.  ``tenant`` names the
        workload stream for scheduling fairness bookkeeping and PerfStats
        attribution (:meth:`tenant_stats`); operands follow the same
        rules as calling the bound op directly (horizontal arrays or
        plane-resident :class:`BitplaneArray`\\ s).

        ``priority`` is the submission's latency class: :meth:`drain`
        packs and enqueues higher-priority submissions first (FIFO within
        a class), so they take the least-loaded banks and win FR-FCFS
        age ties.  ``arrival_ns`` stamps the request's arrival on the
        drain's rank clock (it cannot issue earlier, and its
        :class:`RequestTiming` queue/service split is measured from it) —
        the serving layer uses it to model intra-step arrival skew."""
        if op not in self.ops():
            raise KeyError(f"unknown operation {op!r}; this machine "
                           f"knows {self.ops()}")
        with self._submit_lock:
            fut = SimdramFuture(self, op, tenant, self._n_submitted)
            self._n_submitted += 1
            self._pending.append(_Submission(
                fut, op, operands, n_bits, out_bits, signed_out,
                optimize, backend, tenant, int(priority),
                float(arrival_ns)))
        return fut

    def drain(self, n_banks: int | None = None,
              refresh_policy: str = "aware", policy: str = "frfcfs",
              scheduler: BankScheduler | None = None,
              batch: bool = False) -> ScheduleResult:
        """Run every pending submission: model the schedule (per-bank
        queues, FR-FCFS issue, the chosen refresh policy) and execute the
        corresponding μPrograms, resolving each submission's future with
        its result and its :class:`RequestTiming`.

        ``n_banks`` sizes the modeled controller (default: the machine's
        bank count, or the timing's ``banks_per_chip`` for a single-bank
        machine); pass an explicit ``scheduler`` to control placement /
        policies fully.  Returns the :class:`ScheduleResult` (makespan,
        per-request and per-tenant breakdowns).  Execution charges land on
        the machine accumulator *and* on each submission's tenant
        accumulator (:meth:`tenant_stats`).

        Packing order honors each submission's ``priority`` (higher
        first, FIFO within a class): a high-priority request takes the
        least-loaded banks and wins FR-FCFS age ties.

        ``batch=True`` is the continuous-batching drain the serving layer
        uses: *compatible* submissions — same lowered trace, backend,
        out_bits and unbanked operand shape — are stacked along the bank
        axis and issued as ONE bank-parallel request (one scheduler entry,
        one vmapped dispatch) instead of one request per submission, in
        chunks of the controller's bank count.  All riders of a stack
        share its :class:`RequestTiming`; per-tenant attribution switches
        to fractional bank shares
        (:meth:`~repro.core.backends.PerfStats.charge_banked_share`), so
        tenant-summed ns/nJ/elem-ops still reproduce the machine totals
        while per-tenant *counters* count each rider's own request."""
        with self._submit_lock:
            subs = self._pending
            self._pending = []
        # latency-class packing (stable: FIFO within a class)
        subs.sort(key=lambda s: -s.priority)
        if scheduler is None:
            if n_banks is None:
                n_banks = self.banks if self.banks > 1 \
                    else self.timing.banks_per_chip
            scheduler = BankScheduler(timing=self.timing, n_banks=n_banks,
                                      policy=policy,
                                      refresh_policy=refresh_policy,
                                      memo=self.memory)
        if not subs:
            return scheduler.run()
        resolved = self._drain_batched(subs, scheduler) if batch \
            else self._drain_each(subs, scheduler)
        sched_res = scheduler.run()
        by_rid = {rt.index: rt for rt in sched_res.requests}
        for fut, rid in resolved:
            fut._timing = by_rid.get(rid)
            fut._done = True
        return sched_res

    def _prepare(self, sub: _Submission):
        """Fetch the compiled pair and bind one submission's operands
        (plane layout); caller wraps this in the tenant's timed scope so
        transposition charges land on the right tenant."""
        prog, trace = self.memory.get(sub.name, sub.n_bits, sub.optimize)
        names = tuple(dict.fromkeys(prog.inputs))
        if len(sub.operands) != len(names):
            raise TypeError(
                f"{sub.name} takes {len(names)} operands "
                f"{names}, got {len(sub.operands)}")
        keep = any(isinstance(x, BitplaneArray) for x in sub.operands)
        bound = {}
        for arr_name, x in zip(names, sub.operands):
            if not isinstance(x, BitplaneArray):
                x = BitplaneArray.from_values(jnp.asarray(x), sub.n_bits)
            bound[arr_name] = x
        if len({(o.banked, o.n_banks, o.length, o.words)
                for o in bound.values()}) > 1:
            raise ValueError(
                f"{sub.name}: operand bank/length shapes disagree: "
                f"{[o.planes.shape for o in bound.values()]}")
        return prog, trace, bound, keep

    def _drain_each(self, subs, scheduler) -> list:
        """One scheduler request + one execution item per submission (the
        default drain path).  Returns ``[(future, rid), ...]``."""
        prepared = []
        with self.session(), _timed_execution(stats=self.stats):
            for sub in subs:
                # prepare inside the tenant's scope so operand
                # transposition charges land on the right tenant
                with _timed_execution(stats=self.tenant_stats(sub.tenant)):
                    prog, trace, bound, keep = self._prepare(sub)
                first = next(iter(bound.values()))
                width = first.n_banks if first.banked else 1
                rid = scheduler.enqueue(
                    trace, banks=width, tenant=sub.tenant,
                    name=f"{sub.name}/{sub.n_bits}b",
                    arrival_ns=sub.arrival_ns,
                    lanes=first.words * LANE_WORD * width)
                prepared.append((sub, prog, trace, bound, keep, rid))
            # execute per tenant (attribution scope); inside a tenant,
            # adjacent same-trace requests collapse into banked batches
            for tenant, group in itertools.groupby(
                    prepared, key=lambda p: p[0].tenant):
                group = list(group)
                items = []
                for sub, prog, trace, bound, keep, rid in group:
                    ob = {prog.outputs[0]: sub.out_bits} \
                        if sub.out_bits else None
                    items.append((prog, trace,
                                  {k: v.planes for k, v in bound.items()},
                                  ob, sub.backend or self.backend))
                with _timed_execution(stats=self.tenant_stats(tenant)):
                    outs_list = execute_heterogeneous(items, machine=self)
                    for (sub, prog, trace, bound, keep, rid), outs in zip(
                            group, outs_list):
                        first = next(iter(bound.values()))
                        res = BitplaneArray(outs[prog.outputs[0]],
                                            sub.out_bits or sub.n_bits,
                                            first.length, sub.signed_out)
                        sub.future._value = res if keep else res.to_values()
        return [(sub.future, rid)
                for sub, _prog, _trace, _bound, _keep, rid in prepared]

    def _drain_batched(self, subs, scheduler) -> list:
        """Continuous-batching drain: stack compatible submissions along
        the bank axis into one scheduler request + one vmapped dispatch
        (see :meth:`drain` with ``batch=True``).  Returns
        ``[(future, rid), ...]``."""
        resolved = []
        with self.session(), _timed_execution(stats=self.stats):
            prepared = []
            for sub in subs:
                with _timed_execution(stats=self.tenant_stats(sub.tenant)):
                    prepared.append((sub, *self._prepare(sub)))
            # group compatible submissions; dict preserves first-occurrence
            # order, so the priority sort above carries into enqueue order
            groups: dict = {}
            for p in prepared:
                sub, prog, trace, bound, keep = p
                first = next(iter(bound.values()))
                if first.banked:
                    # already bank-resident: dispatch solo, as unbatched
                    sig = ("solo", id(sub))
                else:
                    sig = (id(trace), sub.backend or self.backend,
                           sub.out_bits,
                           tuple((k, tuple(v.planes.shape))
                                 for k, v in sorted(bound.items())))
                groups.setdefault(sig, []).append(p)
            cap = max(1, scheduler.n_banks)
            for members in groups.values():
                for i in range(0, len(members), cap):
                    resolved.extend(self._run_stack(members[i:i + cap],
                                                    scheduler))
        return resolved

    def _run_stack(self, members, scheduler) -> list:
        """Issue one compatible chunk as a single banked request."""
        sub0, prog, trace, bound0, _keep0 = members[0]
        first0 = next(iter(bound0.values()))
        ob = {prog.outputs[0]: sub0.out_bits} if sub0.out_bits else None
        backend = sub0.backend or self.backend
        out_name = prog.outputs[0]
        if len(members) == 1:
            sub, prog, trace, bound, keep = members[0]
            width = first0.n_banks if first0.banked else 1
            rid = scheduler.enqueue(
                trace, banks=width, tenant=sub.tenant,
                name=f"{sub.name}/{sub.n_bits}b",
                arrival_ns=sub.arrival_ns,
                lanes=first0.words * LANE_WORD * width)
            with _timed_execution(stats=self.tenant_stats(sub.tenant)):
                outs = execute_lowered(
                    prog, trace, {k: v.planes for k, v in bound.items()},
                    out_bits=ob, backend=backend, machine=self)
            res = BitplaneArray(outs[out_name], sub.out_bits or sub.n_bits,
                                first0.length, sub.signed_out)
            sub.future._value = res if keep else res.to_values()
            return [(sub.future, rid)]
        width = len(members)
        lanes_per = first0.words * LANE_WORD
        tenants = {m[0].tenant for m in members}
        label = sub0.tenant if len(tenants) == 1 else "batch"
        rid = scheduler.enqueue(
            trace, banks=width, tenant=label,
            name=f"{sub0.name}/{sub0.n_bits}b",
            arrival_ns=min(m[0].arrival_ns for m in members),
            lanes=lanes_per * width)
        stacked = {k: jnp.stack([m[3][k].planes for m in members])
                   for k in bound0}
        # the machine accumulator takes the full banked charge here (the
        # tenant scopes are NOT active); each rider below takes its
        # fractional bank share so tenant sums stay exact
        outs = execute_lowered(prog, trace, stacked, out_bits=ob,
                               backend=backend, machine=self)
        out = []
        for idx, (sub, _prog, _trace, bound, keep) in enumerate(members):
            self.tenant_stats(sub.tenant).charge_banked_share(
                prog, trace, banks_total=width, banks_own=1,
                lanes=lanes_per)
            first = next(iter(bound.values()))
            res = BitplaneArray(outs[out_name][idx],
                                sub.out_bits or sub.n_bits,
                                first.length, sub.signed_out)
            # resolve in the tenant's scope: the output de-transposition
            # is the rider's own work, same as the unbatched path
            with _timed_execution(stats=self.tenant_stats(sub.tenant)):
                sub.future._value = res if keep else res.to_values()
            out.append((sub.future, rid))
        return out

    # -- scoped instrumentation ----------------------------------------------
    def register_transpose_hook(self, hook) -> None:
        """``hook(kind, n_bits, lanes)`` fires for transposition-unit passes
        inside this machine's session scope only."""
        if hook not in self._transpose_hooks:
            self._transpose_hooks.append(hook)

    def register_movement_hook(self, hook) -> None:
        """``hook(kind, n_rows, banks)`` fires for in-DRAM row relocations
        inside this machine's session scope only."""
        if hook not in self._movement_hooks:
            self._movement_hooks.append(hook)

    def cache_stats(self) -> dict:
        """μProgram Memory counters: {hits, misses, entries, hit_rate,
        capacity, evictions}."""
        return self.memory.stats()

    def perf_report(self) -> str:
        """Render the machine accumulator (see :meth:`PerfStats.report`)."""
        return self.stats.report()


class _DefaultMachine(SimdramMachine):
    """The machine behind the ambient module-level surface.

    Its μProgram Memory is the process-wide compile/lower cache and its op
    registry is the process-wide table in :mod:`repro.core.circuits`, so
    ``bbop_*`` / ``simdram_pipeline`` / ``timed()`` (which consult those
    globals directly) are thin delegates of this machine by construction.
    Ops defined here are visible process-wide.
    """

    def __init__(self) -> None:
        super().__init__(backend=None, banks=1, memory=GLOBAL_TRACE_CACHE)

    def _register(self, name: str, compile_fn, override: bool) -> None:
        from ..core.circuits import register_operation
        register_operation(name, compile_fn, override=override)

    def _unregister(self, name: str) -> None:
        from ..core.circuits import unregister_operation
        unregister_operation(name)


_DEFAULT_MACHINE: SimdramMachine | None = None


def default_machine() -> SimdramMachine:
    """The process-default :class:`SimdramMachine` (created on first use)."""
    global _DEFAULT_MACHINE
    if _DEFAULT_MACHINE is None:
        _DEFAULT_MACHINE = _DefaultMachine()
    return _DEFAULT_MACHINE
