"""Vertical ⇄ horizontal data layout (paper §3.3, §5.1) — JAX side.

The transposition unit of the paper converts between the CPU's horizontal
layout and SIMDRAM's vertical (bit-plane) layout.  On TPU the same conversion
feeds the bit-plane engine: a horizontally-laid-out integer tensor becomes
``uint32[n_bits, lanes/32]`` where plane *i*, lane *j* holds bit *i* of
element *j*.

These are the pure-jnp reference implementations; the Pallas kernel in
``repro.kernels.bitplane_transpose`` is the production path and is verified
against these in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LANE_WORD = 32  # lanes packed per uint32 word


def to_bitplanes(values: jax.Array, n_bits: int) -> jax.Array:
    """int array (E,) → uint32[n_bits, E/32] vertical bit-planes.

    E must be a multiple of 32.  Element j's bit i lands in plane i, word
    j//32, bit j%32.
    """
    (e,) = values.shape
    assert e % LANE_WORD == 0, "lane count must be a multiple of 32"
    u = values.astype(jnp.uint32)
    bits = (u[None, :] >> jnp.arange(n_bits, dtype=jnp.uint32)[:, None]) & 1
    bits = bits.reshape(n_bits, e // LANE_WORD, LANE_WORD)
    shifts = jnp.arange(LANE_WORD, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def from_bitplanes(planes: jax.Array, signed: bool = False,
                   dtype=jnp.int32) -> jax.Array:
    """uint32[n_bits, W] → int array (32·W,)."""
    n_bits, w = planes.shape
    shifts = jnp.arange(LANE_WORD, dtype=jnp.uint32)
    bits = (planes[:, :, None] >> shifts) & 1          # (n_bits, W, 32)
    bits = bits.reshape(n_bits, w * LANE_WORD)
    weights = (jnp.uint32(1) << jnp.arange(n_bits, dtype=jnp.uint32))
    val = (bits.astype(jnp.uint32) * weights[:, None]).sum(0, dtype=jnp.uint32)
    if signed and n_bits < 32:
        sign = (val >> np.uint32(n_bits - 1)) & 1
        val = val.astype(dtype) - (sign << n_bits).astype(dtype)
        return val
    return val.astype(dtype)


def pack_mask(mask: jax.Array) -> jax.Array:
    """bool (E,) → uint32[E/32] single packed plane."""
    return to_bitplanes(mask.astype(jnp.uint32), 1)[0]


def unpack_mask(plane: jax.Array) -> jax.Array:
    (w,) = plane.shape
    shifts = jnp.arange(LANE_WORD, dtype=jnp.uint32)
    return (((plane[:, None] >> shifts) & 1) != 0).reshape(w * LANE_WORD)


# -- numpy twin used by the reference executor tests -------------------------

def np_to_bitplanes(values: np.ndarray, n_bits: int) -> np.ndarray:
    e = values.shape[0]
    assert e % LANE_WORD == 0
    u = values.astype(np.uint32)
    bits = (u[None, :] >> np.arange(n_bits, dtype=np.uint32)[:, None]) & 1
    bits = bits.reshape(n_bits, e // LANE_WORD, LANE_WORD)
    return (bits << np.arange(LANE_WORD, dtype=np.uint32)).sum(-1).astype(np.uint32)


def np_from_bitplanes(planes: np.ndarray) -> np.ndarray:
    n_bits, w = planes.shape
    shifts = np.arange(LANE_WORD, dtype=np.uint32)
    bits = ((planes[:, :, None] >> shifts) & 1).reshape(n_bits, w * LANE_WORD)
    return (bits.astype(np.uint64) << np.arange(n_bits, dtype=np.uint64)[:, None]).sum(0)
