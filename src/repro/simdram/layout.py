"""Vertical ⇄ horizontal data layout (paper §3.3, §5.1) — JAX side.

The transposition unit of the paper converts between the CPU's horizontal
layout and SIMDRAM's vertical (bit-plane) layout.  On TPU the same conversion
feeds the bit-plane engine: a horizontally-laid-out integer tensor becomes
``uint32[n_bits, lanes/32]`` where plane *i*, lane *j* holds bit *i* of
element *j*.

These are the pure-jnp reference implementations; the Pallas kernel in
``repro.kernels.bitplane_transpose`` is the production path and is verified
against these in tests.

Plane-resident values.  :class:`BitplaneArray` wraps planes together with
their element width / logical length / signedness so chained ``bbop_*``
operations can stay vertical end-to-end (paper Steps 1–3 keep operands in
the subarray; the transposition unit is only paid at the memory boundary).
Every trace-level layout conversion is counted in :data:`TRANSPOSE_STATS`
so tests and benchmarks can assert how often the transposition unit ran.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

LANE_WORD = 32  # lanes packed per uint32 word

# trace-level transposition-unit accounting: one entry per to/from pass
# (a vectorized pass over stacked operands counts once, like the hardware
# streaming a block through the transposition unit)
TRANSPOSE_STATS = {"to_bitplanes": 0, "from_bitplanes": 0}

# perf-instrumentation hooks, called as hook(kind, n_bits, lanes) on every
# transposition-unit pass; the timed execution layer in repro.core.backends
# registers here so passes charge their TranspositionModel cost to the
# active PerfStats (empty unless that module has been imported)
_PERF_HOOKS: list = []


def register_transpose_hook(hook) -> None:
    """Register ``hook(kind: str, n_bits: int, lanes: int)`` to observe every
    transposition-unit pass (``kind`` is "to" or "from").

    These module-level hooks are process-wide plumbing: the timed execution
    layer and the machine layer each register exactly one forwarder here.
    For observation scoped to a single session, prefer
    ``SimdramMachine.register_transpose_hook`` — those fire only for
    passes inside that machine's scope.
    """
    if hook not in _PERF_HOOKS:
        _PERF_HOOKS.append(hook)


def unregister_transpose_hook(hook) -> None:
    """Remove a previously-registered transposition hook (no-op if absent)."""
    if hook in _PERF_HOOKS:
        _PERF_HOOKS.remove(hook)


# in-DRAM data-movement hooks, called as hook(kind, n_rows, banks) whenever
# rows physically relocate ("intra" = LISA inter-subarray hop inside one
# bank, "inter" = RowClone PSM transfer over the internal bus between
# banks); ``banks`` names the destination bank count of a scatter (None for
# gathers and intra-bank hops).  The timed execution layer registers here
# so relocations charge the active PerfStats through its MovementModel and
# — because a scatter's rows ride the shared internal bus serially — so a
# replay-mode accumulator can derive the per-bank data-arrival skew that
# desynchronizes the next op's command streams.
_MOVE_HOOKS: list = []


def register_movement_hook(hook) -> None:
    """Register ``hook(kind: str, n_rows: int, banks: int | None, planes)``
    to observe in-DRAM row relocations (``kind`` is "intra" or "inter";
    ``banks`` is the destination bank count of an inter-bank scatter and
    ``planes`` the scattered plane array — both None for gathers and
    intra-bank hops).  Scoped per-session observation goes through
    ``SimdramMachine.register_movement_hook`` instead."""
    if hook not in _MOVE_HOOKS:
        _MOVE_HOOKS.append(hook)


def unregister_movement_hook(hook) -> None:
    """Remove a previously-registered movement hook (no-op if absent)."""
    if hook in _MOVE_HOOKS:
        _MOVE_HOOKS.remove(hook)


def _fire_movement(kind: str, n_rows: int, banks: int | None = None,
                   planes=None) -> None:
    for hook in _MOVE_HOOKS:
        hook(kind, n_rows, banks, planes)


def note_elided_movement(n_rows: int, banks: int | None = None) -> None:
    """Report an inter-op row relocation that cross-op trace fusion made
    unnecessary: the fused chain's allocator placed a producer's output
    rows where the consumer wants its input, so the LISA hop the unfused
    pipeline would pay never happens.  Fires the movement hooks with
    ``kind="elided"`` — observers count it (so fused-vs-unfused hop deltas
    are provable from one snapshot) but charge nothing."""
    _fire_movement("elided", n_rows, banks)


def reset_transpose_stats() -> None:
    TRANSPOSE_STATS["to_bitplanes"] = 0
    TRANSPOSE_STATS["from_bitplanes"] = 0


def transpose_counts() -> tuple[int, int]:
    """(to_bitplanes passes, from_bitplanes passes) since the last reset."""
    return (TRANSPOSE_STATS["to_bitplanes"], TRANSPOSE_STATS["from_bitplanes"])


def to_bitplanes(values: jax.Array, n_bits: int) -> jax.Array:
    """int array (E,) → uint32[n_bits, E/32] vertical bit-planes.

    E must be a multiple of 32.  Element j's bit i lands in plane i, word
    j//32, bit j%32.
    """
    (e,) = values.shape
    assert e % LANE_WORD == 0, "lane count must be a multiple of 32"
    TRANSPOSE_STATS["to_bitplanes"] += 1
    for hook in _PERF_HOOKS:
        hook("to", n_bits, e)
    u = values.astype(jnp.uint32)
    bits = (u[None, :] >> jnp.arange(n_bits, dtype=jnp.uint32)[:, None]) & 1
    bits = bits.reshape(n_bits, e // LANE_WORD, LANE_WORD)
    shifts = jnp.arange(LANE_WORD, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def from_bitplanes(planes: jax.Array, signed: bool = False,
                   dtype=jnp.int32) -> jax.Array:
    """uint32[n_bits, W] → int array (32·W,)."""
    TRANSPOSE_STATS["from_bitplanes"] += 1
    n_bits, w = planes.shape
    for hook in _PERF_HOOKS:
        hook("from", n_bits, w * LANE_WORD)
    shifts = jnp.arange(LANE_WORD, dtype=jnp.uint32)
    bits = (planes[:, :, None] >> shifts) & 1          # (n_bits, W, 32)
    bits = bits.reshape(n_bits, w * LANE_WORD)
    weights = (jnp.uint32(1) << jnp.arange(n_bits, dtype=jnp.uint32))
    val = (bits.astype(jnp.uint32) * weights[:, None]).sum(0, dtype=jnp.uint32)
    if signed and n_bits < 32:
        sign = (val >> np.uint32(n_bits - 1)) & 1
        val = val.astype(dtype) - (sign << n_bits).astype(dtype)
        return val
    return val.astype(dtype)


def pack_mask(mask: jax.Array) -> jax.Array:
    """bool (E,) → uint32[E/32] single packed plane."""
    return to_bitplanes(mask.astype(jnp.uint32), 1)[0]


def unpack_mask(plane: jax.Array) -> jax.Array:
    (w,) = plane.shape
    shifts = jnp.arange(LANE_WORD, dtype=jnp.uint32)
    return (((plane[:, None] >> shifts) & 1) != 0).reshape(w * LANE_WORD)


# -- plane-resident arrays ---------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitplaneArray:
    """A value living in SIMDRAM's vertical layout.

    ``planes`` is ``uint32[n_bits, W]`` (single subarray) or
    ``uint32[banks, n_bits, W]`` (one subarray per bank — the paper's
    16-bank scaling; backends vmap over the leading axis).  ``length`` is
    the logical element count per bank (lanes beyond it are padding).
    """

    planes: jax.Array
    n_bits: int
    length: int
    signed: bool = False

    # -- pytree protocol (jit/vmap-transparent; metadata is static) ---------
    def tree_flatten(self):
        return (self.planes,), (self.n_bits, self.length, self.signed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_bits, length, signed = aux
        return cls(children[0], n_bits, length, signed)

    @property
    def banked(self) -> bool:
        return self.planes.ndim == 3

    @property
    def n_banks(self) -> int:
        return self.planes.shape[0] if self.banked else 1

    @property
    def words(self) -> int:
        return self.planes.shape[-1]

    # -- memory-boundary conversions (each is ONE transposition-unit pass) --
    @classmethod
    def from_values(cls, values: jax.Array, n_bits: int,
                    signed: bool = False) -> "BitplaneArray":
        """Horizontal ints (E,) or (banks, E) → plane-resident array.

        Banked inputs are transposed in a single vectorized pass: banks are
        concatenated along the lane axis (lane padding keeps each bank
        word-aligned), exactly one streaming pass through the transposition
        unit.
        """
        banked = values.ndim == 2
        e = values.shape[-1]
        pad = (-e) % LANE_WORD
        if pad:
            pad_width = ((0, 0), (0, pad)) if banked else ((0, pad),)
            values = jnp.pad(values, pad_width)
        if banked:
            banks = values.shape[0]
            planes = to_bitplanes(values.reshape(-1), n_bits)
            w = planes.shape[1] // banks
            planes = planes.reshape(n_bits, banks, w).transpose(1, 0, 2)
        else:
            planes = to_bitplanes(values, n_bits)
        return cls(planes, n_bits, e, signed)

    def to_values(self, dtype=jnp.int32) -> jax.Array:
        """Plane-resident → horizontal ints (E,) or (banks, E) — one pass."""
        if self.banked:
            banks, n_bits, w = self.planes.shape
            flat = self.planes.transpose(1, 0, 2).reshape(n_bits, banks * w)
            vals = from_bitplanes(flat, signed=self.signed, dtype=dtype)
            return vals.reshape(banks, w * LANE_WORD)[:, :self.length]
        return from_bitplanes(self.planes, signed=self.signed,
                              dtype=dtype)[:self.length]

    # -- cheap plane-level rewrites (no transposition-unit traffic) ---------
    def flip_msb(self) -> "BitplaneArray":
        """Invert the sign plane (unsigned-compare bias trick) in place —
        a single row operation, no layout conversion."""
        msb = self.n_bits - 1
        planes = self.planes
        if self.banked:
            planes = planes.at[:, msb, :].set(~planes[:, msb, :])
        else:
            planes = planes.at[msb, :].set(~planes[msb, :])
        return dataclasses.replace(self, planes=planes)

    def split_lanes(self) -> tuple["BitplaneArray", "BitplaneArray"]:
        """Split the lane axis in half (word-aligned): (lo, hi) halves.

        Lane re-indexing only — no transposition-unit traffic.  Requires an
        even word count and a fully-padded array (length == lanes), which
        tournament-style reductions maintain by construction.
        """
        w = self.words
        if w % 2:
            raise ValueError("lane split needs an even word count")
        half_lanes = (w // 2) * LANE_WORD
        lo = dataclasses.replace(self, planes=self.planes[..., :w // 2],
                                 length=min(self.length, half_lanes))
        hi = dataclasses.replace(self, planes=self.planes[..., w // 2:],
                                 length=min(self.length, half_lanes))
        return lo, hi

    def shift_lanes(self, k: int) -> "BitplaneArray":
        """Shift the lane axis down by ``k`` (lane ``j`` ← lane ``j + k``),
        zero-filling the vacated top lanes — free plane-level word shifts,
        no transposition-unit traffic.

        Lane ``j`` of a plane is bit ``j % 32`` of word ``j // 32``, so a
        sub-word shift is one logical right-shift per word plus an OR of
        the carry bits from the next word.  This is the SWAR step of
        tournament reductions: compare an array against its ``k``-shifted
        self and the low ``k`` lanes accumulate pairwise winners, all the
        way down to lane 0 — no host epilogue.  ``length`` is unchanged
        (the shifted-in lanes are genuine zero values), matching the
        fully-padded layout tournament pipelines maintain.
        """
        if not 0 < k < LANE_WORD:
            raise ValueError(f"lane shift must be in [1, {LANE_WORD - 1}], "
                             f"got {k}")
        p = self.planes
        carry = jnp.concatenate(
            [p[..., 1:], jnp.zeros_like(p[..., :1])], axis=-1)
        planes = (p >> jnp.uint32(k)) | (carry << jnp.uint32(LANE_WORD - k))
        return dataclasses.replace(self, planes=planes)

    def rebank(self, banks: int | None) -> "BitplaneArray":
        """Redistribute the lane axis across DRAM banks (or gather it back).

        ``rebank(k)`` scatters an unbanked array's lanes over ``k`` banks;
        ``rebank(None)``/``rebank(1)`` gathers a banked array back into one
        subarray.  Unlike the free plane-level rewrites above, this is real
        in-DRAM traffic: every plane of every redistributed bank crosses the
        internal bus as a RowClone PSM row transfer, so the movement hooks
        fire with ``kind="inter"`` (× ``n_bits × banks`` rows) and a timed
        scope charges ``MovementModel.inter_bank_ns``.  Requires a fully
        padded array (``length == lanes``), which pipelines maintain at
        word-aligned bank boundaries.
        """
        if banks in (None, 0, 1):
            if not self.banked:
                return self
            # gather: each bank's plane stack rides the bus once
            nb, n_bits, w = self.planes.shape
            flat = self.planes.transpose(1, 0, 2).reshape(n_bits, nb * w)
            _fire_movement("inter", n_bits * nb)
            return BitplaneArray(flat, self.n_bits, nb * w * LANE_WORD,
                                 self.signed)
        if self.banked:
            if banks == self.n_banks:
                return self
            return self.rebank(None).rebank(banks)
        if self.length != self.words * LANE_WORD:
            raise ValueError(
                f"rebank needs a fully padded array (length {self.length} "
                f"!= {self.words * LANE_WORD} lanes)")
        if self.words % banks:
            raise ValueError(f"{self.words} words do not split over "
                             f"{banks} banks")
        w = self.words // banks
        planes = self.planes.reshape(self.n_bits, banks, w).transpose(1, 0, 2)
        # a scatter serializes each destination bank's plane stack over the
        # shared internal bus, so later banks receive their data later —
        # passing the scattered ``planes`` lets a replay-mode PerfStats
        # record that per-bank arrival skew keyed to this array, so the op
        # that actually consumes it replays at those issue offsets
        _fire_movement("inter", self.n_bits * banks, banks, planes)
        return BitplaneArray(planes, self.n_bits, w * LANE_WORD, self.signed)

    def astype_bits(self, n_bits: int) -> "BitplaneArray":
        """Zero-extend or truncate the plane stack (free row re-indexing)."""
        if n_bits == self.n_bits:
            return self
        axis = 1 if self.banked else 0
        cur = self.planes.shape[axis]
        if n_bits < cur:
            planes = (self.planes[:, :n_bits] if self.banked
                      else self.planes[:n_bits])
        else:
            pad = [(0, 0)] * self.planes.ndim
            pad[axis] = (0, n_bits - cur)
            planes = jnp.pad(self.planes, pad)
        return dataclasses.replace(self, planes=planes, n_bits=n_bits)


# -- numpy twin used by the reference executor tests -------------------------

def np_to_bitplanes(values: np.ndarray, n_bits: int) -> np.ndarray:
    e = values.shape[0]
    assert e % LANE_WORD == 0
    u = values.astype(np.uint32)
    bits = (u[None, :] >> np.arange(n_bits, dtype=np.uint32)[:, None]) & 1
    bits = bits.reshape(n_bits, e // LANE_WORD, LANE_WORD)
    return (bits << np.arange(LANE_WORD, dtype=np.uint32)).sum(-1).astype(np.uint32)


def np_from_bitplanes(planes: np.ndarray) -> np.ndarray:
    n_bits, w = planes.shape
    shifts = np.arange(LANE_WORD, dtype=np.uint32)
    bits = ((planes[:, :, None] >> shifts) & 1).reshape(n_bits, w * LANE_WORD)
    return (bits.astype(np.uint64) << np.arange(n_bits, dtype=np.uint64)[:, None]).sum(0)
