"""Multi-tenant bank-level scheduler: per-bank μProgram queues under the
rank-coupled FSM array (ROADMAP item 1 — the "heavy traffic" unlock).

The SIMDRAM control unit lives *inside the memory controller*, yet the
trace-replay substrate (:class:`~repro.simdram.timing.TraceReplayTiming`)
still broadcasts ONE lowered trace to every engaged bank.  A controller
serving real traffic instead packs *independent* requests across banks —
bank-level parallelism — and arbitrates their activations under the shared
rank state: the tRRD ACT→ACT gap, the sliding four-activate tFAW window,
and the periodic tREFI/tRFC all-bank refresh.  :class:`BankScheduler` is
that controller model, the same task-queue-plus-state-machine shape as a
conventional DRAM controller front end:

* **per-bank μProgram queues** — :meth:`enqueue` places a request's lowered
  trace on one or more bank queues (explicit ``bank_ids`` or least-loaded
  assignment); queues hold *heterogeneous* traces, one FIFO per bank.
* **FR-FCFS-style issue** — :meth:`run` replays every queue on the per-bank
  ACT/PRE FSMs, coupled by one :class:`~repro.simdram.timing._RankState`.
  Each arbitration round picks the *first-ready* activation (the bank FSM
  whose next ACT is locally legal earliest — an in-flight AAP's second ACT
  is ready after tRAS while a fresh sequence waits out tRC, so row-hit-
  first falls out of the FSM timing); ties break oldest-request-first,
  then lowest bank.  Issuing globally-earliest-first keeps the shared
  rank bookkeeping (the 4-deep tFAW activation window) in time order.
* **refresh-aware scheduling** — an Ambit-style charge-sharing sequence
  cannot survive an all-bank refresh (every row is precharged mid-flight),
  so the default policy pauses *between* command sequences: before a
  sequence's first ACT the scheduler checks its full busy span against the
  refresh-window grid (:meth:`_RankState.clear_of_refresh`) and holds
  issue until the sequence fits.  Two alternatives quantify the choice:
  ``"stall"`` issues eagerly and *aborts + restarts* a sequence whose
  mid-sequence ACT lands in a window (the wasted activation still consumed
  rank ACT slots), and ``"defer"`` reproduces the replay substrate's
  optimistic mid-sequence deferral exactly — the property-tested
  equivalence anchor (single tenant × identical traces on all banks under
  ``"defer"`` equals :meth:`TraceReplayTiming.replay` cycle-for-cycle,
  whichever ``replay_engine`` the timing selects: the engines are
  cycle-identical, so the anchor is engine-independent).

The event loop always *steps*: an interleaved multi-trace schedule has no
per-trace closed form to memoize, unlike the single-trace replays the
vectorized engine (``DRAMTiming(replay_engine="vectorized")``) compiles
and the :class:`~repro.core.trace.TraceCache` replay memo serves warm.
What CAN be memoized is the whole busy period: a run's outcome is fully
determined by its request set — per request the trace fingerprint, bank
placement and stream arrival cycles — plus the controller policies, bank
count, refresh phase and timing signature.  Pass ``memo=`` (a
:class:`~repro.core.trace.TraceCache`) and :meth:`run` serves a repeated
busy period as a table lookup (re-labeled with the new requests' names /
tenants / lanes), so a decode server re-issuing the same batch shape
every step does not re-step the Python event loop per step.

The scheduler is a pure timing model: it consumes lowered traces and
produces a :class:`ScheduleResult` (makespan, per-request
:class:`RequestTiming`, per-tenant rollups, stall attribution).  Execution
of the corresponding μPrograms is a separate concern —
:meth:`~repro.simdram.machine.SimdramMachine.submit` /
:meth:`~repro.simdram.machine.SimdramMachine.drain` pair this model with
:func:`~repro.core.backends.execute_heterogeneous` and per-tenant
:class:`~repro.core.backends.PerfStats` attribution.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.trace import SEQ_AP
from .timing import DRAMTiming, ReplayResult, TraceReplayTiming

_REFRESH_POLICIES = ("aware", "stall", "defer")
_ISSUE_POLICIES = ("frfcfs",)


@dataclasses.dataclass(frozen=True)
class RequestTiming:
    """Modeled timing of one scheduled request (one enqueued trace).

    ``start_ns`` is the request's first activation, ``finish_ns`` the
    completion of its last stream's final precharge; ``queue_ns`` /
    ``service_ns`` split the end-to-end latency at that first ACT.  Stall
    fields attribute the request's share of the rank-level mechanisms:
    the four-activate window (``tfaw_stall_ns``), refresh deferrals /
    aware pauses (``refresh_stall_ns``), and — under the ``"stall"``
    refresh policy — sequences aborted by a mid-sequence refresh and
    re-issued from scratch (``n_restarts``; the wasted activations are
    included in ``n_acts``)."""
    index: int
    name: str
    tenant: str
    bank_ids: tuple[int, ...]
    arrival_ns: float
    start_ns: float
    finish_ns: float
    analytic_ns: float
    tfaw_stall_ns: float = 0.0
    refresh_stall_ns: float = 0.0
    n_refresh_stalls: int = 0
    n_restarts: int = 0
    n_acts: int = 0
    n_seqs: int = 0
    lanes: int = 0
    stream_finish_ns: tuple[float, ...] = ()
    # (op, n_seqs) per constituent stage when the request's trace is a
    # fused chain — one FR-FCFS unit, but per-op attribution survives
    fused_stages: tuple[tuple[str, int], ...] = ()

    @property
    def queue_ns(self) -> float:
        """Time spent waiting for the first activation."""
        return self.start_ns - self.arrival_ns

    @property
    def service_ns(self) -> float:
        """First activation → final precharge complete."""
        return self.finish_ns - self.start_ns

    def stage_split(self) -> dict[str, float]:
        """Service time attributed per constituent op.

        A fused chain scheduled as one request still reports per-op
        timing: ``service_ns`` split proportionally by each stage's share
        of command sequences (the replay-gap structure makes sequence
        count the first-order cost driver).  Unfused requests map their
        whole service time to their own name."""
        if not self.fused_stages:
            return {self.name: self.service_ns}
        total = max(1, sum(n for _, n in self.fused_stages))
        out: dict[str, float] = {}
        for op, n in self.fused_stages:
            out[op] = out.get(op, 0.0) + self.service_ns * n / total
        return out

    def replay_result(self) -> ReplayResult:
        """This request's timing as a :class:`ReplayResult` — the same
        shape a standalone :meth:`TraceReplayTiming.replay` of its trace
        would return, so futures expose scheduled timing through the
        familiar replay surface."""
        rel = [f - self.start_ns for f in self.stream_finish_ns] or [0.0]
        return ReplayResult(
            ns=self.service_ns,
            stall_ns=max(0.0, self.service_ns - self.analytic_ns),
            cycles=0, n_seqs=self.n_seqs, n_acts=self.n_acts,
            banks=len(self.bank_ids),
            max_bank_ns=max(rel), min_bank_ns=min(rel),
            tfaw_stall_ns=self.tfaw_stall_ns,
            refresh_stall_ns=self.refresh_stall_ns,
            n_refresh_stalls=self.n_refresh_stalls)


@dataclasses.dataclass(frozen=True)
class ScheduleResult:
    """Outcome of one :meth:`BankScheduler.run` event loop.

    ``ns`` is the makespan (last request finish); ``requests`` holds one
    :class:`RequestTiming` per enqueued request, in submission order.
    Rank-level stall attribution mirrors :class:`ReplayResult`; restarts
    count sequences aborted by mid-sequence refresh under the ``"stall"``
    policy."""
    ns: float
    cycles: int
    n_requests: int
    n_acts: int
    tfaw_stall_ns: float
    refresh_stall_ns: float
    n_refresh_stalls: int
    n_restarts: int
    requests: tuple[RequestTiming, ...]
    bank_finish_ns: tuple[float, ...]

    def per_tenant(self) -> dict[str, dict]:
        """Per-tenant rollup: request count, summed queue/service time,
        latest finish, and stall attribution."""
        out: dict[str, dict] = {}
        for r in self.requests:
            d = out.setdefault(r.tenant, {
                "n_requests": 0, "queue_ns": 0.0, "service_ns": 0.0,
                "finish_ns": 0.0, "tfaw_stall_ns": 0.0,
                "refresh_stall_ns": 0.0, "n_restarts": 0, "lanes": 0})
            d["n_requests"] += 1
            d["queue_ns"] += r.queue_ns
            d["service_ns"] += r.service_ns
            d["finish_ns"] = max(d["finish_ns"], r.finish_ns)
            d["tfaw_stall_ns"] += r.tfaw_stall_ns
            d["refresh_stall_ns"] += r.refresh_stall_ns
            d["n_restarts"] += r.n_restarts
            d["lanes"] += r.lanes
        return out


class _Stream:
    """One request's command stream on one bank (the queue entry)."""

    __slots__ = ("rid", "order", "arrival", "seq_i", "phase")

    def __init__(self, rid: int, order: int, arrival: int) -> None:
        self.rid = rid
        self.order = order          # FCFS rank (submission order)
        self.arrival = arrival      # earliest issue cycle on this bank
        self.seq_i = 0
        self.phase = 0              # 1 = second ACT of an AAP pending


class _Request:
    """Shared bookkeeping for one enqueued request across its streams."""

    __slots__ = ("name", "tenant", "kinds", "analytic", "lanes", "bank_ids",
                 "arrival", "first_act", "finishes", "streams_left",
                 "tfaw", "refresh", "n_ref", "restarts", "acts", "fused",
                 "arrivals", "fingerprint")

    def __init__(self, name, tenant, kinds, analytic, lanes, bank_ids,
                 arrival, fused=()) -> None:
        self.name = name
        self.tenant = tenant
        self.kinds = kinds
        self.analytic = analytic
        self.lanes = lanes
        self.bank_ids = bank_ids
        self.arrival = arrival          # min over streams, cycles
        self.first_act: int | None = None
        self.finishes: list[int] = []
        self.streams_left = len(bank_ids)
        self.tfaw = 0
        self.refresh = 0
        self.n_ref = 0
        self.restarts = 0
        self.acts = 0
        self.fused = fused
        self.arrivals: tuple[int, ...] = ()   # per-stream issue cycles
        self.fingerprint = None               # trace content hash (memo key)


class BankScheduler:
    """Bank-level request scheduler over the per-bank ACT/PRE FSM array
    (see the module docstring for the model).

    Parameters
    ----------
    timing : DRAM substrate (DDR4-2400 default); cycle constants and the
        shared rank state come from a :class:`TraceReplayTiming` built on
        it.
    n_banks : banks served by this controller (default: the timing's
        ``banks_per_chip``).
    policy : ``"frfcfs"`` (first-ready, oldest-first ties) — the only
        issue policy; the parameter names the knob for future variants.
    refresh_policy : ``"aware"`` (pause between sequences — default),
        ``"stall"`` (eager issue, mid-sequence refresh aborts + restarts
        the sequence), or ``"defer"`` (optimistic mid-sequence deferral,
        the replay substrate's exact semantics).
    refresh_phase_ns : anchor the refresh-window grid this long after the
        previous refresh epoch (same convention as
        :meth:`TraceReplayTiming.replay`).
    verify : statically verify every enqueued trace
        (:mod:`repro.core.tracelint` — memoized per trace, so cached
        compiles cost nothing here) and run the cross-trace packing pass:
        two co-scheduled requests from *different tenants* sharing a bank
        with overlapping D-row footprints are flagged as ``bank-overlap``
        warnings on :attr:`lint_diagnostics` (an append-only log across
        busy periods; per-period pairing state resets with :meth:`run`).
        A trace with lint *errors* is rejected at ``enqueue`` with
        :class:`~repro.core.tracelint.TraceLintError`.
    memo : optional :class:`~repro.core.trace.TraceCache` whose schedule
        memo serves repeated busy periods without re-stepping the event
        loop (see the module docstring).  Content-keyed, so a hit is
        cycle-exact; request names/tenants/lanes are re-labeled from the
        live request set.
    """

    def __init__(self, timing: DRAMTiming | None = None,
                 n_banks: int | None = None, policy: str = "frfcfs",
                 refresh_policy: str = "aware",
                 refresh_phase_ns: float = 0.0,
                 verify: bool = True, memo=None) -> None:
        if policy not in _ISSUE_POLICIES:
            raise ValueError(f"unknown issue policy {policy!r} "
                             f"(expected one of {_ISSUE_POLICIES})")
        if refresh_policy not in _REFRESH_POLICIES:
            raise ValueError(f"unknown refresh policy {refresh_policy!r} "
                             f"(expected one of {_REFRESH_POLICIES})")
        self._rt = TraceReplayTiming(timing)
        self.timing = self._rt.timing
        self.n_banks = int(n_banks) if n_banks is not None \
            else self.timing.banks_per_chip
        if self.n_banks < 1:
            raise ValueError(f"n_banks must be >= 1, got {self.n_banks}")
        self.policy = policy
        self.refresh_policy = refresh_policy
        self.refresh_phase_ns = refresh_phase_ns
        self._queues: list[list[_Stream]] = [[] for _ in range(self.n_banks)]
        self._load = [0] * self.n_banks      # enqueued ACT-cycles per bank
        self._requests: list[_Request] = []
        self.verify = verify
        self.memo = memo
        # (name, tenant, D-row footprint, bank set) per request this busy
        # period — the cross-trace bank-overlap lint pairs against these
        self._lint_entries: list[tuple[str, str, frozenset, set]] = []
        self.lint_diagnostics: list = []

    def __repr__(self) -> str:
        pending = sum(len(q) for q in self._queues)
        return (f"BankScheduler(n_banks={self.n_banks}, "
                f"policy={self.policy!r}, "
                f"refresh_policy={self.refresh_policy!r}, "
                f"queued_streams={pending})")

    # -- queueing ------------------------------------------------------------
    def enqueue(self, trace, banks: int = 1, tenant: str = "default",
                name: str = "?", arrival_ns: float = 0.0,
                offsets_ns=None, lanes: int = 0,
                bank_ids=None) -> int:
        """Queue one lowered ``trace`` as a request ``banks`` banks wide;
        returns the request index (key into the eventual
        :attr:`ScheduleResult.requests`).

        The request's identical command stream is queued on ``banks``
        distinct banks — explicit ``bank_ids``, or the least-loaded banks
        by enqueued activation cycles.  ``offsets_ns`` optionally skews
        each stream's earliest start (e.g. scatter data-arrival skew) on
        top of ``arrival_ns``; ``lanes`` is carried through to the result
        for throughput accounting."""
        banks = max(1, int(banks))
        if banks > self.n_banks:
            raise ValueError(f"request is {banks} banks wide but the "
                             f"scheduler serves {self.n_banks}")
        if bank_ids is not None:
            bank_ids = tuple(int(b) for b in bank_ids)
            if len(bank_ids) != banks:
                raise ValueError(f"{len(bank_ids)} bank_ids for a "
                                 f"{banks}-bank request")
            if not all(0 <= b < self.n_banks for b in bank_ids):
                raise ValueError(f"bank_ids {bank_ids} out of range for "
                                 f"{self.n_banks} banks")
        else:
            by_load = sorted(range(self.n_banks),
                             key=lambda k: (self._load[k], k))
            bank_ids = tuple(sorted(by_load[:banks]))
        if offsets_ns is not None and len(offsets_ns) != banks:
            raise ValueError(f"{len(offsets_ns)} issue offsets for "
                             f"{banks} banks")
        if self.verify:
            from ..core.tracelint import lint_packing, row_footprint
            # per-trace lint is memoized on the trace — a compiled trace
            # was already verified at compile time and costs nothing here
            trace.lint().raise_for_errors()
            entry = (name, tenant, row_footprint(trace), set(bank_ids))
            for prior in self._lint_entries:
                self.lint_diagnostics.extend(lint_packing([prior, entry]))
            self._lint_entries.append(entry)
        tck = self.timing.tCK_ns
        kinds = trace.seqs[:, 0].tolist()
        mix = trace.command_mix()
        analytic = (mix["AAP"] * self.timing.t_aap_ns
                    + mix["AP"] * self.timing.t_ap_ns)
        rid = len(self._requests)
        order = rid
        base = max(0, math.ceil(arrival_ns / tck))
        arrivals = [base] * banks if offsets_ns is None else \
            [max(base, math.ceil(o / tck)) for o in offsets_ns]
        # a fused chain trace enqueues as ONE request — a single FR-FCFS
        # unit — but carries its per-stage seq spans so RequestTiming can
        # still attribute service time per constituent op
        chain = getattr(trace, "chain", None)
        fused = tuple((s.op, s.seq_end - s.seq_start)
                      for s in getattr(chain, "stages", ()) or ())
        req = _Request(name, tenant, kinds, analytic, int(lanes), bank_ids,
                       min(arrivals) if arrivals else base, fused=fused)
        req.arrivals = tuple(arrivals)
        req.fingerprint = trace.fingerprint
        self._requests.append(req)
        if not kinds:
            # empty trace: completes on arrival, engages no bank
            req.streams_left = 0
            req.first_act = req.arrival
            req.finishes = list(arrivals)
            return rid
        est = sum(self._rt.c_rc + (self._rt.c_ras if k != SEQ_AP else 0)
                  for k in kinds)
        for a, b in zip(arrivals, bank_ids):
            self._queues[b].append(_Stream(rid, order, a))
            self._load[b] += est
        return rid

    @property
    def n_pending(self) -> int:
        """Streams still queued (across all banks)."""
        return sum(len(q) for q in self._queues)

    # -- the event loop ------------------------------------------------------
    def run(self) -> ScheduleResult:
        """Drain every queue through the FSM array and return the schedule.

        One-shot: the run starts a fresh rank clock at cycle 0, consumes
        everything enqueued so far, and resets the queues — a subsequent
        ``enqueue``/``run`` round models a new, independently-anchored
        busy period."""
        rt = self._rt
        tck = self.timing.tCK_ns
        c_ras, c_rp, c_rc = rt.c_ras, rt.c_rp, rt.c_rc
        phase = 0
        if rt.c_refi and self.refresh_phase_ns:
            phase = math.ceil(self.refresh_phase_ns / tck) % rt.c_refi
        memo_key = None
        if self.memo is not None and self._requests:
            # the busy period's full determinant: per-request content
            # (trace hash, placement, stream arrival cycles) + controller
            # configuration.  Names/tenants/lanes/fused labels are NOT in
            # the key — a hit is re-labeled from the live request set.
            memo_key = ("sched", self.policy, self.refresh_policy, phase,
                        self.n_banks, rt._sig,
                        tuple((r.fingerprint, r.bank_ids, r.arrivals)
                              for r in self._requests))
            hit = self.memo.schedule_get(memo_key)
            if hit is not None:
                relabeled = tuple(
                    dataclasses.replace(
                        cached, name=req.name, tenant=req.tenant,
                        lanes=req.lanes, fused_stages=req.fused)
                    for cached, req in zip(hit.requests, self._requests))
                self._reset()
                return dataclasses.replace(hit, requests=relabeled)
        rank = rt._rank(coupled=True, phase=phase)
        queues = self._queues
        requests = self._requests
        aware = self.refresh_policy == "aware"
        stall = self.refresh_policy == "stall"
        # per-bank FSM state (banks power up idle and precharged)
        n = self.n_banks
        now = [0] * n
        last_act = [-c_rc] * n
        last_pre = [-c_rp] * n
        head = [0] * n                   # FIFO cursor per bank queue
        bank_finish = [0] * n
        pending = sum(len(q) for q in queues)
        total_acts = 0
        total_restarts = 0
        while pending:
            # arbitration: the first-ready bank head (FR-FCFS) or the
            # oldest queued stream (FCFS); ties oldest-then-lowest-bank
            best = None
            for k in range(n):
                if head[k] >= len(queues[k]):
                    continue
                s = queues[k][head[k]]
                if s.phase:
                    t = last_act[k] + c_ras
                else:
                    t = max(now[k], last_pre[k] + c_rp, last_act[k] + c_rc,
                            s.arrival)
                # Under the eager ``"stall"`` policy, in-flight sequences
                # take strict priority (FR-FCFS row-hit-first): after an
                # all-bank refresh every aborted stream's fresh first ACT
                # is ready at the window end, perpetually outracing the
                # in-flight second ACTs (ready a tRAS later) — without the
                # priority no AAP ever completes between refresh windows
                # and the eager loop livelocks.  Rank ACT issue times stay
                # monotone regardless (constrain_act floors at the last
                # recorded ACT + tRRD), so the shared bookkeeping is safe.
                key = (0 if (stall and s.phase) else 1, t, s.order, k)
                if best is None or key < best[0]:
                    best = (key, k, s, t)
            _, k, s, t = best
            req = requests[s.rid]
            kind = req.kinds[s.seq_i]
            if aware and s.phase == 0 and kind != SEQ_AP:
                # pause-point: hold the sequence until every activation
                # clears the refresh grid — a window landing between the
                # ACTs would destroy the in-flight charge-sharing state.
                # (Single-ACT sequences need no lookahead: constrain_refresh
                # already keeps the lone ACT out of windows, and the FSM
                # model issues precharges unconstrained, matching the
                # replay substrate.)  A pause re-arbitrates instead of
                # issuing: another bank's ready activation takes the slot,
                # and the shared rank bookkeeping stays in time order.
                t2 = rank.clear_of_refresh(t, c_ras + 1)
                if t2 > t:
                    rank.refresh_stall += t2 - t
                    rank.n_refresh_stalls += 1
                    req.refresh += t2 - t
                    req.n_ref += 1
                    s.arrival = t2
                    continue
            tfaw0 = rank.tfaw_stall
            t = rank.constrain_act(t)
            req.tfaw += rank.tfaw_stall - tfaw0
            if stall and s.phase:
                ws = rank.next_refresh_start(last_act[k] + 1)
                if ws is not None and ws <= t:
                    # a refresh window opened between the sequence's
                    # activations: the all-bank refresh precharged the rank
                    # mid-sequence, destroying the in-flight charge-sharing
                    # state — the sequence aborts and re-issues after the
                    # window (the wasted activation already consumed its
                    # rank ACT slot)
                    s.phase = 0
                    req.restarts += 1
                    total_restarts += 1
                    we = ws + rank.c_rfc
                    if we > t:
                        req.refresh += we - t
                        req.n_ref += 1
                    now[k] = max(now[k], we)
                    continue
            ref0, nref0 = rank.refresh_stall, rank.n_refresh_stalls
            t = rank.constrain_refresh(t)
            req.refresh += rank.refresh_stall - ref0
            req.n_ref += rank.n_refresh_stalls - nref0
            rank.record(t)
            last_act[k] = t
            req.acts += 1
            total_acts += 1
            if req.first_act is None or t < req.first_act:
                req.first_act = t
            if s.phase == 0 and kind != SEQ_AP:
                s.phase = 1               # AAP / Case-2: back-to-back ACT
            else:
                pre = t + c_ras           # sequence retires with a PRECHARGE
                last_pre[k] = pre
                now[k] = pre
                s.phase = 0
                s.seq_i += 1
                if s.seq_i == len(req.kinds):
                    fin = pre + c_rp      # final precharge must complete
                    req.finishes.append(fin)
                    req.streams_left -= 1
                    bank_finish[k] = fin
                    head[k] += 1
                    pending -= 1
        # collect per-request timings in submission order
        out = []
        for rid, req in enumerate(requests):
            start = req.first_act if req.first_act is not None \
                else req.arrival
            finishes = req.finishes or [req.arrival]
            out.append(RequestTiming(
                index=rid, name=req.name, tenant=req.tenant,
                bank_ids=req.bank_ids,
                arrival_ns=req.arrival * tck, start_ns=start * tck,
                finish_ns=max(finishes) * tck, analytic_ns=req.analytic,
                tfaw_stall_ns=req.tfaw * tck,
                refresh_stall_ns=req.refresh * tck,
                n_refresh_stalls=req.n_ref, n_restarts=req.restarts,
                n_acts=req.acts, n_seqs=len(req.kinds) * len(req.bank_ids),
                lanes=req.lanes,
                stream_finish_ns=tuple(f * tck for f in finishes),
                fused_stages=req.fused))
        cycles = max((max(r.finishes) for r in requests if r.finishes),
                     default=0)
        result = ScheduleResult(
            ns=cycles * tck, cycles=cycles, n_requests=len(requests),
            n_acts=total_acts, tfaw_stall_ns=rank.tfaw_stall * tck,
            refresh_stall_ns=rank.refresh_stall * tck,
            n_refresh_stalls=rank.n_refresh_stalls,
            n_restarts=total_restarts, requests=tuple(out),
            bank_finish_ns=tuple(f * tck for f in bank_finish))
        if memo_key is not None:
            self.memo.schedule_put(memo_key, result)
        self._reset()
        return result

    def _reset(self) -> None:
        self._queues = [[] for _ in range(self.n_banks)]
        self._load = [0] * self.n_banks
        self._requests = []
        self._lint_entries = []
