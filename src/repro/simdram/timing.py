"""DRAM timing, throughput and energy model (paper §6, §7.1, §7.2).

The paper's methodology: an operation's latency is the sum of its AAP/AP
command-sequence latencies under DDR4-2400 timing; throughput is
``SIMD lanes × banks / latency``; energy follows the Micron power model with
Ambit's observation that each additional simultaneously-activated row costs
+22% activation energy [131].

Baselines (paper Table 2): the CPU (16-core Skylake, AVX-512, 4-channel
DDR4-2400) and GPU (Titan V, HBM2) are modeled at their *memory-bandwidth
roofline* for these streaming, memory-bound kernels — the paper itself
classifies the target workloads as memory-bound, so the bandwidth roofline is
the right analytic stand-in for measured hardware we do not have.  All
constants are documented here and surfaced in benchmark output.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.trace import SEQ_AP
from ..core.uprogram import UProgram


@dataclasses.dataclass(frozen=True)
class DRAMTiming:
    """DDR4-2400 (per paper Table 2)."""
    tCK_ns: float = 0.833
    tRCD_ns: float = 14.16
    tRP_ns: float = 14.16
    tRAS_ns: float = 32.0
    row_bits: int = 8 * 1024 * 8          # 8 kB row = 65536 bitlines/SIMD lanes
    banks_per_chip: int = 16

    # command-sequence latencies (Ambit/RowClone command structure):
    #   AP  = ACTIVATE(triple) → PRECHARGE                = tRAS + tRP
    #   AAP = ACTIVATE → ACTIVATE → PRECHARGE             = 2·tRAS + tRP
    @property
    def t_ap_ns(self) -> float:
        return self.tRAS_ns + self.tRP_ns

    @property
    def t_aap_ns(self) -> float:
        return 2 * self.tRAS_ns + self.tRP_ns


@dataclasses.dataclass(frozen=True)
class DRAMEnergy:
    """Activation energy per 8 kB row (derived from the Micron TN-41-01 power
    model for DDR4-2400 x8: (IDD0−IDD3N)·tRC·VDD·devices_per_rank)."""
    e_act_nj: float = 5.8          # one full-row ACTIVATE+PRECHARGE pair
    tra_row_penalty: float = 0.22  # +22% per extra simultaneous row [131]
    background_w: float = 0.15     # per-bank background/peripheral power

    def e_ap_nj(self) -> float:
        # triple-row activation: 1 + 2·22% of a single activation
        return self.e_act_nj * (1 + 2 * self.tra_row_penalty)

    def e_aap_nj(self) -> float:
        return self.e_act_nj * 2   # two back-to-back activations


@dataclasses.dataclass(frozen=True)
class BaselineModel:
    """Memory-bandwidth-roofline models for the CPU/GPU baselines."""
    cpu_bw_gbs: float = 76.8       # 4 ch × DDR4-2400 (Table 2)
    gpu_bw_gbs: float = 652.8      # Titan V HBM2
    cpu_tdp_w: float = 165.0       # Skylake 16-core package
    gpu_tdp_w: float = 250.0       # Titan V board power
    # per-op stream profile: (input arrays, output arrays)
    streams: dict = dataclasses.field(default_factory=lambda: dict(
        default=(2, 1), relu=(1, 1), abs=(1, 1), bitcount=(1, 1),
        and_reduction=(3, 1), or_reduction=(3, 1), xor_reduction=(3, 1),
        if_else=(3, 1),
    ))

    def throughput_gops(self, op: str, n_bits: int, gpu: bool = False) -> float:
        ins, outs = self.streams.get(op, self.streams["default"])
        # computed in bits: the paper evaluates arbitrary precisions, and
        # ``n_bits // 8`` floors to 0 bytes for sub-byte elements
        bytes_per_elem = (ins + outs) * n_bits / 8
        bw = self.gpu_bw_gbs if gpu else self.cpu_bw_gbs
        return bw / bytes_per_elem

    def power_w(self, gpu: bool = False) -> float:
        return self.gpu_tdp_w if gpu else self.cpu_tdp_w


@dataclasses.dataclass(frozen=True)
class MovementModel:
    """In-DRAM data movement (paper §7.6): LISA for intra-bank inter-subarray
    row copies, RowClone PSM for inter-bank copies over the internal bus."""
    t_lisa_row_ns: float = 90.5          # LISA RBM hop (LISA paper, ~1.6 tRC)
    t_psm_row_ns: float = 8 * 1024 / 8 * 0.833  # PSM: row serialized over bus

    def intra_bank_ns(self, n_rows: int) -> float:
        return n_rows * self.t_lisa_row_ns

    def inter_bank_ns(self, n_rows: int) -> float:
        return n_rows * self.t_psm_row_ns


@dataclasses.dataclass(frozen=True)
class TranspositionModel:
    """Transposition-unit overhead (paper §5.1, §7.7): each 64 B cache line
    transposes in one 4 GHz core cycle through the transpose buffer; the
    critical path is the DRAM write of the first subarray's object slices
    (later subarrays overlap with compute)."""
    cacheline_bits: int = 512
    t_buffer_ns: float = 0.25            # 1 cycle @ 4 GHz
    dram_ch_bw_gbs: float = 19.2         # one DDR4-2400 channel

    def first_subarray_ns(self, n_bits: int, lanes: int) -> float:
        # ceiling division: a partial cache line still takes a full buffer
        # pass and a full line write (flooring reported *zero* transposition
        # cost for lanes < 512 and undercounted non-multiples)
        lines_per_plane = -(-lanes // self.cacheline_bits)
        n_lines = n_bits * lines_per_plane
        bytes_moved = n_lines * self.cacheline_bits / 8
        return n_lines * self.t_buffer_ns + bytes_moved / self.dram_ch_bw_gbs


# ---------------------------------------------------------------------------
# Trace-replay timing substrate (DRAMsim-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    """Outcome of replaying one lowered trace on the bank FSM."""
    ns: float            # replayed latency (cycle-quantized, with stalls)
    stall_ns: float      # replayed − analytic (≥ 0: replay only adds stalls)
    cycles: int          # DRAM clock cycles consumed
    n_seqs: int          # command sequences replayed
    n_acts: int          # row activations issued


class _BankFSM:
    """Per-bank ACT/PRE state machine in DRAM clock cycles.

    Tracks the two hazards the analytic per-command sum ignores: an ACT may
    only issue tRP after the bank's last PRECHARGE and tRC after its last
    ACTIVATE, and a PRECHARGE only tRAS after the row (or row group)
    activated.  Within an AAP the back-to-back ACTIVATE follows the source
    activation after tRAS (Ambit's command structure: the source row is
    latched in the sense amplifiers before the destination wordline rises).
    """

    __slots__ = ("now", "last_act", "last_pre", "n_acts")

    def __init__(self, c_rp: int, c_rc: int) -> None:
        # the bank powers up idle and precharged
        self.now = 0
        self.last_act = -c_rc
        self.last_pre = -c_rp
        self.n_acts = 0

    def activate(self, c_rp: int, c_rc: int) -> int:
        t = max(self.now, self.last_pre + c_rp, self.last_act + c_rc)
        self.last_act = t
        self.n_acts += 1
        return t

    def activate_back_to_back(self, c_ras: int) -> int:
        """Second ACTIVATE of an AAP: tRAS after the source activation."""
        t = self.last_act + c_ras
        self.last_act = t
        self.n_acts += 1
        return t

    def precharge(self, c_ras: int) -> int:
        t = self.last_act + c_ras
        self.last_pre = t
        self.now = t
        return t


class TraceReplayTiming:
    """Cycle-accurate trace replay: every command sequence of a
    :class:`~repro.core.trace.LoweredTrace` is issued to a per-bank FSM on
    DRAM clock edges instead of being charged a flat analytic latency.

    Commands issue on tCK boundaries, so each timing parameter rounds *up*
    to whole cycles; combined with the FSM's ACT/PRE hazards this makes the
    replayed latency a superset of the analytic sum — replay can only add
    stall cycles, never remove work.  Banks run the command stream in
    lockstep (the paper's control unit broadcasts one μOp stream), so one
    FSM replays for all banks.
    """

    def __init__(self, timing: DRAMTiming | None = None) -> None:
        self.timing = timing or DRAMTiming()
        tck = self.timing.tCK_ns
        self.c_ras = math.ceil(self.timing.tRAS_ns / tck)
        self.c_rp = math.ceil(self.timing.tRP_ns / tck)
        self.c_rc = self.c_ras + self.c_rp        # ACT→ACT, same bank

    def replay(self, trace) -> ReplayResult:
        c_ras, c_rp, c_rc = self.c_ras, self.c_rp, self.c_rc
        bank = _BankFSM(c_rp, c_rc)
        kinds = trace.seqs[:, 0].tolist()
        for kind in kinds:
            bank.activate(c_rp, c_rc)
            if kind != SEQ_AP:                    # AAP / Case-2 fused AAP
                bank.activate_back_to_back(c_ras)
            bank.precharge(c_ras)
        # the final precharge must complete before the op retires
        cycles = bank.now + c_rp if kinds else 0
        ns = cycles * self.timing.tCK_ns
        mix = trace.command_mix()
        analytic = (mix["AAP"] * self.timing.t_aap_ns
                    + mix["AP"] * self.timing.t_ap_ns)
        return ReplayResult(ns=ns, stall_ns=max(0.0, ns - analytic),
                            cycles=cycles, n_seqs=len(kinds),
                            n_acts=bank.n_acts)


class SimdramPerfModel:
    """Throughput / energy for a compiled μProgram (the paper's Fig. 9/10)."""

    def __init__(self, timing: DRAMTiming | None = None,
                 energy: DRAMEnergy | None = None,
                 baseline: BaselineModel | None = None,
                 movement: MovementModel | None = None,
                 transposition: TranspositionModel | None = None,
                 replay: TraceReplayTiming | None = None) -> None:
        self.timing = timing or DRAMTiming()
        self.energy = energy or DRAMEnergy()
        self.baseline = baseline or BaselineModel()
        self.movement = movement or MovementModel()
        self.transposition = transposition or TranspositionModel()
        self.replay_timing = replay or TraceReplayTiming(self.timing)

    def replay_result(self, trace) -> ReplayResult:
        """Replay a lowered trace on the bank FSM (measured-style latency)."""
        return self.replay_timing.replay(trace)

    def replay_latency_ns(self, trace) -> float:
        return self.replay_result(trace).ns

    def replay_energy_nj(self, prog: UProgram, trace) -> float:
        """Replayed energy: the activation energy is fixed by the command
        mix (identical to the analytic model), but stall cycles still burn
        background/peripheral power — so replayed nJ ≥ analytic nJ by
        exactly ``background_w × stall_ns``."""
        return (self.energy_nj(prog)
                + self.energy.background_w * self.replay_result(trace).stall_ns)

    def latency_ns(self, prog: UProgram) -> float:
        mix = prog.command_mix()
        t = self.timing
        return mix["AAP"] * t.t_aap_ns + mix["AP"] * t.t_ap_ns

    def throughput_gops(self, prog: UProgram, banks: int = 1) -> float:
        """Elements per second (×1e-9): one row of SIMD lanes per bank per
        μProgram execution; banks operate in parallel (§6)."""
        lanes = self.timing.row_bits
        return lanes * banks / self.latency_ns(prog)

    def energy_nj(self, prog: UProgram) -> float:
        mix = prog.command_mix()
        e = self.energy
        # an AAP whose source activation is a TRA pays the TRA penalty too
        extra_tra = mix["TRA"] - mix["AP"]
        return (mix["AAP"] * e.e_aap_nj() + mix["AP"] * e.e_ap_nj()
                + extra_tra * e.e_act_nj * 2 * e.tra_row_penalty)

    def power_w(self, prog: UProgram, banks: int = 1) -> float:
        return (self.energy_nj(prog) / self.latency_ns(prog)
                + self.energy.background_w) * banks

    def throughput_per_watt(self, prog: UProgram, banks: int = 1) -> float:
        return self.throughput_gops(prog, banks) / self.power_w(prog, banks)

    # -- baselines ----------------------------------------------------------
    def cpu_gops(self, op: str, n_bits: int) -> float:
        return self.baseline.throughput_gops(op, n_bits, gpu=False)

    def gpu_gops(self, op: str, n_bits: int) -> float:
        return self.baseline.throughput_gops(op, n_bits, gpu=True)

    def cpu_gops_per_watt(self, op: str, n_bits: int) -> float:
        return self.cpu_gops(op, n_bits) / self.baseline.power_w(False)

    def gpu_gops_per_watt(self, op: str, n_bits: int) -> float:
        return self.gpu_gops(op, n_bits) / self.baseline.power_w(True)
