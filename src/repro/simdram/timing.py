"""DRAM timing, throughput and energy model (paper §6, §7.1, §7.2).

The paper's methodology: an operation's latency is the sum of its AAP/AP
command-sequence latencies under DDR4-2400 timing; throughput is
``SIMD lanes × banks / latency``; energy follows the Micron power model with
Ambit's observation that each additional simultaneously-activated row costs
+22% activation energy [131].

Baselines (paper Table 2): the CPU (16-core Skylake, AVX-512, 4-channel
DDR4-2400) and GPU (Titan V, HBM2) are modeled at their *memory-bandwidth
roofline* for these streaming, memory-bound kernels — the paper itself
classifies the target workloads as memory-bound, so the bandwidth roofline is
the right analytic stand-in for measured hardware we do not have.  All
constants are documented here and surfaced in benchmark output.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.trace import ACT_GAP_RAS, ACT_GAP_RC, ACT_GAP_START, SEQ_AP
from ..core.uprogram import UProgram


@dataclasses.dataclass(frozen=True)
class DRAMTiming:
    """DDR4-2400 (per paper Table 2).

    Beyond the per-bank row-cycle parameters, the replay substrate obeys the
    rank-level activation windows real chips enforce: ``tRRD`` (minimum gap
    between ACTs to different banks of a rank), ``tFAW`` (at most four ACTs
    per sliding window — the four-activate window), and periodic refresh
    (one ``tRFC``-long all-bank refresh every ``tREFI``).  Set ``tFAW_ns=0``
    / ``tRRD_ns=0`` to lift the activation windows and ``tREFI_ns=0`` to
    disable refresh.  ``desync_policy`` selects how multi-bank replay runs:
    ``"desync"`` (default) replays one FSM per bank with the rank windows
    coupling them; ``"lockstep"`` replays the legacy single broadcast FSM
    that assumes banks mirror each other for free (no tRRD/tFAW).

    ``replay_engine`` selects how traces replay: ``"vectorized"``
    (default) compiles each trace's stall structure to arrays and solves
    the timing recurrences with prefix scans, falling back to the FSM for
    the configurations it cannot prove exact; ``"stepped"`` always steps
    the per-edge FSM (the oracle).  Both produce cycle-identical
    :class:`ReplayResult`\\ s.
    """
    tCK_ns: float = 0.833
    tRCD_ns: float = 14.16
    tRP_ns: float = 14.16
    tRAS_ns: float = 32.0
    row_bits: int = 8 * 1024 * 8          # 8 kB row = 65536 bitlines/SIMD lanes
    banks_per_chip: int = 16
    # rank-level activation windows + refresh (DDR4-2400 x8 datasheet values)
    tRRD_ns: float = 4.9                  # ACT→ACT, different banks (tRRD_L)
    tFAW_ns: float = 30.0                 # four-activate window
    tREFI_ns: float = 7812.5              # avg refresh interval (64 ms / 8192)
    tRFC_ns: float = 350.0                # refresh cycle time (8 Gb die)
    desync_policy: str = "desync"         # "desync" | "lockstep"
    replay_engine: str = "vectorized"     # "vectorized" | "stepped"

    # command-sequence latencies (Ambit/RowClone command structure):
    #   AP  = ACTIVATE(triple) → PRECHARGE                = tRAS + tRP
    #   AAP = ACTIVATE → ACTIVATE → PRECHARGE             = 2·tRAS + tRP
    @property
    def t_ap_ns(self) -> float:
        return self.tRAS_ns + self.tRP_ns

    @property
    def t_aap_ns(self) -> float:
        return 2 * self.tRAS_ns + self.tRP_ns


@dataclasses.dataclass(frozen=True)
class DRAMEnergy:
    """Activation energy per 8 kB row (derived from the Micron TN-41-01 power
    model for DDR4-2400 x8: (IDD0−IDD3N)·tRC·VDD·devices_per_rank)."""
    e_act_nj: float = 5.8          # one full-row ACTIVATE+PRECHARGE pair
    tra_row_penalty: float = 0.22  # +22% per extra simultaneous row [131]
    background_w: float = 0.15     # per-bank background/peripheral power

    def e_ap_nj(self) -> float:
        # triple-row activation: 1 + 2·22% of a single activation
        return self.e_act_nj * (1 + 2 * self.tra_row_penalty)

    def e_aap_nj(self) -> float:
        return self.e_act_nj * 2   # two back-to-back activations


@dataclasses.dataclass(frozen=True)
class BaselineModel:
    """Memory-bandwidth-roofline models for the CPU/GPU baselines."""
    cpu_bw_gbs: float = 76.8       # 4 ch × DDR4-2400 (Table 2)
    gpu_bw_gbs: float = 652.8      # Titan V HBM2
    cpu_tdp_w: float = 165.0       # Skylake 16-core package
    gpu_tdp_w: float = 250.0       # Titan V board power
    # per-op stream profile: (input arrays, output arrays)
    streams: dict = dataclasses.field(default_factory=lambda: dict(
        default=(2, 1), relu=(1, 1), abs=(1, 1), bitcount=(1, 1),
        and_reduction=(3, 1), or_reduction=(3, 1), xor_reduction=(3, 1),
        if_else=(3, 1),
    ))

    def throughput_gops(self, op: str, n_bits: int, gpu: bool = False) -> float:
        ins, outs = self.streams.get(op, self.streams["default"])
        # computed in bits: the paper evaluates arbitrary precisions, and
        # ``n_bits // 8`` floors to 0 bytes for sub-byte elements
        bytes_per_elem = (ins + outs) * n_bits / 8
        bw = self.gpu_bw_gbs if gpu else self.cpu_bw_gbs
        return bw / bytes_per_elem

    def power_w(self, gpu: bool = False) -> float:
        return self.gpu_tdp_w if gpu else self.cpu_tdp_w


@dataclasses.dataclass(frozen=True)
class MovementModel:
    """In-DRAM data movement (paper §7.6): LISA for intra-bank inter-subarray
    row copies, RowClone PSM for inter-bank copies over the internal bus."""
    t_lisa_row_ns: float = 90.5          # LISA RBM hop (LISA paper, ~1.6 tRC)
    t_psm_row_ns: float = 8 * 1024 / 8 * 0.833  # PSM: row serialized over bus

    def intra_bank_ns(self, n_rows: int) -> float:
        return n_rows * self.t_lisa_row_ns

    def inter_bank_ns(self, n_rows: int) -> float:
        return n_rows * self.t_psm_row_ns


@dataclasses.dataclass(frozen=True)
class TranspositionModel:
    """Transposition-unit overhead (paper §5.1, §7.7): each 64 B cache line
    transposes in one 4 GHz core cycle through the transpose buffer; the
    critical path is the DRAM write of the first subarray's object slices
    (later subarrays overlap with compute)."""
    cacheline_bits: int = 512
    t_buffer_ns: float = 0.25            # 1 cycle @ 4 GHz
    dram_ch_bw_gbs: float = 19.2         # one DDR4-2400 channel

    def first_subarray_ns(self, n_bits: int, lanes: int) -> float:
        # ceiling division: a partial cache line still takes a full buffer
        # pass and a full line write (flooring reported *zero* transposition
        # cost for lanes < 512 and undercounted non-multiples)
        lines_per_plane = -(-lanes // self.cacheline_bits)
        n_lines = n_bits * lines_per_plane
        bytes_moved = n_lines * self.cacheline_bits / 8
        return n_lines * self.t_buffer_ns + bytes_moved / self.dram_ch_bw_gbs


# ---------------------------------------------------------------------------
# Trace-replay timing substrate (DRAMsim-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    """Outcome of replaying one lowered trace on the bank FSM array.

    ``ns`` is the overall finish (the slowest bank); the per-bank breakdown
    records how desynchronized the banks ended up (``max_bank_ns`` −
    ``min_bank_ns``) and attributes stall time to the two rank-level
    mechanisms: the four-activate window (``tfaw_stall_ns``) and refresh
    windows (``refresh_stall_ns``).  Stall attributions are summed over
    every issued command (per-bank streams) or over the broadcast timeline
    (lockstep policy).
    """
    ns: float            # replayed latency (cycle-quantized, with stalls)
    stall_ns: float      # replayed − analytic (≥ 0: replay only adds stalls)
    cycles: int          # DRAM clock cycles consumed (slowest bank)
    n_seqs: int          # command sequences replayed (all banks)
    n_acts: int          # row activations issued (all banks)
    banks: int = 1
    max_bank_ns: float = 0.0      # slowest bank's finish time (== ns)
    min_bank_ns: float = 0.0      # fastest bank's finish time
    tfaw_stall_ns: float = 0.0    # ACTs deferred by the four-activate window
    refresh_stall_ns: float = 0.0  # ACTs deferred by refresh windows
    n_refresh_stalls: int = 0     # ACT issues pushed past a refresh window

    @property
    def bank_spread_ns(self) -> float:
        """Finish-time spread between the slowest and fastest bank."""
        return self.max_bank_ns - self.min_bank_ns


class _RankState:
    """Rank-level issue constraints shared by every bank FSM of a rank.

    Tracks the three mechanisms the per-bank FSMs cannot see alone: the
    minimum ACT→ACT gap across banks (tRRD), the sliding four-activate
    window (tFAW — at most four ACTs per window), and periodic refresh
    (ACTs may not issue inside ``[k·tREFI, k·tREFI + tRFC)``).  All three
    only ever *delay* an ACT, so replay latency remains a superset of the
    analytic command sum.

    ``phase`` shifts the refresh-window grid: refresh windows are anchored
    in *rank* time, not per-op time, so a replay that starts ``phase``
    cycles after the previous refresh epoch sees its first window after
    ``tREFI − phase`` local cycles instead of ``tREFI``.  The timed
    execution layer threads its accumulated replay clock through here
    (``PerfStats(refresh_phase=True)``) so ops shorter than tREFI still
    accrue their share of refresh stall inside long pipelines.
    """

    __slots__ = ("c_rrd", "c_faw", "c_refi", "c_rfc", "phase", "last_act",
                 "acts", "tfaw_stall", "refresh_stall", "n_refresh_stalls")

    def __init__(self, c_rrd: int, c_faw: int, c_refi: int,
                 c_rfc: int, phase: int = 0) -> None:
        self.c_rrd = c_rrd
        self.c_faw = c_faw
        self.c_refi = c_refi
        self.c_rfc = c_rfc
        self.phase = phase
        self.last_act: int | None = None
        self.acts: list[int] = []          # issue cycles of the last 4 ACTs
        self.tfaw_stall = 0
        self.refresh_stall = 0
        self.n_refresh_stalls = 0

    def constrain_act(self, t: int) -> int:
        """Earliest cycle ≥ ``t`` satisfying the rank's ACT-slot windows —
        the tRRD ACT→ACT gap and the sliding four-activate tFAW window.
        Refresh is *not* consulted (see :meth:`constrain_refresh`); the
        split lets queue-aware schedulers interleave their own refresh
        policy between the two checks."""
        if self.c_rrd and self.last_act is not None:
            t = max(t, self.last_act + self.c_rrd)
        if self.c_faw and len(self.acts) == 4:
            gate = self.acts[0] + self.c_faw
            if gate > t:
                self.tfaw_stall += gate - t
                t = gate
        return t

    def refresh_window(self, t: int) -> tuple[int, int] | None:
        """The ``(start, end)`` local-cycle bounds of the refresh window
        covering ``t``, or None when ``t`` is outside every active window.

        Rank time = local replay time + phase since the last epoch.
        ``k >= 1`` models the freshly-refreshed bank of a standalone
        replay (no window at its own t=0); with a threaded phase the
        epoch-0 window is real — an op whose clock lands just past a
        tREFI boundary starts *inside* that window and must stall out of
        it (phase > 0 lifts the guard for k == 0)."""
        if not self.c_refi:
            return None
        ta = t + self.phase
        k = ta // self.c_refi
        if (k >= 1 or self.phase) and ta < k * self.c_refi + self.c_rfc:
            return (k * self.c_refi - self.phase,
                    k * self.c_refi + self.c_rfc - self.phase)
        return None

    def next_refresh_start(self, t: int) -> int | None:
        """Local start cycle of the first *active* refresh window whose
        start is ≥ ``t`` (None when refresh is disabled).  The epoch-0
        window only exists under a threaded phase, matching
        :meth:`refresh_window`'s guard."""
        if not self.c_refi:
            return None
        k_min = 0 if self.phase else 1
        k = max(k_min, -(-(t + self.phase) // self.c_refi))
        return k * self.c_refi - self.phase

    def clear_of_refresh(self, t: int, span: int) -> int:
        """Earliest cycle ≥ ``t`` at which a busy period of ``span`` cycles
        fits entirely between refresh windows — the refresh-*aware*
        scheduler's pause-point: rather than letting a window interrupt an
        in-flight command sequence, issue is held until the whole sequence
        can run to completion.  A span too long to ever fit between two
        windows is returned unchanged; the caller falls back to
        mid-sequence refresh semantics."""
        if not self.c_refi or span >= self.c_refi - self.c_rfc:
            return t
        while True:
            win = self.refresh_window(t)
            if win is not None:
                t = win[1]
                continue
            nxt = self.next_refresh_start(t)
            if nxt is not None and nxt < t + span:
                t = nxt + self.c_rfc
                continue
            return t

    def constrain_refresh(self, t: int) -> int:
        """Earliest cycle ≥ ``t`` outside any refresh window (ACTs may not
        issue while the rank refreshes); deferral is metered as refresh
        stall."""
        win = self.refresh_window(t)
        if win is not None:
            self.refresh_stall += win[1] - t
            self.n_refresh_stalls += 1
            t = win[1]
        return t

    def constrain(self, t: int) -> int:
        """Earliest cycle ≥ ``t`` at which one more ACT may issue (ACT-slot
        windows first, then refresh)."""
        return self.constrain_refresh(self.constrain_act(t))

    def record(self, t: int) -> None:
        # tolerate slightly out-of-order records (a scheduler issuing a
        # prioritized in-flight ACT with tRRD disabled): the window
        # bookkeeping needs last_act/acts[0] to be the true max/min
        self.last_act = t if self.last_act is None else max(self.last_act, t)
        self.acts.append(t)
        if len(self.acts) > 1 and self.acts[-2] > t:
            self.acts.sort()
        if len(self.acts) > 4:
            del self.acts[0]


class TraceReplayTiming:
    """Cycle-accurate trace replay: every command sequence of a
    :class:`~repro.core.trace.LoweredTrace` is issued to an array of
    per-bank ACT/PRE state machines on DRAM clock edges instead of being
    charged a flat analytic latency.

    Each bank FSM tracks the hazards the analytic per-command sum ignores:
    an ACT may only issue tRP after the bank's last PRECHARGE and tRC after
    its last ACTIVATE, and a PRECHARGE only tRAS after the row (or row
    group) activated; within an AAP the back-to-back ACTIVATE follows the
    source activation after tRAS (Ambit's command structure).  Banks of a
    rank are additionally coupled by the shared :class:`_RankState` — tRRD
    between ACTs to different banks, the four-activate tFAW window, and
    periodic tREFI/tRFC refresh windows that stall in-flight sequences —
    and each bank may start at its own issue offset (``offsets_ns``, e.g.
    the data-arrival skew of a preceding inter-bank redistribution).

    Commands issue on tCK boundaries, so each timing parameter rounds *up*
    to whole cycles; quantization, hazards, rank windows and offsets only
    ever *delay* commands, so the replayed latency is a superset of the
    analytic sum on every policy.  ``desync_policy="lockstep"`` restores
    the legacy broadcast model (one FSM replays for all banks, no
    tRRD/tFAW coupling) for A/B comparison.

    Two engines produce the same cycle-exact result.  ``"stepped"`` is
    the per-edge FSM above, the oracle.  ``"vectorized"`` (the default)
    compiles the trace's activation skeleton once
    (:meth:`~repro.core.trace.LoweredTrace.act_structure`) and solves the
    per-bank ready chains, the tRRD/tFAW rank windows and the tREFI/tRFC
    refresh grid as a monotone fixpoint over cycle arrays — cummax prefix
    scans for the chain/rank closures, a pointwise jump for refresh —
    then reconstructs the stall attribution from the converged schedule.
    The rank-level arbitration order is itself solved as an outer
    fixpoint (solve times under a candidate order, re-sort by local
    readiness, repeat until stable) and *verified* against the FSM's
    arbitration rule on the converged schedule; the few configurations
    the solver cannot prove (``tRRD=0`` with ``tFAW`` active across
    desynchronized banks, or a non-converging fixpoint) transparently
    fall back to the stepped FSM, so the engine choice is never visible
    in results.
    """

    # fixpoint-iteration headroom beyond the refresh-window estimate; the
    # solver falls back to the stepped oracle if it fails to converge
    _BASE_ITERS = 64
    # rank-coupled order resolution is sequential per refresh window, so
    # schedules crossing more windows than this are cheaper to step
    _MAX_WINDOWS = 16

    def __init__(self, timing: DRAMTiming | None = None) -> None:
        self.timing = timing or DRAMTiming()
        t = self.timing
        tck = t.tCK_ns
        self.c_ras = math.ceil(t.tRAS_ns / tck)
        self.c_rp = math.ceil(t.tRP_ns / tck)
        self.c_rc = self.c_ras + self.c_rp        # ACT→ACT, same bank
        self.c_rrd = math.ceil(t.tRRD_ns / tck) if t.tRRD_ns > 0 else 0
        self.c_faw = math.ceil(t.tFAW_ns / tck) if t.tFAW_ns > 0 else 0
        refresh_on = t.tREFI_ns > 0 and t.tRFC_ns > 0
        self.c_refi = math.ceil(t.tREFI_ns / tck) if refresh_on else 0
        self.c_rfc = math.ceil(t.tRFC_ns / tck) if refresh_on else 0
        if self.c_refi and self.c_rfc >= self.c_refi:
            raise ValueError(
                f"tRFC ({t.tRFC_ns} ns) must be shorter than tREFI "
                f"({t.tREFI_ns} ns) — the bank would never leave refresh")
        if t.desync_policy not in ("desync", "lockstep"):
            raise ValueError(f"unknown desync policy {t.desync_policy!r} "
                             "(expected 'desync' or 'lockstep')")
        if t.replay_engine not in ("vectorized", "stepped"):
            raise ValueError(f"unknown replay engine {t.replay_engine!r} "
                             "(expected 'vectorized' or 'stepped')")
        # every scalar a ReplayResult depends on besides the trace/banks/
        # offsets/phase — the timing part of the TraceCache memo key (the
        # analytic baseline uses the raw ns values, hence both forms)
        self._sig = (tck, t.tRAS_ns, t.tRP_ns, self.c_rrd, self.c_faw,
                     self.c_refi, self.c_rfc)

    def _rank(self, coupled: bool, phase: int = 0) -> _RankState:
        return _RankState(self.c_rrd if coupled else 0,
                          self.c_faw if coupled else 0,
                          self.c_refi, self.c_rfc, phase=phase)

    def replay(self, trace, banks: int = 1, offsets_ns=None,
               policy: str | None = None, refresh_phase_ns: float = 0.0,
               engine: str | None = None, cache=None) -> ReplayResult:
        """Replay ``trace`` on ``banks`` per-bank FSMs.

        ``offsets_ns`` optionally gives each bank's issue offset (bank *k*'s
        stream may not start before ``offsets_ns[k]``); ``policy`` overrides
        the timing's ``desync_policy`` for this replay.  Refresh windows are
        anchored ``refresh_phase_ns`` after the previous refresh epoch —
        with the default 0, each op replays standalone from t=0, so only
        ops that individually span a tREFI interval accrue refresh stall;
        a replay-mode :class:`~repro.core.backends.PerfStats` built with
        ``refresh_phase=True`` threads its accumulated pipeline clock
        through here instead, so refresh bites across op boundaries.

        ``engine`` overrides the timing's ``replay_engine`` for this call;
        ``cache`` optionally names a :class:`~repro.core.trace.TraceCache`
        whose replay memo serves warm replays as a table lookup, keyed by
        ``(trace.fingerprint, banks, offsets, refresh-phase bucket,
        policy, engine, timing signature)``.
        """
        policy = policy or self.timing.desync_policy
        if policy not in ("desync", "lockstep"):
            raise ValueError(f"unknown desync policy {policy!r}")
        engine = engine or self.timing.replay_engine
        if engine not in ("vectorized", "stepped"):
            raise ValueError(f"unknown replay engine {engine!r}")
        banks = max(1, int(banks))
        tck = self.timing.tCK_ns
        if trace.seqs.shape[0] == 0:
            return ReplayResult(ns=0.0, stall_ns=0.0, cycles=0, n_seqs=0,
                                n_acts=0, banks=banks)
        if offsets_ns is not None and len(offsets_ns) != banks:
            raise ValueError(f"{len(offsets_ns)} issue offsets for "
                             f"{banks} banks")
        lockstep = policy == "lockstep"
        if lockstep:
            # legacy broadcast: one FSM stands in for every bank (banks
            # mirror for free — no tRRD/tFAW coupling, offsets ignored)
            offsets = [0]
        else:
            offsets = [0] * banks if offsets_ns is None else \
                [math.ceil(o / tck) for o in offsets_ns]
        ref_phase = 0
        if self.c_refi and refresh_phase_ns:
            ref_phase = math.ceil(refresh_phase_ns / tck) % self.c_refi
        key = None
        if cache is not None:
            key = (trace.fingerprint, banks, tuple(offsets), ref_phase,
                   policy, engine, self._sig)
            hit = cache.replay_get(key)
            if hit is not None:
                return hit
        res = None
        if engine == "vectorized":
            res = self._replay_vectorized(trace, banks, offsets, lockstep,
                                          ref_phase)
        if res is None:
            res = self._replay_stepped(trace, banks, offsets, lockstep,
                                       ref_phase)
        if key is not None:
            cache.replay_put(key, res)
        return res

    # -- stepped engine: the per-edge FSM oracle -----------------------------

    def _replay_stepped(self, trace, banks: int, offsets: list,
                        lockstep: bool, ref_phase: int) -> ReplayResult:
        kinds = trace.seqs[:, 0].tolist()
        tck = self.timing.tCK_ns
        n_banks = len(offsets)
        rank = self._rank(coupled=not lockstep, phase=ref_phase)
        c_ras, c_rp, c_rc = self.c_ras, self.c_rp, self.c_rc
        n_seq = len(kinds)
        # per-bank FSM state (the bank powers up idle and precharged)
        now = list(offsets)
        last_act = [o - c_rc for o in offsets]
        last_pre = [o - c_rp for o in offsets]
        seq_i = [0] * n_banks
        phase = [0] * n_banks            # 1 = second ACT of an AAP pending
        finish = [0] * n_banks
        n_acts = 0
        pending = n_banks
        while pending:
            # next activation: the bank whose FSM is locally ready first
            best_k = -1
            best_t = 0
            for k in range(n_banks):
                if seq_i[k] >= n_seq:
                    continue
                if phase[k]:
                    t = last_act[k] + c_ras
                else:
                    t = max(now[k], last_pre[k] + c_rp, last_act[k] + c_rc)
                if best_k < 0 or t < best_t:
                    best_k, best_t = k, t
            k = best_k
            t = rank.constrain(best_t)
            rank.record(t)
            last_act[k] = t
            n_acts += 1
            if phase[k] == 0 and kinds[seq_i[k]] != SEQ_AP:
                phase[k] = 1              # AAP / Case-2: back-to-back ACT
            else:
                pre = t + c_ras           # sequence retires with a PRECHARGE
                last_pre[k] = pre
                now[k] = pre
                phase[k] = 0
                seq_i[k] += 1
                if seq_i[k] == n_seq:
                    # the final precharge must complete before the op retires
                    finish[k] = pre + c_rp
                    pending -= 1
        return self._package(trace, banks, lockstep, max(finish),
                             min(finish), n_acts * (banks if lockstep else 1),
                             rank.tfaw_stall, rank.refresh_stall,
                             rank.n_refresh_stalls)

    # -- vectorized engine: prefix-scan fixpoint over cycle arrays -----------

    def _refresh_jump(self, t: np.ndarray, ref_phase: int) -> np.ndarray:
        """Vectorized :meth:`_RankState.constrain_refresh`: every element
        inside a refresh window jumps to that window's end (the least
        stable cycle ≥ t), elements outside pass through unchanged."""
        if not self.c_refi:
            return t
        ta = t + ref_phase
        k = ta // self.c_refi
        in_win = (((k >= 1) | (ref_phase > 0))
                  & (ta < k * self.c_refi + self.c_rfc))
        return np.where(in_win, k * self.c_refi + self.c_rfc - ref_phase, t)

    def _iter_cap(self, horizon: int) -> int:
        """Fixpoint-iteration budget: each sweep resolves at least the
        earliest unresolved refresh-window crossing, so the window count
        over the (stall-inflated) schedule horizon bounds the iterations
        needed; headroom on top, and the caller falls back to the stepped
        oracle if the budget is ever exhausted."""
        if not self.c_refi:
            return self._BASE_ITERS
        slack = max(1, self.c_refi - self.c_rfc)
        return self._BASE_ITERS + 4 * (int(horizon) // slack + 1)

    def _solve_chains(self, gaps: np.ndarray, offs: np.ndarray,
                      ref_phase: int):
        """Exact schedule for rank-uncoupled streams (no tRRD/tFAW): each
        bank is an independent ready chain ``r_i = jump(r_{i-1} + g_i)``,
        solved for all banks at once by alternating the refresh jump with
        a cummax chain closure until fixpoint.  Returns ``(R, tfaw=0,
        refresh_stall, n_refresh)`` with R of shape (banks, n_acts), or
        None if the iteration budget runs out."""
        cum = np.cumsum(gaps)
        base = offs[:, None] + cum[None, :]
        R = base
        for _ in range(self._iter_cap(int(base.max()))):
            j = self._refresh_jump(R, ref_phase)
            nxt = np.maximum.accumulate(j - cum[None, :], axis=1) \
                + cum[None, :]
            if np.array_equal(nxt, R):
                break
            R = nxt
        else:
            return None
        # stall attribution: re-derive each ACT's pre-refresh candidate
        # from its predecessor and meter the jumps, as the FSM does
        cand = np.empty_like(R)
        cand[:, 0] = offs
        cand[:, 1:] = R[:, :-1] + gaps[1:][None, :]
        j = self._refresh_jump(cand, ref_phase)
        if not np.array_equal(j, R):
            return None
        return R, 0, int((j - cand).sum()), int((j > cand).sum())

    def _solve_coupled(self, gaps: np.ndarray, offs: np.ndarray,
                       ref_phase: int, rrd: int, faw: int):
        """Exact schedule for rank-coupled per-bank streams.

        The FSM arbitrates by *local* readiness: the next ACT issued is
        the head with the least per-bank ready time (ties to the lowest
        bank).  Per-bank ready times are nondecreasing along each bank's
        own stream, so that arbitration order is exactly the k-way merge
        of the per-bank ready chains — i.e. the lexicographic sort of
        ``(ready, bank, position)``.  Along a *known* issue order π the
        issue times are the least fixpoint of monotone constraints

            r_{π(n)} = jump(max(l_{π(n)}, r_{π(n-1)} + tRRD,
                                r_{π(n-4)} + tFAW))

        (``l`` the per-bank gap chain, ``jump`` the refresh deferral),
        solved by Kleene-iterating four cummax/pointwise closures: the
        tRRD chain (one prefix cummax over the permuted order), the tFAW
        chain (four strided cummaxes, one per ``n mod 4`` residue), the
        refresh jump, and the per-bank gap chains.  The order itself is
        the outer fixpoint: solve under a candidate π, re-derive the
        ready times, re-sort; when the sort reproduces π, the candidate
        provably equals the FSM's arbitration and the times are exact.
        Each round certifies at least one more position of the final
        order (the common prefix of candidate and re-sort, plus the
        divergence point itself, is already the FSM's order), so the
        loop converges — but refresh windows must be order-resolved
        front to back, so the round count scales with the number of
        windows the schedule crosses.  Refresh-dominated schedules
        (``> _MAX_WINDOWS`` windows), a non-converging fixpoint, or an
        exhausted budget return None and the caller steps the oracle —
        this path is exact-or-absent, never approximate.  Returns
        ``(R, tfaw_stall, refresh_stall, n_refresh)`` with R of shape
        (n_acts, banks)."""
        a = len(gaps)
        b = len(offs)
        n = a * b
        cum = np.cumsum(gaps)
        base = offs[None, :] + cum[:, None]                    # (a, b)
        idx = np.arange(n, dtype=np.int64)
        k_flat = idx % b
        i_flat = idx // b
        rrd_ramp = idx * rrd
        faw_ramp = np.arange((n + 3) // 4, dtype=np.int64) * faw
        # the schedule horizon includes the rank serializers: n ACTs
        # cannot issue faster than one per tRRD nor four per tFAW
        horizon = int(base.max())
        if rrd:
            horizon = max(horizon, n * rrd)
        if faw:
            horizon = max(horizon, ((n + 3) // 4) * faw)
        windows = 0
        if self.c_refi:
            windows = horizon // max(1, self.c_refi - self.c_rfc) + 1
            if windows > self._MAX_WINDOWS:
                return None
        # chain↔window alternation can propagate as slowly as a few
        # positions per sweep, so the sweep budget scales with n; a sweep
        # costs about as much as stepping two ACTs, so the worst wasted
        # attempt stays well under one stepped replay.  The budget is
        # global across order rounds — prefix freezing (below) makes the
        # total suffix work amortize to roughly one full solve.
        budget = max(self._BASE_ITERS + 4 * windows, n // 4)
        outer_cap = 32 + 4 * windows

        def local_ready(r):
            ready = np.empty_like(r)
            ready[0, :] = offs
            ready[1:, :] = r[:-1, :] + gaps[1:, None]
            return ready

        def order_of(r):
            # arbitration order: by per-bank ready time, ties to the
            # lowest bank, then stream position (same-bank "ties" are
            # just the bank's own program order)
            return np.lexsort((i_flat, k_flat,
                               local_ready(r).reshape(-1)))

        def solve(perm, r):
            # Kleene iteration from below: each sweep applies the four
            # monotone closures once; a sweep that changes nothing means
            # the least fixpoint under this order has been reached
            nonlocal budget
            while budget > 0:
                budget -= 1
                prev = r
                flat = r.reshape(-1)[perm]
                if rrd:
                    flat = np.maximum.accumulate(flat - rrd_ramp) + rrd_ramp
                if faw:
                    for rho in range(min(4, n)):
                        s = flat[rho::4]
                        ramp = faw_ramp[:len(s)]
                        flat[rho::4] = np.maximum.accumulate(s - ramp) + ramp
                flat = self._refresh_jump(flat, ref_phase)
                nxt = np.empty(n, np.int64)
                nxt[perm] = flat
                r = nxt.reshape(a, b)
                r = np.maximum.accumulate(r - cum[:, None], axis=0) \
                    + cum[:, None]
                if np.array_equal(r, prev):
                    return r
            return None

        perm = order_of(base)
        r0 = base
        for j in range(outer_cap):         # outer: the issue-order fixpoint
            r = solve(perm, r0)
            if r is None:
                return None
            nperm = order_of(r)
            neq = np.nonzero(nperm != perm)[0]
            if neq.size == 0:
                break
            # the common prefix of the candidate and the re-derived order
            # already matches the FSM's arbitration, so those issue times
            # are final: freeze them and re-solve only the suffix from
            # below under the corrected order (the divergence point
            # itself is also certified, so each round makes progress)
            d = int(neq[0])
            if j >= 8 and d * outer_cap < n * (j + 1):
                # projecting the certified-prefix growth rate to the round
                # cap falls short of n — e.g. scrambled issue offsets that
                # deviate from every candidate roughly once per bank-round
                # — so bail out before burning the whole sweep budget
                return None
            perm = nperm
            r0 = base.copy()
            r0.reshape(-1)[perm[:d]] = r.reshape(-1)[perm[:d]]
        else:
            return None
        # stall attribution along the verified issue order, mirroring the
        # FSM's metering: the tFAW deferral is measured after the tRRD
        # floor, refresh jumps are measured last.  The tFAW gate reads the
        # 4th-latest issued ACT, which is position n-4 only on a monotone
        # schedule — guaranteed by the tRRD chain, verified anyway.
        flat_r = r.reshape(-1)[perm]
        if np.any(np.diff(flat_r) < 0):
            return None
        t = local_ready(r).reshape(-1)[perm]
        if rrd:
            t[1:] = np.maximum(t[1:], flat_r[:-1] + rrd)
        tfaw_stall = 0
        if faw and n > 4:
            gate = flat_r[:-4] + faw
            tfaw_stall = int(np.maximum(gate - t[4:], 0).sum())
            t[4:] = np.maximum(t[4:], gate)
        j = self._refresh_jump(t, ref_phase)
        if not np.array_equal(j, flat_r):
            return None
        return r, tfaw_stall, int((j - t).sum()), int((j > t).sum())

    def _replay_vectorized(self, trace, banks: int, offsets: list,
                           lockstep: bool, ref_phase: int
                           ) -> ReplayResult | None:
        """Closed-form replay of ``trace``; None where only the stepped
        oracle is exact (the dispatcher falls back)."""
        codes = trace.act_structure()
        a = len(codes)
        gap_of = np.zeros(3, np.int64)
        gap_of[ACT_GAP_START] = 0
        gap_of[ACT_GAP_RAS] = self.c_ras
        gap_of[ACT_GAP_RC] = self.c_rc
        gaps = gap_of[codes]
        offs = np.asarray(offsets, np.int64)
        rrd = 0 if lockstep else self.c_rrd
        faw = 0 if lockstep else self.c_faw
        if rrd == 0 and faw == 0:
            solved = self._solve_chains(gaps, offs, ref_phase)
            if solved is None:
                return None
            R, tfaw_stall, refresh_stall, n_refresh = solved
            finish = R[:, -1] + self.c_rc
        else:
            if len(offs) > 1 and rrd == 0:
                # a four-activate window without the tRRD serializer that
                # keeps issue order monotone has no provable closed-form
                # arbitration order — stepped is forced (see README)
                return None
            solved = self._solve_coupled(gaps, offs, ref_phase, rrd, faw)
            if solved is None:
                return None
            R, tfaw_stall, refresh_stall, n_refresh = solved
            finish = R[-1, :] + self.c_rc
        n_acts = a * banks
        return self._package(trace, banks, lockstep, int(finish.max()),
                             int(finish.min()), n_acts, tfaw_stall,
                             refresh_stall, n_refresh)

    def _package(self, trace, banks: int, lockstep: bool, cycles: int,
                 min_cycles: int, n_acts: int, tfaw_stall: int,
                 refresh_stall: int, n_refresh_stalls: int) -> ReplayResult:
        tck = self.timing.tCK_ns
        ns = cycles * tck
        mix = trace.command_mix()
        analytic = (mix["AAP"] * self.timing.t_aap_ns
                    + mix["AP"] * self.timing.t_ap_ns)
        return ReplayResult(
            ns=ns, stall_ns=max(0.0, ns - analytic), cycles=cycles,
            n_seqs=trace.seqs.shape[0] * banks, n_acts=n_acts,
            banks=banks, max_bank_ns=ns, min_bank_ns=min_cycles * tck,
            tfaw_stall_ns=tfaw_stall * tck,
            refresh_stall_ns=refresh_stall * tck,
            n_refresh_stalls=n_refresh_stalls)


class SimdramPerfModel:
    """Throughput / energy for a compiled μProgram (the paper's Fig. 9/10)."""

    def __init__(self, timing: DRAMTiming | None = None,
                 energy: DRAMEnergy | None = None,
                 baseline: BaselineModel | None = None,
                 movement: MovementModel | None = None,
                 transposition: TranspositionModel | None = None,
                 replay: TraceReplayTiming | None = None) -> None:
        self.timing = timing or DRAMTiming()
        self.energy = energy or DRAMEnergy()
        self.baseline = baseline or BaselineModel()
        self.movement = movement or MovementModel()
        self.transposition = transposition or TranspositionModel()
        self.replay_timing = replay or TraceReplayTiming(self.timing)

    def replay_result(self, trace, banks: int = 1, offsets_ns=None,
                      refresh_phase_ns: float = 0.0, engine: str | None = None,
                      cache=None) -> ReplayResult:
        """Replay a lowered trace on the per-bank FSM array (measured-style
        latency, tFAW/refresh windows, optional per-bank issue offsets and
        cross-op refresh phase).  ``engine`` overrides the timing's
        ``replay_engine``; ``cache`` (a TraceCache) memoizes the closed-form
        result so warm replays are a table lookup."""
        return self.replay_timing.replay(trace, banks=banks,
                                         offsets_ns=offsets_ns,
                                         refresh_phase_ns=refresh_phase_ns,
                                         engine=engine, cache=cache)

    def replay_latency_ns(self, trace, banks: int = 1) -> float:
        return self.replay_result(trace, banks=banks).ns

    def replay_energy_nj(self, prog: UProgram, trace, banks: int = 1,
                         result: ReplayResult | None = None) -> float:
        """Replayed energy: the activation energy is fixed by the command
        mix (identical to the analytic model, × banks), but stall cycles
        still burn per-bank background/peripheral power — so replayed nJ ≥
        analytic nJ by exactly ``banks × background_w × stall_ns``.  This is
        the single source of truth for the formula:
        ``PerfStats.charge_program`` calls it (passing its memoized
        ``result``) instead of re-deriving it inline."""
        if result is None:
            result = self.replay_result(trace, banks=banks)
        return (self.energy_nj(prog)
                + self.energy.background_w * result.stall_ns) * banks

    def latency_ns(self, prog: UProgram) -> float:
        mix = prog.command_mix()
        t = self.timing
        return mix["AAP"] * t.t_aap_ns + mix["AP"] * t.t_ap_ns

    def throughput_gops(self, prog: UProgram, banks: int = 1) -> float:
        """Elements per second (×1e-9): one row of SIMD lanes per bank per
        μProgram execution; banks operate in parallel (§6)."""
        lanes = self.timing.row_bits
        return lanes * banks / self.latency_ns(prog)

    def energy_nj(self, prog: UProgram) -> float:
        mix = prog.command_mix()
        e = self.energy
        # an AAP whose source activation is a TRA pays the TRA penalty too
        extra_tra = mix["TRA"] - mix["AP"]
        return (mix["AAP"] * e.e_aap_nj() + mix["AP"] * e.e_ap_nj()
                + extra_tra * e.e_act_nj * 2 * e.tra_row_penalty)

    def power_w(self, prog: UProgram, banks: int = 1) -> float:
        return (self.energy_nj(prog) / self.latency_ns(prog)
                + self.energy.background_w) * banks

    def throughput_per_watt(self, prog: UProgram, banks: int = 1) -> float:
        return self.throughput_gops(prog, banks) / self.power_w(prog, banks)

    # -- baselines ----------------------------------------------------------
    def cpu_gops(self, op: str, n_bits: int) -> float:
        return self.baseline.throughput_gops(op, n_bits, gpu=False)

    def gpu_gops(self, op: str, n_bits: int) -> float:
        return self.baseline.throughput_gops(op, n_bits, gpu=True)

    def cpu_gops_per_watt(self, op: str, n_bits: int) -> float:
        return self.cpu_gops(op, n_bits) / self.baseline.power_w(False)

    def gpu_gops_per_watt(self, op: str, n_bits: int) -> float:
        return self.gpu_gops(op, n_bits) / self.baseline.power_w(True)
