"""DRAM timing, throughput and energy model (paper §6, §7.1, §7.2).

The paper's methodology: an operation's latency is the sum of its AAP/AP
command-sequence latencies under DDR4-2400 timing; throughput is
``SIMD lanes × banks / latency``; energy follows the Micron power model with
Ambit's observation that each additional simultaneously-activated row costs
+22% activation energy [131].

Baselines (paper Table 2): the CPU (16-core Skylake, AVX-512, 4-channel
DDR4-2400) and GPU (Titan V, HBM2) are modeled at their *memory-bandwidth
roofline* for these streaming, memory-bound kernels — the paper itself
classifies the target workloads as memory-bound, so the bandwidth roofline is
the right analytic stand-in for measured hardware we do not have.  All
constants are documented here and surfaced in benchmark output.
"""
from __future__ import annotations

import dataclasses

from ..core.uprogram import UProgram


@dataclasses.dataclass(frozen=True)
class DRAMTiming:
    """DDR4-2400 (per paper Table 2)."""
    tCK_ns: float = 0.833
    tRCD_ns: float = 14.16
    tRP_ns: float = 14.16
    tRAS_ns: float = 32.0
    row_bits: int = 8 * 1024 * 8          # 8 kB row = 65536 bitlines/SIMD lanes
    banks_per_chip: int = 16

    # command-sequence latencies (Ambit/RowClone command structure):
    #   AP  = ACTIVATE(triple) → PRECHARGE                = tRAS + tRP
    #   AAP = ACTIVATE → ACTIVATE → PRECHARGE             = 2·tRAS + tRP
    @property
    def t_ap_ns(self) -> float:
        return self.tRAS_ns + self.tRP_ns

    @property
    def t_aap_ns(self) -> float:
        return 2 * self.tRAS_ns + self.tRP_ns


@dataclasses.dataclass(frozen=True)
class DRAMEnergy:
    """Activation energy per 8 kB row (derived from the Micron TN-41-01 power
    model for DDR4-2400 x8: (IDD0−IDD3N)·tRC·VDD·devices_per_rank)."""
    e_act_nj: float = 5.8          # one full-row ACTIVATE+PRECHARGE pair
    tra_row_penalty: float = 0.22  # +22% per extra simultaneous row [131]
    background_w: float = 0.15     # per-bank background/peripheral power

    def e_ap_nj(self) -> float:
        # triple-row activation: 1 + 2·22% of a single activation
        return self.e_act_nj * (1 + 2 * self.tra_row_penalty)

    def e_aap_nj(self) -> float:
        return self.e_act_nj * 2   # two back-to-back activations


@dataclasses.dataclass(frozen=True)
class BaselineModel:
    """Memory-bandwidth-roofline models for the CPU/GPU baselines."""
    cpu_bw_gbs: float = 76.8       # 4 ch × DDR4-2400 (Table 2)
    gpu_bw_gbs: float = 652.8      # Titan V HBM2
    cpu_tdp_w: float = 165.0       # Skylake 16-core package
    gpu_tdp_w: float = 250.0       # Titan V board power
    # per-op stream profile: (input arrays, output arrays)
    streams: dict = dataclasses.field(default_factory=lambda: dict(
        default=(2, 1), relu=(1, 1), abs=(1, 1), bitcount=(1, 1),
        and_reduction=(3, 1), or_reduction=(3, 1), xor_reduction=(3, 1),
        if_else=(3, 1),
    ))

    def throughput_gops(self, op: str, n_bits: int, gpu: bool = False) -> float:
        ins, outs = self.streams.get(op, self.streams["default"])
        # computed in bits: the paper evaluates arbitrary precisions, and
        # ``n_bits // 8`` floors to 0 bytes for sub-byte elements
        bytes_per_elem = (ins + outs) * n_bits / 8
        bw = self.gpu_bw_gbs if gpu else self.cpu_bw_gbs
        return bw / bytes_per_elem

    def power_w(self, gpu: bool = False) -> float:
        return self.gpu_tdp_w if gpu else self.cpu_tdp_w


@dataclasses.dataclass(frozen=True)
class MovementModel:
    """In-DRAM data movement (paper §7.6): LISA for intra-bank inter-subarray
    row copies, RowClone PSM for inter-bank copies over the internal bus."""
    t_lisa_row_ns: float = 90.5          # LISA RBM hop (LISA paper, ~1.6 tRC)
    t_psm_row_ns: float = 8 * 1024 / 8 * 0.833  # PSM: row serialized over bus

    def intra_bank_ns(self, n_rows: int) -> float:
        return n_rows * self.t_lisa_row_ns

    def inter_bank_ns(self, n_rows: int) -> float:
        return n_rows * self.t_psm_row_ns


@dataclasses.dataclass(frozen=True)
class TranspositionModel:
    """Transposition-unit overhead (paper §5.1, §7.7): each 64 B cache line
    transposes in one 4 GHz core cycle through the transpose buffer; the
    critical path is the DRAM write of the first subarray's object slices
    (later subarrays overlap with compute)."""
    cacheline_bits: int = 512
    t_buffer_ns: float = 0.25            # 1 cycle @ 4 GHz
    dram_ch_bw_gbs: float = 19.2         # one DDR4-2400 channel

    def first_subarray_ns(self, n_bits: int, lanes: int) -> float:
        # ceiling division: a partial cache line still takes a full buffer
        # pass and a full line write (flooring reported *zero* transposition
        # cost for lanes < 512 and undercounted non-multiples)
        lines_per_plane = -(-lanes // self.cacheline_bits)
        n_lines = n_bits * lines_per_plane
        bytes_moved = n_lines * self.cacheline_bits / 8
        return n_lines * self.t_buffer_ns + bytes_moved / self.dram_ch_bw_gbs


class SimdramPerfModel:
    """Throughput / energy for a compiled μProgram (the paper's Fig. 9/10)."""

    def __init__(self, timing: DRAMTiming | None = None,
                 energy: DRAMEnergy | None = None,
                 baseline: BaselineModel | None = None,
                 movement: MovementModel | None = None,
                 transposition: TranspositionModel | None = None) -> None:
        self.timing = timing or DRAMTiming()
        self.energy = energy or DRAMEnergy()
        self.baseline = baseline or BaselineModel()
        self.movement = movement or MovementModel()
        self.transposition = transposition or TranspositionModel()

    def latency_ns(self, prog: UProgram) -> float:
        mix = prog.command_mix()
        t = self.timing
        return mix["AAP"] * t.t_aap_ns + mix["AP"] * t.t_ap_ns

    def throughput_gops(self, prog: UProgram, banks: int = 1) -> float:
        """Elements per second (×1e-9): one row of SIMD lanes per bank per
        μProgram execution; banks operate in parallel (§6)."""
        lanes = self.timing.row_bits
        return lanes * banks / self.latency_ns(prog)

    def energy_nj(self, prog: UProgram) -> float:
        mix = prog.command_mix()
        e = self.energy
        # an AAP whose source activation is a TRA pays the TRA penalty too
        extra_tra = mix["TRA"] - mix["AP"]
        return (mix["AAP"] * e.e_aap_nj() + mix["AP"] * e.e_ap_nj()
                + extra_tra * e.e_act_nj * 2 * e.tra_row_penalty)

    def power_w(self, prog: UProgram, banks: int = 1) -> float:
        return (self.energy_nj(prog) / self.latency_ns(prog)
                + self.energy.background_w) * banks

    def throughput_per_watt(self, prog: UProgram, banks: int = 1) -> float:
        return self.throughput_gops(prog, banks) / self.power_w(prog, banks)

    # -- baselines ----------------------------------------------------------
    def cpu_gops(self, op: str, n_bits: int) -> float:
        return self.baseline.throughput_gops(op, n_bits, gpu=False)

    def gpu_gops(self, op: str, n_bits: int) -> float:
        return self.baseline.throughput_gops(op, n_bits, gpu=True)

    def cpu_gops_per_watt(self, op: str, n_bits: int) -> float:
        return self.cpu_gops(op, n_bits) / self.baseline.power_w(False)

    def gpu_gops_per_watt(self, op: str, n_bits: int) -> float:
        return self.gpu_gops(op, n_bits) / self.baseline.power_w(True)
