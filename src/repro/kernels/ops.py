"""jit'd wrappers around the Pallas kernels (+ row-file plumbing).

``run_trace_kernel`` is the end-to-end Pallas path for any lowered
command trace (:class:`~repro.core.trace.LoweredTrace`): build a row file
(D rows + C rows + B cells) straight from the trace's row-index map, run
its int32 command array in the VMEM FSM kernel, read outputs back.  It is
semantically identical to ``repro.core.unrolled.run_trace_unrolled`` (the
trace-time path) and the decoded ``repro.core.executor`` run (the numpy
reference) — tests assert all three agree.  ``run_uprogram_kernel`` keeps
the μProgram-level entry point by lowering first (memoized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.trace import LoweredTrace, lower_program
from ..core.uprogram import UProgram
from .bitplane_transpose import bitplane_transpose
from .bitserial_matmul import bitserial_matmul, pack_signs
from .uprog_executor import uprog_execute

__all__ = ["bitplane_transpose", "bitserial_matmul", "pack_signs",
           "run_trace_kernel", "run_uprogram_kernel", "transpose_to_planes"]


def run_trace_kernel(trace: LoweredTrace, operands: dict[str, jax.Array],
                     out_bits: dict[str, int] | None = None,
                     interpret: bool = True) -> dict[str, jax.Array]:
    """Execute a lowered command trace via the Pallas row-file kernel.

    operands: name → uint32[n_bits, W] bit-planes.
    """
    words = next(iter(operands.values())).shape[1]
    zero = jnp.zeros((words,), jnp.uint32)
    planes: list = [zero] * trace.n_rows
    for key in trace.d_rows:
        arr, bit = key
        if arr in operands and bit < operands[arr].shape[0]:
            planes[trace.row_index[key] - 1] = operands[arr][bit]
    c1_row = trace.row_index["C1"] - 1
    planes[c1_row] = jnp.full((words,), jnp.uint32(0xFFFFFFFF))
    rows = jnp.stack(planes)
    pad = (-words) % 128
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
        rows = rows.at[c1_row, words:].set(jnp.uint32(0xFFFFFFFF))
    cmds = jnp.asarray(trace.cmds, jnp.int32)
    final = uprog_execute(cmds, rows, interpret=interpret)
    final = final[:, :words]
    out_bits = out_bits or {}
    outs = {}
    for name in trace.outputs:
        nb = out_bits.get(name, trace.n_bits)
        outs[name] = final[jnp.array(trace.out_row_ids(name, nb))]
    return outs


def run_uprogram_kernel(prog: UProgram, operands: dict[str, jax.Array],
                        out_bits: dict[str, int] | None = None,
                        interpret: bool = True) -> dict[str, jax.Array]:
    """μProgram-level entry: lower (memoized), then run the trace kernel."""
    return run_trace_kernel(lower_program(prog), operands,
                            out_bits=out_bits, interpret=interpret)


def transpose_to_planes(x: jax.Array, n_bits: int,
                        interpret: bool = True) -> jax.Array:
    """int32[E] → uint32[n_bits, E/32] via the Pallas transpose kernel.

    E must be a multiple of 32·128 (one kernel block); callers pad.
    The kernel produces 32 planes; the top 32−n_bits are dropped.
    """
    (e,) = x.shape
    groups = x.astype(jnp.uint32).reshape(e // 32, 32)
    planes = bitplane_transpose(groups, interpret=interpret)
    return planes[:n_bits]
