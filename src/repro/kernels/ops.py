"""jit'd wrappers around the Pallas kernels (+ row-file plumbing).

``run_uprogram_kernel`` is the end-to-end Pallas path for any compiled
μProgram: build a row file (D rows + C rows + B cells), encode the command
stream, execute in the VMEM kernel, read outputs back.  It is semantically
identical to ``repro.core.unrolled.run_unrolled`` (the trace-time path) and
``repro.core.executor`` (the numpy reference) — tests assert all three agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.uprogram import AAP, AP, DRow, UProgram
from .bitplane_transpose import bitplane_transpose
from .bitserial_matmul import bitserial_matmul, pack_signs
from .uprog_executor import encode_program, uprog_execute

__all__ = ["bitplane_transpose", "bitserial_matmul", "pack_signs",
           "run_uprogram_kernel", "transpose_to_planes"]


def _program_drows(prog: UProgram):
    rows = set()
    for u in prog.flatten():
        if isinstance(u, AAP):
            if isinstance(u.src, DRow):
                rows.add((u.src.array, u.src.bit))
            for d in u.dsts:
                if isinstance(d, DRow):
                    rows.add((d.array, d.bit))
    return sorted(rows)


def run_uprogram_kernel(prog: UProgram, operands: dict[str, jax.Array],
                        out_bits: dict[str, int] | None = None,
                        interpret: bool = True) -> dict[str, jax.Array]:
    """Execute a μProgram via the Pallas row-file kernel.

    operands: name → uint32[n_bits, W] bit-planes, W a multiple of 128.
    """
    words = next(iter(operands.values())).shape[1]
    drows = _program_drows(prog)
    index: dict = {}
    planes = []

    def add_row(key, data):
        index[key] = len(planes) + 1   # 1-based
        planes.append(data)

    zero = jnp.zeros((words,), jnp.uint32)
    for key in drows:
        arr, bit = key
        if arr in operands and bit < operands[arr].shape[0]:
            add_row(key, operands[arr][bit])
        else:
            add_row(key, zero)
    add_row("C0", zero)
    add_row("C1", jnp.full((words,), jnp.uint32(0xFFFFFFFF)))
    for cell in range(6):
        add_row(("cell", cell), zero)
    rows = jnp.stack(planes)
    pad = (-words) % 128
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
        rows = rows.at[index["C1"] - 1, words:].set(jnp.uint32(0xFFFFFFFF))
    cmds = encode_program(prog, index)
    final = uprog_execute(cmds, rows, interpret=interpret)
    final = final[:, :words]
    out_bits = out_bits or {}
    outs = {}
    for name in prog.outputs:
        nb = out_bits.get(name, prog.n_bits)
        sel = [index.get((name, i), index["C0"]) - 1 for i in range(nb)]
        outs[name] = final[jnp.array(sel)]
    return outs


def transpose_to_planes(x: jax.Array, n_bits: int,
                        interpret: bool = True) -> jax.Array:
    """int32[E] → uint32[n_bits, E/32] via the Pallas transpose kernel.

    E must be a multiple of 32·128 (one kernel block); callers pad.
    The kernel produces 32 planes; the top 32−n_bits are dropped.
    """
    (e,) = x.shape
    groups = x.astype(jnp.uint32).reshape(e // 32, 32)
    planes = bitplane_transpose(groups, interpret=interpret)
    return planes[:n_bits]
