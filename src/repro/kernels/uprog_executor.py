"""Pallas TPU kernel: μProgram executor over a VMEM-resident row file.

The TPU analogue of the SIMDRAM control unit's μOp Processing FSM (paper
Fig. 7): the encoded AAP/AP command stream drives a row file held in VMEM,
with the lane dimension tiled across the grid (each grid step is an
independent slice of SIMD lanes — the paper's bank/subarray parallelism).

Command encoding (int32[N, 4]):
    (op, a, b, c)
    op = 0: COPY  row|a| ← read(b)                      (AAP)
    op = 1: MAJ   rows |a|,|b|,|c| ← MAJ(read(a),read(b),read(c))   (AP)
Row operands are 1-based; a negative index reads/writes the complement
(dual-contact-cell port).  Index 0 is reserved (reads as constant 0; the
C1 row is a regular row pre-filled with ones).

The command stream lives in SMEM via PrefetchScalarGridSpec so the FSM loop
is scalar-driven while row data stays vectorized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE_BLOCK = 128
SUBLANE = 8


def _read(rows, idx):
    v = rows[jnp.abs(idx) - 1]
    return jnp.where(idx < 0, ~v, v)


def _write(rows, idx, val):
    val = jnp.where(idx < 0, ~val, val)
    return rows.at[jnp.abs(idx) - 1].set(val)


def _kernel(cmds_ref, rows_ref, out_ref, *, n_cmds: int):
    rows = rows_ref[...]

    def body(t, rows):
        op = cmds_ref[t, 0]
        a, b, c = cmds_ref[t, 1], cmds_ref[t, 2], cmds_ref[t, 3]
        va, vb, vc = _read(rows, a), _read(rows, b), _read(rows, c)
        maj = (va & vb) | (va & vc) | (vb & vc)
        is_maj = op == 1
        val_a = jnp.where(is_maj, maj, vb)
        rows = _write(rows, a, val_a)

        def maj_writes(rows):
            return _write(_write(rows, b, maj), c, maj)

        rows = jax.lax.cond(is_maj, maj_writes, lambda r: r, rows)
        return rows

    rows = jax.lax.fori_loop(0, n_cmds, body, rows)
    out_ref[...] = rows


@functools.partial(jax.jit, static_argnames=("interpret",))
def uprog_execute(cmds: jax.Array, rows: jax.Array,
                  interpret: bool = False) -> jax.Array:
    """Run an encoded command stream over a row file.

    cmds: int32[N, 4]; rows: uint32[R, W] with W a multiple of 128.
    Returns the final row file.
    """
    n_cmds = cmds.shape[0]
    r, w = rows.shape
    assert w % LANE_BLOCK == 0
    grid = (w // LANE_BLOCK,)
    return pl.pallas_call(
        functools.partial(_kernel, n_cmds=n_cmds),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((r, LANE_BLOCK), lambda i, cmds: (0, i))],
            out_specs=pl.BlockSpec((r, LANE_BLOCK), lambda i, cmds: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.uint32),
        interpret=interpret,
    )(cmds, rows)


# ---------------------------------------------------------------------------
# μProgram → command-stream encoding
# ---------------------------------------------------------------------------

def encode_program(prog, row_index: dict) -> jax.Array:
    """Encode a flattened μProgram against a row-index map.

    ``row_index`` maps RowRef keys to 1-based row numbers: ('array', bit)
    for D rows, ('cell', c) for B cells, 'C0'/'C1' for the constant rows.
    Multi-destination AAPs are split into one command per destination (same
    bitline value semantics); Case-2 fused AAPs emit MAJ + copy.

    The encoding itself is owned by the command-trace IR
    (:func:`repro.core.trace.encode_uops`); this wrapper only adapts it to
    the kernel's jnp argument.  Prefer lowering once via
    :func:`repro.core.trace.lower_program` and executing the cached
    ``LoweredTrace``.
    """
    from ..core.trace import encode_uops
    cmds, _ = encode_uops(prog.flatten(), row_index)
    return jnp.asarray(cmds, jnp.int32)
