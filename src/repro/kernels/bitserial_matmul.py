"""Pallas TPU kernel: packed bit-serial (XNOR-popcount) matmul.

The compute hot-spot of the paper's application suite (XNOR-NET VGG/LeNet,
kNN distances, BitWeaving scans): a binarized matmul where both operands are
sign-packed 32×-dense uint32 words and the inner product is
``K − 2·popcount(a ⊕ b)``.

TPU adaptation: SIMDRAM computes this with one AP per bit across 65 536
bitlines; on TPU the same vertical-layout insight packs 32 weights per word
and the VPU computes XOR+popcount at 8×128 vreg granularity, with the
(M, N) output tiled to MXU-friendly 128×128 blocks and K streamed through
VMEM.  Accumulation is int32.

Grid: (M/bm, N/bn, K/bk) with K innermost so each (i,j) output block stays
resident in VMEM across the K stream (output revisiting).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount(v: jax.Array) -> jax.Array:
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(a_ref, b_ref, o_ref, *, bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]            # (bm, bk) uint32
    b = b_ref[...]            # (bn, bk) uint32
    # mismatch popcount, contracted over the packed-K axis
    x = a[:, None, :] ^ b[None, :, :]          # (bm, bn, bk)
    o_ref[...] += _popcount(x).sum(-1)


@functools.partial(jax.jit,
                   static_argnames=("k_bits", "bm", "bn", "bk", "interpret"))
def bitserial_matmul(a_packed: jax.Array, b_packed: jax.Array, k_bits: int,
                     bm: int = 128, bn: int = 128, bk: int = 8,
                     interpret: bool = False) -> jax.Array:
    """a: uint32[M, K/32] sign-packed; b: uint32[N, K/32]; → int32[M, N]."""
    m, kw = a_packed.shape
    n, kw2 = b_packed.shape
    assert kw == kw2 and k_bits == kw * 32
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kw)
    assert m % bm == 0 and n % bn == 0 and kw % bk == 0
    mismatches = pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=(m // bm, n // bn, kw // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bn, bk), lambda i, j, k: (j, k))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_packed, b_packed)
    return k_bits - 2 * mismatches


def pack_signs(x: jax.Array) -> jax.Array:
    """float/int (..., K) → uint32 (..., K/32): bit=1 ⇔ x ≥ 0 (+1)."""
    *lead, k = x.shape
    assert k % 32 == 0
    bits = (x >= 0).astype(jnp.uint32).reshape(*lead, k // 32, 32)
    return (bits << jnp.arange(32, dtype=jnp.uint32)).sum(-1, dtype=jnp.uint32)
