"""Pallas TPU kernel: 32×32 bit-matrix transpose (the transposition unit).

This is the hardware transposition unit of paper §5.1 re-thought for TPU:
instead of a buffer between LLC and memory controller, a VMEM-resident
masked-shift network (Hacker's-Delight transpose32) converts 32-element
groups of horizontally-laid-out words into 32 bit-planes in 5 vector steps.

Layout choice (TPU-native): the 32-element axis lives on *sublanes* and the
group axis on *lanes*, so every masked shift is a sublane roll + vector
bitwise op — no lane shuffles, no gathers.  Block shape (32, 128) matches the
8×128 vreg tiling (4 vregs per block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 32          # elements per transpose group (= bits per word)
LANE_BLOCK = 128    # groups per kernel block (TPU lane width)


def _transpose32_block(a: jax.Array) -> jax.Array:
    """Bit-transpose a (32, G) uint32 block along the sublane axis.

    a[e, g] = word of element e in group g; returns p[i, g] whose lane bit e
    is bit i of a[e, g].  Masked-shift network, 5 stages.
    """
    e_idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    j = 16
    m = jnp.uint32(0x0000FFFF)
    while j:
        upper_sel = (e_idx & j) == 0
        partner_dn = pl.roll(a, -j, 0) if hasattr(pl, "roll") else jnp.roll(a, -j, 0)
        partner_up = pl.roll(a, j, 0) if hasattr(pl, "roll") else jnp.roll(a, j, 0)
        t_up = (a ^ (partner_dn >> j)) & m           # valid on upper lanes
        t_dn = ((partner_up ^ (a >> j)) & m) << j    # t computed at partner
        a = jnp.where(upper_sel, a ^ t_up, a ^ t_dn)
        j >>= 1
        m = m ^ (m << j) if j else m
    return a


def _fwd_kernel(x_ref, o_ref):
    # x_ref: (32, LANE_BLOCK) element-words (sublane e, lane g).
    # The HD network computes the mirrored transpose (out[i] bit e =
    # in[31−e] bit 31−i); reversing the sublane axis on both sides yields
    # the LSB-first transpose (out[i] bit e = in[e] bit i).
    x = jax.lax.rev(x_ref[...], (0,))
    o_ref[...] = jax.lax.rev(_transpose32_block(x), (0,))


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitplane_transpose(groups: jax.Array, interpret: bool = False) -> jax.Array:
    """uint32[G, 32] horizontal element words → uint32[32, G] bit-planes.

    G must be a multiple of 128.  out[i, g] lane-bit e = bit i of
    groups[g, e] — but note the kernel works in (32, G) orientation, so we
    feed groups.T and the result is directly (32, G).
    """
    g, e = groups.shape
    assert e == GROUP and g % LANE_BLOCK == 0, (g, e)
    x = groups.T  # (32, G): sublane = element index, lane = group
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(g // LANE_BLOCK,),
        in_specs=[pl.BlockSpec((GROUP, LANE_BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((GROUP, LANE_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((GROUP, g), jnp.uint32),
        interpret=interpret,
    )(x)
    return out
