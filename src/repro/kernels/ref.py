"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_ref(v: jax.Array) -> jax.Array:
    """SWAR popcount over uint32."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def bitplane_transpose_ref(groups: jax.Array) -> jax.Array:
    """uint32[G, 32] (element words, horizontal) → uint32[32, G] bit-planes.

    out[i, g] bit e  ==  bit i of groups[g, e].
    """
    g, e = groups.shape
    assert e == 32
    bits = (groups[:, :, None] >> jnp.arange(32, dtype=jnp.uint32)) & 1
    # bits[g, e, i] = bit i of element e in group g
    planes = (bits.astype(jnp.uint32)
              << jnp.arange(32, dtype=jnp.uint32)[None, :, None]).sum(
        axis=1, dtype=jnp.uint32)
    return planes.T  # (32, G)


def bitserial_matmul_ref(a_packed: jax.Array, b_packed: jax.Array,
                         k_bits: int) -> jax.Array:
    """XNOR-net matmul oracle over sign-packed operands.

    a_packed: uint32[M, K/32], b_packed: uint32[N, K/32]; bit=1 encodes +1,
    bit=0 encodes −1.  Returns int32[M, N] = Σ_k a_k·b_k = K − 2·popc(a⊕b).
    """
    x = a_packed[:, None, :] ^ b_packed[None, :, :]
    mismatches = popcount_ref(x).sum(-1)
    return (k_bits - 2 * mismatches).astype(jnp.int32)


def uprog_maj_ref(rows: jax.Array, cmds: jax.Array) -> jax.Array:
    """Oracle for the μProgram executor kernel.

    rows: uint32[R, W] row file.  cmds: int32[N, 4] with
      (op, a, b, c):  op=0 → copy rows[b] (xor 0x1-flagged complement) to a;
                      op=1 → rows[a],rows[b],rows[c] ← MAJ(...).
    Row operands encode complement reads in the sign bit (negative = ~row).
    """
    def rd(rows, idx):
        neg = idx < 0
        v = rows[jnp.abs(idx) - 1]
        return jnp.where(neg, ~v, v)

    def step(rows, cmd):
        op, a, b, c = cmd[0], cmd[1], cmd[2], cmd[3]
        va, vb, vc = rd(rows, a), rd(rows, b), rd(rows, c)
        maj = (va & vb) | (va & vc) | (vb & vc)
        cpy = vb

        def wr(rows, idx, val):
            neg = idx < 0
            val = jnp.where(neg, ~val, val)
            return rows.at[jnp.abs(idx) - 1].set(val)

        rows_maj = wr(wr(wr(rows, a, maj), b, maj), c, maj)
        rows_cpy = wr(rows, a, cpy)
        return jnp.where(op == 1, rows_maj, rows_cpy), None

    rows, _ = jax.lax.scan(step, rows, cmds)
    return rows
