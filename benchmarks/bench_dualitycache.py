"""Paper Fig. 12 / §7.4: SIMDRAM:16 vs DualityCache (ideal & realistic).

DualityCache constants from the paper: in-cache op energy 60.1 nJ/bit-op
units vs DRAM 13.3; a DRAM access costs 650× a DualityCache op; realistic
config must stream the 45 MB working set from DRAM through a 35 MB cache.
"""
from __future__ import annotations

from repro.core.circuits import compile_operation
from repro.simdram.timing import SimdramPerfModel

from .common import row

CACHE_BW_GBS = 2000.0        # aggregate L3 slice bandwidth (DualityCache)
DRAM_BW_GBS = 76.8
WORKING_SET_MB = 45.0
N_ELEMS = 64 * 1024 * 1024


def main() -> None:
    m = SimdramPerfModel()
    print("# Fig. 12 — SIMDRAM:16 vs DualityCache (64M 32-bit ops)")
    for op in ("addition", "subtraction", "multiplication", "division"):
        prog = compile_operation(op, 32)
        lanes = m.timing.row_bits * 16
        t_simdram = m.latency_ns(prog) * -(-N_ELEMS // lanes)
        # DualityCache ideal: bit-serial in-SRAM at cache clocks — model as
        # command count × 1ns (SRAM row ops) over 35MB-worth of lanes
        dc_lanes = 35 * 1024 * 1024 * 8 // 32
        t_dc_ideal = prog.command_count() * 1.0 * -(-N_ELEMS // dc_lanes)
        t_move = (WORKING_SET_MB * 3 / 1024) / DRAM_BW_GBS * 1e9  # in+out
        t_dc_real = t_dc_ideal + t_move * -(-N_ELEMS // dc_lanes)
        row(f"fig12/{op}", 0,
            f"simdram16={t_simdram/1e6:.2f}ms dc_ideal={t_dc_ideal/1e6:.2f}ms"
            f" dc_realistic={t_dc_real/1e6:.2f}ms "
            f"speedup_vs_realistic={t_dc_real/t_simdram:.1f}x")
    # energy (paper: SIMDRAM ≈ 600× less than DC:Realistic)
    e_dram_bit, e_cache_bit, dram_access_mult = 13.3, 60.1, 650.0
    e_simdram = e_dram_bit
    e_dc_real = e_cache_bit + dram_access_mult * e_cache_bit / 32
    row("fig12/energy_model", 0,
        f"simdram_nj_bit={e_simdram} dc_realistic_nj_bit={e_dc_real:.0f} "
        f"ratio={e_dc_real/e_simdram:.0f}x (paper: ~600x incl. DRAM loads)")


if __name__ == "__main__":
    main()
