"""Paper Fig. 14: worst-case data-transposition overhead; plus wall-time of
our Pallas transpose kernel vs the jnp reference on this host."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.circuits import compile_operation
from repro.simdram.timing import SimdramPerfModel, TranspositionModel

from .common import row, timed


def main() -> None:
    m = SimdramPerfModel()
    tr = TranspositionModel()
    print("# Fig. 14 — transposition overhead (first-subarray critical path)")
    overh = []
    for op in ("addition", "multiplication", "and_reduction", "relu"):
        for n in (8, 64):
            t_op = m.latency_ns(compile_operation(op, n))
            t_tr = tr.first_subarray_ns(n, m.timing.row_bits)
            overh.append(100 * t_tr / (t_tr + t_op))
            row(f"fig14/{op}/n{n}", 0,
                f"transpose={t_tr/1e3:.1f}us op={t_op/1e3:.1f}us "
                f"overhead={100*t_tr/(t_tr+t_op):.1f}%")
    row("fig14/avg", 0, f"overhead={np.mean(overh):.1f}% (paper: 7.1% @1bank)")

    # measured: Pallas transpose kernel vs jnp reference (host wall time)
    from repro.kernels.bitplane_transpose import bitplane_transpose
    from repro.kernels.ref import bitplane_transpose_ref
    g = jnp.asarray(np.random.default_rng(0).integers(
        0, 2**32, (2048, 32), dtype=np.uint32))
    _, us_k = timed(lambda: bitplane_transpose(g, interpret=True).block_until_ready())
    _, us_r = timed(lambda: bitplane_transpose_ref(g).block_until_ready())
    row("fig14/pallas_transpose_2048grp", us_k, f"ref_us={us_r:.0f}")


if __name__ == "__main__":
    main()
