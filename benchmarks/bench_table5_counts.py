"""Paper Table 5: AAP/AP command-sequence counts per operation × element
size, ours vs the paper's closed forms and the Ambit baseline."""
from __future__ import annotations

from repro.core.circuits import ALL_OPS, PAPER_COUNTS, compile_operation

from .common import row, timed


def main() -> None:
    print("# Table 5 — command sequences (ours / paper / ambit-naive)")
    total = {"ours": 0, "paper": 0, "ambit": 0}
    for op in ALL_OPS:
        for n in (8, 16, 32, 64):
            if op == "division" and n > 32:
                continue
            prog, us = timed(lambda: compile_operation(op, n), repeat=1)
            ours = prog.command_count()
            paper = PAPER_COUNTS[op](n)
            ambit = compile_operation(op, n, optimize=False).command_count()
            total["ours"] += ours
            total["paper"] += paper
            total["ambit"] += ambit
            row(f"table5/{op}/n{n}", us,
                f"ours={ours} paper={paper} ambit={ambit} "
                f"delta={(ours - paper) / paper:+.0%}")
    row("table5/aggregate", 0,
        f"ours={total['ours']} paper={total['paper']} ambit={total['ambit']} "
        f"ambit_ratio={total['ambit'] / total['ours']:.2f}x (paper: 2.0x)")


if __name__ == "__main__":
    main()
