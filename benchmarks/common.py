"""Shared benchmark plumbing: every bench emits `name,us_per_call,derived`
CSV rows (us_per_call = wall-time of the representative computation on this
host; derived = the paper-comparable metric)."""
from __future__ import annotations

import math
import re
import time


def row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def timed(fn, *args, repeat: int = 3):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


# modeled-throughput keys in derived columns: modeled_gops=, rowscale16_gops=,
# cpu_gops=, gops_per_w=, ...  (wall-clock melems_per_s and speedup ratios
# are deliberately not matched — only model outputs are gated)
_GOPS_ROW = re.compile(r"\b([A-Za-z0-9_]*gops[A-Za-z0-9_]*)=([^\s,]+)")


def bad_perf_values(text: str) -> list[str]:
    """Every ``*gops*=value`` occurrence that is zero or non-finite — the
    ``--smoke`` gate that turns perf-model garbage into a failing exit."""
    bad = []
    for line in text.splitlines():
        for key, val in _GOPS_ROW.findall(line):
            try:
                x = float(val.rstrip("x"))
            except ValueError:
                bad.append(f"{key}={val} (unparsable) in: {line}")
                continue
            if not math.isfinite(x) or x == 0:
                bad.append(f"{key}={val} in: {line}")
    return bad


_KV = re.compile(r"\b([A-Za-z0-9_]+)=([^\s,]+)")


def bad_gate_rows(text: str) -> list[str]:
    """The compile-cache and trace-replay ``--smoke`` gates.

    * any ``cache_hit_rate=`` must be finite and > 0 — the chained-pipeline
      benchmark must actually hit the compile/lower cache;
    * any row carrying both ``replay_ns=`` and ``analytic_ns=`` must
      satisfy finite ``replay_ns`` > 0 and ``replay_ns >= analytic_ns`` —
      cycle-accurate replay can only *add* stall cycles to the analytic
      command sum, so a smaller value means the FSM dropped work.
    """
    bad = []
    for line in text.splitlines():
        kv = dict(_KV.findall(line))

        def num(key):
            try:
                return float(kv[key].rstrip("x"))
            except ValueError:
                return None

        if "cache_hit_rate" in kv:
            r = num("cache_hit_rate")
            if r is None or not math.isfinite(r) or r <= 0:
                bad.append(f"cache_hit_rate={kv['cache_hit_rate']} "
                           f"(must be > 0) in: {line}")
        if "replay_ns" in kv and "analytic_ns" in kv:
            rep, ana = num("replay_ns"), num("analytic_ns")
            if (rep is None or ana is None or not math.isfinite(rep)
                    or not math.isfinite(ana) or rep <= 0 or ana <= 0
                    or rep < ana):
                bad.append(f"replay_ns={kv['replay_ns']} vs "
                           f"analytic_ns={kv['analytic_ns']} (both must "
                           f"be finite and non-zero, replay >= analytic) "
                           f"in: {line}")
    return bad
