"""Shared benchmark plumbing: every bench emits `name,us_per_call,derived`
CSV rows (us_per_call = wall-time of the representative computation on this
host; derived = the paper-comparable metric)."""
from __future__ import annotations

import math
import re
import time


def row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def timed(fn, *args, repeat: int = 3):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


# modeled-throughput keys in derived columns: modeled_gops=, rowscale16_gops=,
# cpu_gops=, gops_per_w=, ...  (wall-clock melems_per_s and speedup ratios
# are deliberately not matched — only model outputs are gated)
_GOPS_ROW = re.compile(r"\b([A-Za-z0-9_]*gops[A-Za-z0-9_]*)=([^\s,]+)")


def bad_perf_values(text: str) -> list[str]:
    """Every ``*gops*=value`` occurrence that is zero or non-finite — the
    ``--smoke`` gate that turns perf-model garbage into a failing exit."""
    bad = []
    for line in text.splitlines():
        for key, val in _GOPS_ROW.findall(line):
            try:
                x = float(val.rstrip("x"))
            except ValueError:
                bad.append(f"{key}={val} (unparsable) in: {line}")
                continue
            if not math.isfinite(x) or x == 0:
                bad.append(f"{key}={val} in: {line}")
    return bad


_KV = re.compile(r"\b([A-Za-z0-9_]+)=([^\s,]+)")


def bad_gate_rows(text: str) -> list[str]:
    """The compile-cache and trace-replay ``--smoke`` gates.

    * any ``cache_hit_rate=`` must be finite and > 0 — the chained-pipeline
      benchmark must actually hit the compile/lower cache;
    * ordered latency pairs must respect the modeling hierarchy — each
      replay layer can only *add* stall cycles, so a smaller value means an
      FSM dropped work: ``replay_ns >= analytic_ns`` (cycle quantization +
      hazards), ``replay_ns >= lockstep_ns`` (rank-coupled desynchronized
      streams vs the broadcast FSM), ``lockstep_ns >= analytic_ns``,
      ``refresh_on_ns >= refresh_off_ns`` (refresh windows only stall), and
      ``refresh_phased_ns >= refresh_anchored_ns`` (threading the cross-op
      refresh phase through a chain can only add stall over per-op
      anchoring), ``sched_mixed_gops >= sched_serial_gops`` (bank-level
      packing of independent requests can only raise aggregate throughput
      over the serialized single stream), and ``sched_stall_ns >=
      sched_aware_ns`` (under refresh-heavy timing, eager issue pays for
      aborted mid-sequence refreshes; pausing between sequences cannot be
      slower), ``fuse_fused_gops >= fuse_unfused_gops`` and
      ``fuse_unfused_replay_ns >= fuse_fused_replay_ns`` (fusing a chain
      into one trace removes inter-op relocations and cannot slow the
      refresh-phased replay), ``serve_batched_tokens_per_s >=
      serve_sequential_tokens_per_s`` (continuously batching concurrent
      decode sessions into the bank axis cannot lower aggregate modeled
      throughput over serving them one at a time), and ``serve_p99_ns >=
      serve_p50_ns`` (a percentile tail cannot sit below the median).
      Both members of every present pair must be finite and non-zero.
    * any ``sched_memo_hit_rate=`` must be finite and > 0 — steady-state
      continuous-batching decode repeats identical scheduler busy periods,
      so the whole-schedule memo must actually hit;
    * any ``fuse_elided_hops=`` must be > 0 — the fused chain must
      actually elide inter-op movement, not just concatenate traces.
    * the vectorized replay engine gates: ``vector_parity_delta_ns=`` must
      be exactly zero (the closed form is exact-or-absent — any non-zero
      delta means it disagreed with the stepped FSM oracle instead of
      declining), and ``vector_speedup=`` must be finite and >= 100 (the
      memoized warm replay path must actually short-circuit the per-edge
      stepping).
    """
    # (slower_key, faster_key, why) — slower >= faster, both finite > 0
    orderings = (
        ("replay_ns", "analytic_ns", "replay can only add stalls"),
        ("replay_ns", "lockstep_ns", "desync can only add stalls"),
        ("lockstep_ns", "analytic_ns", "lockstep replay can only add stalls"),
        ("refresh_on_ns", "refresh_off_ns", "refresh can only add stalls"),
        ("refresh_phased_ns", "refresh_anchored_ns",
         "threading the refresh phase across ops can only add stalls"),
        ("sched_mixed_gops", "sched_serial_gops",
         "bank-level packing can only raise aggregate throughput"),
        ("sched_stall_ns", "sched_aware_ns",
         "refresh-aware pausing avoids aborted sequences"),
        ("lint_cold_us", "lint_warm_us",
         "the memoized re-lint on cache hits must be cheaper than the "
         "first full liveness pass"),
        ("fuse_fused_gops", "fuse_unfused_gops",
         "fusing a chain into one trace elides inter-op relocations, so "
         "the fused modeled rate cannot be lower"),
        ("fuse_unfused_replay_ns", "fuse_fused_replay_ns",
         "the fused trace replays the same refresh-phased command stream "
         "in one pass, so it cannot be slower"),
        ("serve_batched_tokens_per_s", "serve_sequential_tokens_per_s",
         "continuous batching packs concurrent decode sessions into the "
         "bank axis, so aggregate tokens/s cannot fall below serving the "
         "same sessions one at a time"),
        ("serve_p99_ns", "serve_p50_ns",
         "the p99 token latency cannot sit below the median"),
    )
    bad = []
    for line in text.splitlines():
        kv = dict(_KV.findall(line))

        def num(key):
            try:
                return float(kv[key].rstrip("x"))
            except ValueError:
                return None

        if "cache_hit_rate" in kv:
            r = num("cache_hit_rate")
            if r is None or not math.isfinite(r) or r <= 0:
                bad.append(f"cache_hit_rate={kv['cache_hit_rate']} "
                           f"(must be > 0) in: {line}")
        if "sched_memo_hit_rate" in kv:
            r = num("sched_memo_hit_rate")
            if r is None or not math.isfinite(r) or r <= 0:
                bad.append(f"sched_memo_hit_rate="
                           f"{kv['sched_memo_hit_rate']} (steady-state "
                           f"decode must hit the whole-schedule memo) "
                           f"in: {line}")
        if "fuse_elided_hops" in kv:
            h = num("fuse_elided_hops")
            if h is None or not math.isfinite(h) or h <= 0:
                bad.append(f"fuse_elided_hops={kv['fuse_elided_hops']} "
                           f"(fusion must elide at least one inter-op "
                           f"hop) in: {line}")
        if "vector_parity_delta_ns" in kv:
            d = num("vector_parity_delta_ns")
            if d is None or not math.isfinite(d) or d != 0:
                bad.append(f"vector_parity_delta_ns="
                           f"{kv['vector_parity_delta_ns']} (vectorized "
                           f"replay must match the stepped FSM exactly "
                           f"or decline) in: {line}")
        if "vector_speedup" in kv:
            s = num("vector_speedup")
            if s is None or not math.isfinite(s) or s < 100:
                bad.append(f"vector_speedup={kv['vector_speedup']} (warm "
                           f"memoized vectorized replay must be >= 100x "
                           f"the stepped FSM) in: {line}")
        for slow_key, fast_key, why in orderings:
            if slow_key not in kv or fast_key not in kv:
                continue
            slow, fast = num(slow_key), num(fast_key)
            if (slow is None or fast is None or not math.isfinite(slow)
                    or not math.isfinite(fast) or slow <= 0 or fast <= 0
                    or slow < fast):
                bad.append(f"{slow_key}={kv[slow_key]} vs "
                           f"{fast_key}={kv[fast_key]} (both must be "
                           f"finite and non-zero, {slow_key} >= "
                           f"{fast_key}: {why}) in: {line}")
    return bad
