"""Shared benchmark plumbing: every bench emits `name,us_per_call,derived`
CSV rows (us_per_call = wall-time of the representative computation on this
host; derived = the paper-comparable metric)."""
from __future__ import annotations

import time


def row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def timed(fn, *args, repeat: int = 3):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us
