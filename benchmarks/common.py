"""Shared benchmark plumbing: every bench emits `name,us_per_call,derived`
CSV rows (us_per_call = wall-time of the representative computation on this
host; derived = the paper-comparable metric)."""
from __future__ import annotations

import math
import re
import time


def row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def timed(fn, *args, repeat: int = 3):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


# modeled-throughput keys in derived columns: modeled_gops=, rowscale16_gops=,
# cpu_gops=, gops_per_w=, ...  (wall-clock melems_per_s and speedup ratios
# are deliberately not matched — only model outputs are gated)
_GOPS_ROW = re.compile(r"\b([A-Za-z0-9_]*gops[A-Za-z0-9_]*)=([^\s,]+)")


def bad_perf_values(text: str) -> list[str]:
    """Every ``*gops*=value`` occurrence that is zero or non-finite — the
    ``--smoke`` gate that turns perf-model garbage into a failing exit."""
    bad = []
    for line in text.splitlines():
        for key, val in _GOPS_ROW.findall(line):
            try:
                x = float(val.rstrip("x"))
            except ValueError:
                bad.append(f"{key}={val} (unparsable) in: {line}")
                continue
            if not math.isfinite(x) or x == 0:
                bad.append(f"{key}={val} in: {line}")
    return bad
