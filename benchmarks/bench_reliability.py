"""Paper Table 3: TRA / TRAb2b / QRA failure rates under process variation
across 45/32/22 nm (Monte-Carlo charge-sharing model)."""
from __future__ import annotations

from repro.simdram.reliability import reliability_table

from .common import row, timed

PAPER = {  # Table 3 reference values (%)
    ("45nm", 0.10, "TRA"): 0.02, ("45nm", 0.20, "TRA"): 3.01,
    ("32nm", 0.10, "TRA"): 0.35, ("32nm", 0.20, "TRA"): 3.90,
    ("22nm", 0.10, "TRA"): 0.42, ("22nm", 0.20, "TRA"): 4.50,
}


def main() -> None:
    print("# Table 3 — multi-row-activation failure rates (%)")
    table, us = timed(lambda: reliability_table(iters=10_000), repeat=1)
    for node, rows in table.items():
        for var, vals in rows.items():
            def fmt(v):
                return v if isinstance(v, str) else f"{100 * v:.2f}"
            ref = PAPER.get((node, var, "TRA"))
            row(f"table3/{node}/var{int(var * 100)}", us / 12,
                f"TRA={fmt(vals['TRA'])} TRAb2b={fmt(vals['TRAb2b'])} "
                f"QRA={fmt(vals['QRA'])}"
                + (f" paperTRA={ref}" if ref is not None else ""))


if __name__ == "__main__":
    main()
