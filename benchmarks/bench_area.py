"""Paper §7.8: area model — control unit + transposition unit."""
from __future__ import annotations

from .common import row

XEON_E5_2697_MM2 = 456.0     # die area reference used by the paper


def main() -> None:
    print("# §7.8 — area overhead model")
    bbop_fifo_kb, uprog_scratch_kb, uop_mem_b = 2, 2, 128
    ctrl_mm2 = 0.04           # CACTI estimate at 22nm (paper)
    transp_mm2 = 0.06         # object tracker 8kB + 2×4kB transpose buffers
    total = ctrl_mm2 + transp_mm2
    row("area/control_unit", 0,
        f"mm2={ctrl_mm2} (bbop_fifo={bbop_fifo_kb}kB "
        f"scratchpad={uprog_scratch_kb}kB uop_mem={uop_mem_b}B)")
    row("area/transposition_unit", 0, f"mm2={transp_mm2}")
    row("area/total", 0,
        f"mm2={total} cpu_fraction={100 * total / XEON_E5_2697_MM2:.2f}% "
        f"(paper: 0.2%)")
    # μProgram sizes actually fit the paper's 128 B budget?
    from repro.core.circuits import ALL_OPS, compile_operation
    worst = 0
    for op in ALL_OPS:
        prog = compile_operation(op, 8)
        size = 2 * (len(prog.prologue) + len(prog.body) + len(prog.epilogue)
                    + 4)   # 2B per μOp + loop control
        worst = max(worst, size)
    row("area/uprogram_worst_bytes", 0,
        f"bytes={worst} (paper budget: 128 B for the loopable ops)")


if __name__ == "__main__":
    main()
