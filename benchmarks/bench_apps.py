"""Paper Fig. 11: seven real-world kernels on the SIMDRAM substrate.

Each kernel runs *functionally* at reduced scale through the bbop engine
(correctness asserted against numpy), and its *full-scale* latency is
derived from the compiled μProgram command counts with the DDR4 timing model
— the paper's own methodology (command counts × timing).  CPU baseline:
memory-bandwidth roofline over the kernel's stream footprint.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.circuits import compile_operation
from repro.ops import (bbop_add, bbop_bitcount, bbop_greater,
                       bbop_greater_equal, bbop_if_else, bbop_mul, bbop_sub)
from repro.simdram.timing import SimdramPerfModel

from .common import row

RNG = np.random.default_rng(11)
M = SimdramPerfModel()


@dataclasses.dataclass
class Kernel:
    name: str
    ops: list          # (op_name, n_bits, calls) per element
    streams: tuple     # (in_arrays, out_arrays) of n_bits elements
    n_bits: int = 8


def kernel_latency_ns(k: Kernel, n_elements: int, banks: int = 16) -> float:
    lanes = M.timing.row_bits * banks
    chunks = -(-n_elements // lanes)
    total = 0.0
    for op, n, calls in k.ops:
        total += M.latency_ns(compile_operation(op, n)) * calls * chunks
    return total


def cpu_latency_ns(k: Kernel, n_elements: int) -> float:
    ins, outs = k.streams
    byts = n_elements * (ins + outs) * (k.n_bits // 8)
    return byts / M.baseline.cpu_bw_gbs


# -- functional validations (reduced scale) ----------------------------------

def xnor_conv_layer():
    """XNOR-NET conv as binary dot products: popcount(xnor) (VGG/LeNet)."""
    x = jnp.array(RNG.integers(0, 256, 256), jnp.int32)
    w = jnp.array(RNG.integers(0, 256, 256), jnp.int32)
    xn = 255 - (np.asarray(x) ^ np.asarray(w))            # XNOR
    exp = np.array([bin(v).count("1") for v in xn.tolist()])
    got = bbop_bitcount(jnp.array(255 - np.asarray(x ^ w)), 8)
    assert np.array_equal(np.asarray(got), exp)


def knn_distance():
    """kNN: |a-b| accumulate (8-bit quantized MNIST per the paper)."""
    a = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    b = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    d1 = bbop_sub(a, b, 8)
    d2 = bbop_sub(b, a, 8)
    sel = bbop_greater(a, b, 8)
    dist = bbop_if_else(sel, d1, d2, 8)
    exp = np.abs(np.asarray(a) - np.asarray(b))
    assert np.array_equal(np.asarray(dist), exp)


def tpch_q1():
    """TPC-H Q1 core: qty*price accumulation under a date filter."""
    qty = jnp.array(RNG.integers(0, 11, 128), jnp.int32)
    price = jnp.array(RNG.integers(0, 18, 128), jnp.int32)
    date = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    mask = bbop_greater_equal(jnp.full((128,), 90, jnp.int32), date, 8)
    rev = bbop_mul(qty, price, 8)
    sel = bbop_if_else(mask, rev, jnp.zeros((128,), jnp.int32), 8)
    exp = np.where(np.asarray(date) <= 90,
                   (np.asarray(qty) * np.asarray(price)) & 255, 0)
    assert np.array_equal(np.asarray(sel), exp)


def bitweaving_scan():
    """BitWeaving: predicate scan c1 <= v <= c2 (paper §D)."""
    v = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    lo = jnp.full((128,), 50, jnp.int32)
    hi = jnp.full((128,), 180, jnp.int32)
    ge = bbop_greater_equal(v, lo, 8)
    le = bbop_greater_equal(hi, v, 8)
    both = np.asarray(ge) & np.asarray(le)
    exp = ((np.asarray(v) >= 50) & (np.asarray(v) <= 180)).astype(int)
    assert np.array_equal(both, exp)


def brightness():
    """Brightness (paper §D): x+b clamped to [0,255] via predication."""
    x = jnp.array(RNG.integers(0, 256, 128), jnp.int32)
    b = 40
    raw = bbop_add(x, jnp.full((128,), b, jnp.int32), 8)
    # overflow ⇔ raw < x (mod-256 wraparound)
    ovf = bbop_greater(x, raw, 8)
    out = bbop_if_else(ovf, jnp.full((128,), 255, jnp.int32), raw, 8)
    exp = np.minimum(np.asarray(x) + b, 255)
    assert np.array_equal(np.asarray(out), exp)


KERNELS = {
    "vgg13-xnor": Kernel("vgg13-xnor",
                         [("xor_reduction", 8, 64), ("bitcount", 8, 64),
                          ("addition", 16, 64)], (2, 1)),
    "vgg16-xnor": Kernel("vgg16-xnor",
                         [("xor_reduction", 8, 80), ("bitcount", 8, 80),
                          ("addition", 16, 80)], (2, 1)),
    "lenet-xnor": Kernel("lenet-xnor",
                         [("xor_reduction", 8, 8), ("bitcount", 8, 8),
                          ("addition", 16, 8)], (2, 1)),
    "knn": Kernel("knn", [("subtraction", 8, 2), ("greater", 8, 1),
                          ("if_else", 8, 1), ("addition", 16, 1)], (2, 1)),
    "tpch-q1": Kernel("tpch-q1", [("multiplication", 8, 1),
                                  ("greater_equal", 8, 1),
                                  ("if_else", 8, 1), ("addition", 16, 4)],
                      (3, 1)),
    "bitweaving": Kernel("bitweaving", [("greater_equal", 8, 2),
                                        ("and_reduction", 8, 1)], (1, 1)),
    "brightness": Kernel("brightness", [("addition", 8, 1), ("greater", 8, 1),
                                        ("if_else", 8, 1)], (1, 1)),
}

VALIDATE = {"vgg13-xnor": xnor_conv_layer, "vgg16-xnor": xnor_conv_layer,
            "lenet-xnor": xnor_conv_layer, "knn": knn_distance,
            "tpch-q1": tpch_q1, "bitweaving": bitweaving_scan,
            "brightness": brightness}


def main() -> None:
    print("# Fig. 11 — real-world kernels (functional @reduced, latency "
          "@64M elements)")
    n = 64 * 1024 * 1024
    speedups = []
    for name, k in KERNELS.items():
        VALIDATE[name]()
        t16 = kernel_latency_ns(k, n, banks=16)
        t1 = kernel_latency_ns(k, n, banks=1)
        tc = cpu_latency_ns(k, n)
        speedups.append(tc / t16)
        row(f"fig11/{name}", 0,
            f"functional=OK simdram16={t16/1e6:.2f}ms simdram1={t1/1e6:.1f}ms"
            f" cpu={tc/1e6:.2f}ms speedup16={tc/t16:.1f}x")
    row("fig11/avg", 0,
        f"speedup16_vs_cpu={np.mean(speedups):.1f}x (paper: 21x)")


if __name__ == "__main__":
    main()
