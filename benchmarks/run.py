# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper table/figure (+ the roofline
table from the multi-pod dry-run artifacts).

    PYTHONPATH=src python -m benchmarks.run [--only fig9,table3,...]

``--smoke`` additionally *gates* on the modeled rows: any ``*gops*=``
value that is non-finite or zero, a ``cache_hit_rate=`` that is not
positive (the chained-pipeline benchmark must hit the compile/lower
cache), or any violated replay ordering — ``replay_ns >= lockstep_ns >=
analytic_ns`` (desynchronized per-bank streams, the lockstep broadcast
FSM and the analytic command sum can each only add stall cycles over the
next) and ``refresh_on_ns >= refresh_off_ns`` — fails the run with a
non-zero exit, so the nightly job catches perf-model regressions instead
of printing garbage.

``--artifact PATH`` writes the parsed results (every ``name,us,derived``
row with its key=value pairs decoded, per-bench pass/fail, and the gate
diagnostics) as one JSON document — the persisted benchmark artifact the
nightly job uploads, so runs are diffable without re-parsing CSV text.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import re
import sys
import traceback

from . import (bench_apps, bench_area, bench_data_movement,
               bench_dualitycache, bench_energy, bench_reliability,
               bench_roofline, bench_table5_counts, bench_throughput,
               bench_transposition)
from .common import _KV, bad_gate_rows, bad_perf_values

BENCHES = {
    "table5": bench_table5_counts.main,      # Table 5  command counts
    "fig9": bench_throughput.main,           # Fig. 9   throughput
    "fig10": bench_energy.main,              # Fig. 10  energy efficiency
    "fig11": bench_apps.main,                # Fig. 11  real-world kernels
    "fig12": bench_dualitycache.main,        # Fig. 12  DualityCache
    "table3": bench_reliability.main,        # Table 3  reliability
    "fig13": bench_data_movement.main,       # Fig. 13  data movement
    "fig14": bench_transposition.main,       # Fig. 14  transposition
    "area": bench_area.main,                 # §7.8     area
    "roofline": bench_roofline.main,         # §Roofline (ours)
}


# fast subset run nightly by CI before the full suite; each main() that
# accepts ``smoke=True`` shrinks its problem sizes
SMOKE = ("table5", "fig9", "fig14")

_ROW = re.compile(r"^([A-Za-z0-9_/.\-]+),(-?[\d.]+),(.*)$")


def parse_rows(text: str) -> list[dict]:
    """Decode the ``name,us_per_call,derived`` CSV rows a bench printed
    into JSON-ready dicts (derived key=value pairs parsed to floats where
    they are numeric; trailing ``x`` ratio suffixes are kept as strings)."""
    rows = []
    for line in text.splitlines():
        m = _ROW.match(line.strip())
        if not m:
            continue
        name, us, derived = m.groups()
        kv: dict[str, object] = {}
        for key, val in _KV.findall(derived):
            try:
                kv[key] = float(val)
            except ValueError:
                kv[key] = val
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": kv})
    return rows


class _Tee(io.TextIOBase):
    def __init__(self, *streams):
        self.streams = streams

    def write(self, s):
        for st in self.streams:
            st.write(s)
        return len(s)

    def flush(self):
        for st in self.streams:
            st.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset with reduced problem sizes; gates "
                         "on finite, non-zero modeled-throughput rows")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="write parsed results (rows, gate diagnostics, "
                         "per-bench status) to PATH as JSON")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only
             else list(SMOKE) if args.smoke else list(BENCHES))
    capture = args.smoke or args.artifact is not None
    failed = []
    benches: dict[str, dict] = {}
    for name in names:
        print(f"\n==== {name} ====", flush=True)
        captured = io.StringIO()
        sink = _Tee(sys.stdout, captured) if capture else sys.stdout
        record = benches[name] = {"ok": True, "rows": [], "gate_errors": []}
        try:
            import inspect
            fn = BENCHES[name]
            with contextlib.redirect_stdout(sink):
                if args.smoke and "smoke" in inspect.signature(fn).parameters:
                    fn(smoke=True)
                else:
                    fn()
        except Exception:    # noqa: BLE001 — report and continue
            traceback.print_exc()
            record["ok"] = False
            failed.append(name)
            continue
        finally:
            record["rows"] = parse_rows(captured.getvalue())
        if args.smoke:
            text = captured.getvalue()
            bad = bad_perf_values(text) + bad_gate_rows(text)
            if bad:
                print(f"{name}: bad modeled-throughput / cache / "
                      f"replay rows:", file=sys.stderr)
                for b in bad:
                    print(f"  {b}", file=sys.stderr)
                record["ok"] = False
                record["gate_errors"] = bad
                failed.append(name)
    if args.artifact:
        payload = {"argv": sys.argv[1:], "smoke": args.smoke,
                   "failed": failed, "benches": benches}
        with open(args.artifact, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"\nwrote benchmark artifact: {args.artifact}")
    if failed:
        print(f"\nFAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)
    print("\nall benches complete")


if __name__ == "__main__":
    main()
