# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper table/figure (+ the roofline
table from the multi-pod dry-run artifacts).

    PYTHONPATH=src python -m benchmarks.run [--only fig9,table3,...]

``--smoke`` additionally *gates* on the modeled rows: any ``*gops*=``
value that is non-finite or zero, a ``cache_hit_rate=`` that is not
positive (the chained-pipeline benchmark must hit the compile/lower
cache), or any violated replay ordering — ``replay_ns >= lockstep_ns >=
analytic_ns`` (desynchronized per-bank streams, the lockstep broadcast
FSM and the analytic command sum can each only add stall cycles over the
next) and ``refresh_on_ns >= refresh_off_ns`` — fails the run with a
non-zero exit, so the nightly job catches perf-model regressions instead
of printing garbage.

``--artifact PATH`` writes the parsed results (every ``name,us,derived``
row with its key=value pairs decoded, per-bench pass/fail, and the gate
diagnostics) as one JSON document — the persisted benchmark artifact the
nightly job uploads, so runs are diffable without re-parsing CSV text.

``--compare BASELINE.json`` diffs this run against a pinned artifact
(``benchmarks/artifacts/BENCH_*.json``) and exits non-zero on regressions:
deterministic modeled keys (``*gops*``, ``hit_rate``) past the percentage
tolerance (default 5%), and warm-path wall keys (``*speedup*`` higher-
better, ``*warm_us`` lower-better) past a wide multiplicative guard that
absorbs shared-runner noise but still fires when a memo path stops
short-circuiting.  Rows or keys present on only one side are skipped — the
comparison gates drift on the surface both runs share, it does not freeze
the row set.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import math
import re
import sys
import traceback

from . import (bench_apps, bench_area, bench_data_movement,
               bench_dualitycache, bench_energy, bench_reliability,
               bench_roofline, bench_serving, bench_table5_counts,
               bench_throughput, bench_transposition)
from .common import _KV, bad_gate_rows, bad_perf_values

BENCHES = {
    "table5": bench_table5_counts.main,      # Table 5  command counts
    "fig9": bench_throughput.main,           # Fig. 9   throughput
    "fig10": bench_energy.main,              # Fig. 10  energy efficiency
    "fig11": bench_apps.main,                # Fig. 11  real-world kernels
    "fig12": bench_dualitycache.main,        # Fig. 12  DualityCache
    "table3": bench_reliability.main,        # Table 3  reliability
    "fig13": bench_data_movement.main,       # Fig. 13  data movement
    "fig14": bench_transposition.main,       # Fig. 14  transposition
    "area": bench_area.main,                 # §7.8     area
    "roofline": bench_roofline.main,         # §Roofline (ours)
    "serving": bench_serving.main,           # §Serving (ours)
}


# fast subset run nightly by CI before the full suite; each main() that
# accepts ``smoke=True`` shrinks its problem sizes
SMOKE = ("table5", "fig9", "fig14", "serving")

_ROW = re.compile(r"^([A-Za-z0-9_/.\-]+),(-?[\d.]+),(.*)$")


def parse_rows(text: str) -> list[dict]:
    """Decode the ``name,us_per_call,derived`` CSV rows a bench printed
    into JSON-ready dicts (derived key=value pairs parsed to floats where
    they are numeric; trailing ``x`` ratio suffixes are kept as strings)."""
    rows = []
    for line in text.splitlines():
        m = _ROW.match(line.strip())
        if not m:
            continue
        name, us, derived = m.groups()
        kv: dict[str, object] = {}
        for key, val in _KV.findall(derived):
            try:
                kv[key] = float(val)
            except ValueError:
                kv[key] = val
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": kv})
    return rows


# --compare key classes.  Modeled rates (deterministic functions of the
# code, bit-identical across runs) are held to the tight percentage
# tolerance; memoized warm-path wall keys (speedups and *warm_us) are real
# clocks that swing severalfold run-to-run on a shared host, so they get a
# wide multiplicative guard instead — still a hard non-zero exit when a
# memo path stops short-circuiting (those regress by orders of magnitude,
# e.g. a dead replay memo drops vector_speedup from ~1000x to ~1x).  Raw
# cold/one-shot wall clocks (us_per_call, stepped_us, cold_us, ...) are
# deliberately not matched — they measure the host, not the model or the
# memo hot path.
_MODEL_HIGHER = re.compile(r"gops|hit_rate")
_WALL_HIGHER = re.compile(r"speedup")
_WALL_LOWER = re.compile(r"warm_us$")


def _artifact_rows(payload: dict) -> dict[str, dict]:
    """Flatten an artifact's benches to ``{row_name: derived_kv}``."""
    rows: dict[str, dict] = {}
    for bench in payload.get("benches", {}).values():
        for r in bench.get("rows", []):
            rows[r["name"]] = r.get("derived", {})
    return rows


def compare_artifacts(baseline: dict, current: dict,
                      tolerance: float = 0.05,
                      wall_factor: float = 4.0) -> list[str]:
    """Regressions of ``current`` vs ``baseline``.

    Only rows and derived keys present in *both* artifacts are compared.
    Deterministic modeled keys (throughput, hit rates) fail when the new
    value falls below ``old / (1 + tolerance)``; noisy warm-path wall keys
    fail only past ``wall_factor`` (speedups that collapse below
    ``old / wall_factor``, warm timings that blow past
    ``old * wall_factor``)."""
    base_rows = _artifact_rows(baseline)
    regressions = []

    def as_float(v):
        if isinstance(v, str):
            try:
                v = float(v.rstrip("x"))
            except ValueError:
                return None
        return float(v)

    for name, cur_kv in sorted(_artifact_rows(current).items()):
        old_kv = base_rows.get(name)
        if old_kv is None:
            continue
        for key, cur_raw in cur_kv.items():
            if key not in old_kv:
                continue
            old, cur = as_float(old_kv[key]), as_float(cur_raw)
            if (old is None or cur is None or not math.isfinite(old)
                    or not math.isfinite(cur) or old <= 0):
                continue
            if _MODEL_HIGHER.search(key) and not _WALL_HIGHER.search(key):
                if cur < old / (1 + tolerance):
                    regressions.append(
                        f"{name}: {key} fell {old:g} -> {cur:g} "
                        f"({cur / old - 1:+.1%}, tolerance {tolerance:.0%})")
            elif _WALL_HIGHER.search(key):
                if cur < old / wall_factor:
                    regressions.append(
                        f"{name}: {key} collapsed {old:g} -> {cur:g} "
                        f"(past the {wall_factor:g}x wall-clock guard)")
            elif _WALL_LOWER.search(key):
                if cur > old * wall_factor:
                    regressions.append(
                        f"{name}: {key} blew up {old:g} -> {cur:g} "
                        f"(past the {wall_factor:g}x wall-clock guard)")
    return regressions


class _Tee(io.TextIOBase):
    def __init__(self, *streams):
        self.streams = streams

    def write(self, s):
        for st in self.streams:
            st.write(s)
        return len(s)

    def flush(self):
        for st in self.streams:
            st.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset with reduced problem sizes; gates "
                         "on finite, non-zero modeled-throughput rows")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="write parsed results (rows, gate diagnostics, "
                         "per-bench status) to PATH as JSON")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="diff this run against a pinned benchmark artifact "
                         "and exit non-zero on warm-path regressions beyond "
                         "--tolerance")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="relative regression tolerance for deterministic "
                         "modeled keys under --compare (default 0.05 = 5%%)")
    ap.add_argument("--wall-factor", type=float, default=4.0,
                    help="multiplicative guard for noisy warm-path wall "
                         "keys under --compare (default 4.0)")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only
             else list(SMOKE) if args.smoke else list(BENCHES))
    capture = (args.smoke or args.artifact is not None
               or args.compare is not None)
    failed = []
    benches: dict[str, dict] = {}
    for name in names:
        print(f"\n==== {name} ====", flush=True)
        captured = io.StringIO()
        sink = _Tee(sys.stdout, captured) if capture else sys.stdout
        record = benches[name] = {"ok": True, "rows": [], "gate_errors": []}
        try:
            import inspect
            fn = BENCHES[name]
            with contextlib.redirect_stdout(sink):
                if args.smoke and "smoke" in inspect.signature(fn).parameters:
                    fn(smoke=True)
                else:
                    fn()
        except Exception:    # noqa: BLE001 — report and continue
            traceback.print_exc()
            record["ok"] = False
            failed.append(name)
            continue
        finally:
            record["rows"] = parse_rows(captured.getvalue())
        if args.smoke:
            text = captured.getvalue()
            bad = bad_perf_values(text) + bad_gate_rows(text)
            if bad:
                print(f"{name}: bad modeled-throughput / cache / "
                      f"replay rows:", file=sys.stderr)
                for b in bad:
                    print(f"  {b}", file=sys.stderr)
                record["ok"] = False
                record["gate_errors"] = bad
                failed.append(name)
    payload = {"argv": sys.argv[1:], "smoke": args.smoke,
               "failed": failed, "benches": benches}
    if args.artifact:
        with open(args.artifact, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"\nwrote benchmark artifact: {args.artifact}")
    if args.compare:
        with open(args.compare) as fh:
            baseline = json.load(fh)
        regressions = compare_artifacts(baseline, payload,
                                        tolerance=args.tolerance,
                                        wall_factor=args.wall_factor)
        if regressions:
            print(f"\nREGRESSIONS vs {args.compare}:", file=sys.stderr)
            for r in regressions:
                print(f"  {r}", file=sys.stderr)
            failed.append(f"compare:{args.compare}")
        else:
            print(f"\nno regressions vs {args.compare} "
                  f"(tolerance {args.tolerance:.0%})")
    if failed:
        print(f"\nFAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)
    print("\nall benches complete")


if __name__ == "__main__":
    main()
