"""Paper Fig. 9: throughput of the 16 operations — SIMDRAM:1/4/16 vs the
CPU/GPU bandwidth-roofline baselines and the Ambit baseline — plus the
*measured* section: wall-clock of the executable backends (unrolled /
pallas-interpret / reference oracle), fused plane-resident pipelines vs the
per-op transpose round-trip, and the multi-bank batch axis.

The ``fig9live``/``fig10live`` rows come from the *live* timed pipeline
(PerfStats charged by the executed chain), not a detached model pass:
``modeled_gops`` is the effective rate of the lanes actually engaged
including transposition/movement overhead; ``rowscale16_gops`` rescales the
same charged command stream to a full 8 kB row × 16 banks for the
paper-comparable Fig. 9/10 speedup and efficiency columns.

Four gated sections ride along under ``--smoke``:

* ``cache/…`` — compile/lower-cache hot-path speedup of an 8-op chained
  pipeline (cold synthesis+allocation+lowering vs warm cache fetch) with
  the hit/miss counters; the gate requires ``cache_hit_rate > 0``.
* ``replay/…`` — cycle-accurate trace-replay latency vs the analytic
  command-sum for every Table-5 op, three ways per row: the full
  desynchronized per-bank model (tRRD/tFAW/refresh, ``replay_ns``), the
  legacy lockstep broadcast FSM with refresh off (``lockstep_ns``), and
  the analytic sum (``analytic_ns``); a refresh on-vs-off A/B row
  (``refresh_on_ns``/``refresh_off_ns``); and replay-mode pipelines
  (unbanked and banked) reporting replayed vs analytic ns/nJ side by
  side.  The gates require ``replay_ns ≥ lockstep_ns ≥ analytic_ns`` and
  ``refresh_on_ns ≥ refresh_off_ns`` on every row (desynchronization,
  activation windows and refresh can only add stalls).
* ``fuse/…`` — cross-op trace fusion: the 8-op chained pipeline compiled
  to one fused ``LoweredTrace`` (row-allocation reuse across op seams) vs
  the per-op execution of the identical chain.  The gates require
  ``fuse_fused_gops ≥ fuse_unfused_gops`` with ``fuse_elided_hops > 0``
  (fusion must actually remove inter-op relocations) and, under the
  refresh-phased replay clock, ``fuse_fused_replay_ns ≤
  fuse_unfused_replay_ns`` (one concatenated command stream cannot replay
  slower than the same stream issued per-op).
* ``sched/…`` — the bank-level scheduler: a mixed two-tenant workload
  drained through ``machine.submit()`` packs heterogeneous requests across
  banks, so the aggregate rate must beat the serialized single-stream
  replay of the same requests (``sched_mixed_gops ≥ sched_serial_gops``),
  with per-tenant queue/service latency attribution summing exactly to
  the machine totals; and a refresh-policy A/B under refresh-heavy timing
  where pausing between sequences beats eager issue with mid-sequence
  abort + restart (``sched_stall_ns ≥ sched_aware_ns``)."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.circuits import ALL_OPS, compile_operation
from repro.simdram.timing import DRAMTiming, SimdramPerfModel

from .common import row, timed


# ---------------------------------------------------------------------------
# Measured: backends × fusion × banks
# ---------------------------------------------------------------------------

def _block(x):
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, x)
    return x


def measured(smoke: bool = False) -> None:
    from repro.ops import (bbop_add, bbop_mul, bbop_relu, simdram_pipeline)
    from repro.simdram.layout import reset_transpose_stats, transpose_counts

    n = 1024 if smoke else 8192
    banks_list = (1, 4) if smoke else (1, 16)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    c = jnp.asarray(rng.integers(0, 256, n), jnp.int32)

    # per-backend single-op wall clock (8-bit add)
    backends = ("unrolled", "pallas") if smoke else \
        ("unrolled", "pallas", "reference")
    for be in backends:
        _, us = timed(lambda: _block(bbop_add(a, b, 8, backend=be)),
                      repeat=2 if smoke else 3)
        row(f"measured/backend/{be}/add8/n{n}", us,
            f"melems_per_s={n / us:.2f}")

    # fused chain vs per-op transposes: relu(add(mul(a, b), c))
    def unfused():
        return _block(bbop_relu(bbop_add(bbop_mul(a, b, 8), c, 8), 8))

    def fused():
        with simdram_pipeline() as p:
            pa, pb, pc = p.load([a, b, c], 8)
            return _block(p.store(
                bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8)))

    reset_transpose_stats()
    unfused()
    t_un = sum(transpose_counts())
    reset_transpose_stats()
    fused()
    t_fu = sum(transpose_counts())
    _, us_un = timed(unfused, repeat=2 if smoke else 3)
    _, us_fu = timed(fused, repeat=2 if smoke else 3)
    row(f"measured/unfused/chain3/n{n}", us_un,
        f"transposes_per_call={t_un}")
    row(f"measured/fused/chain3/n{n}", us_fu,
        f"transposes_per_call={t_fu} speedup={us_un / us_fu:.2f}x")

    # same chains under the timed layer: modeled DRAM cost vs wall-clock,
    # side by side.  The unfused chain pays per-op transposition; the fused
    # chain pays inter-op relocations instead.  A LISA hop moves a full
    # 8 kB row while transposition scales with lanes streamed, so below a
    # lane-count crossover the fusion_gain row honestly reports < 1x.
    from repro.core.backends import timed as timed_scope
    with timed_scope() as st_un:
        unfused()
    with simdram_pipeline(timed=True) as p:
        pa, pb, pc = p.load([a, b, c], 8)
        _block(p.store(bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8)))
    st_fu = p.stats
    for tag, st, us in (("unfused", st_un, us_un), ("fused", st_fu, us_fu)):
        row(f"modeled/{tag}/chain3/n{n}", us,
            f"modeled_ns={st.total_ns:.1f} modeled_nj={st.total_nj:.1f} "
            f"modeled_gops={st.gops():.4f} wall_us={us:.1f} "
            f"transpose_ns={st.transpose_ns:.1f} "
            f"movement_ns={st.movement_ns:.1f}")
    row(f"modeled/fusion_gain/chain3/n{n}", 0,
        f"modeled_speedup={st_un.total_ns / st_fu.total_ns:.2f}x "
        f"energy_ratio={st_un.total_nj / max(st_fu.total_nj, 1e-12):.2f}x")

    # multi-bank batch axis (the paper's 16-bank scaling, vmapped)
    for banks in banks_list:
        ab = jnp.asarray(rng.integers(0, 256, (banks, n)), jnp.int32)
        bb = jnp.asarray(rng.integers(0, 256, (banks, n)), jnp.int32)

        def banked():
            with simdram_pipeline(banks=banks) as p:
                pa, pb = p.load([ab, bb], 8)
                return _block(p.store(bbop_add(pa, pb, 8)))

        _, us = timed(banked, repeat=2 if smoke else 3)
        row(f"measured/banked/add8/banks{banks}/n{n}", us,
            f"melems_per_s={banks * n / us:.2f}")


# ---------------------------------------------------------------------------
# Compile/lower cache + trace-replay timing (gated under --smoke)
# ---------------------------------------------------------------------------

def cache_and_replay(smoke: bool = False) -> None:
    from repro.core.trace import (clear_trace_cache, compile_trace,
                                  trace_cache_stats)
    from repro.ops import (bbop_abs, bbop_add, bbop_mul, bbop_relu, bbop_sub,
                           simdram_pipeline)

    n = 512 if smoke else 4096
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, n), jnp.int32)

    def chain8():
        # 8 chained bbops (5 distinct μPrograms) — every call goes through
        # the compile/lower cache, like a decode loop would
        with simdram_pipeline() as p:
            x, y = p.load([a, b], 8)
            t = bbop_add(x, y, 8)
            t = bbop_mul(t, x, 8)
            t = bbop_sub(t, y, 8)
            t = bbop_relu(t, 8)
            t = bbop_add(t, x, 8)
            t = bbop_abs(t, 8)
            t = bbop_sub(t, x, 8)
            t = bbop_relu(t, 8)
            return _block(p.store(t))

    clear_trace_cache()
    t0 = time.perf_counter()
    chain8()                              # cold: synthesis + alloc + lower
    cold_us = (time.perf_counter() - t0) * 1e6
    after_cold = trace_cache_stats()
    _, warm_us = timed(chain8, repeat=2 if smoke else 3)
    st = trace_cache_stats()
    row(f"cache/chain8/n{n}", warm_us,
        f"cold_us={cold_us:.1f} warm_us={warm_us:.1f} "
        f"compile_speedup={cold_us / warm_us:.2f}x "
        f"cache_hits={st['hits']} cache_misses={st['misses']} "
        f"cache_hit_rate={st['hit_rate']:.3f} "
        f"cold_misses={after_cold['misses']}")

    # TraceLint overhead: cold = the one-time static liveness pass a fresh
    # lowering pays under verify=True; warm = the memoized re-check every
    # later cache hit pays.  Gated: lint_cold_us >= lint_warm_us (the memo
    # must actually short-circuit the pass).
    import dataclasses as _dc
    lint_keys = [("addition", 8), ("multiplication", 8), ("relu", 8),
                 ("abs", 8), ("division", 8)]
    lint_traces = [compile_trace(nm, nb, verify=False)[1]
                   for nm, nb in lint_keys]
    n_cmds = sum(t.cmds.shape[0] for t in lint_traces)

    def lint_fresh():
        for t in lint_traces:
            _dc.replace(t, _lint=None).lint()

    _, lint_cold_us = timed(lint_fresh, repeat=2 if smoke else 5)
    _, lint_warm_us = timed(lambda: [t.lint() for t in lint_traces],
                            repeat=2 if smoke else 5)
    row(f"lint/compile_overhead/{len(lint_traces)}ops", lint_cold_us,
        f"lint_cold_us={lint_cold_us:.1f} lint_warm_us={lint_warm_us:.2f} "
        f"lint_memo_speedup={lint_cold_us / max(lint_warm_us, 1e-9):.0f}x "
        f"n_cmds={n_cmds}")

    # session-machine μProgram Memory: an explicit SimdramMachine running
    # the same chain through its own bounded cache — hit rate gated like
    # the process-wide cache above
    from repro.ops import SimdramMachine
    mach = SimdramMachine(backend="unrolled", cache_capacity=16)
    for _ in range(2):
        with mach.pipeline() as p:
            x, y = p.load([a, b], 8)
            _block(p.store(bbop_relu(bbop_add(x, y, 8), 8)))
    cs = mach.cache_stats()
    row(f"machine/cache/n{n}", 0,
        f"cache_hits={cs['hits']} cache_misses={cs['misses']} "
        f"cache_hit_rate={cs['hit_rate']:.3f} entries={cs['entries']} "
        f"capacity={cs['capacity']} evictions={cs['evictions']}")

    # replay-mode pipeline: replayed vs analytic ns/nJ side by side
    with simdram_pipeline(timed=True, model="replay") as p:
        x, y = p.load([a, b], 8)
        _block(p.store(bbop_relu(bbop_add(bbop_mul(x, y, 8), x, 8), 8)))
    ps = p.stats
    row(f"replaypipe/chain3/n{n}", 0,
        f"replay_ns={ps.replay_ns:.1f} analytic_ns={ps.exec_ns:.1f} "
        f"replay_nj={ps.replay_nj:.1f} analytic_nj={ps.exec_nj:.1f} "
        f"stall_ns={ps.replay_stall_ns:.1f}")

    # cross-op refresh phase A/B: the same short-op chain with the replay
    # clock threaded through the refresh grid vs per-op anchoring.  Every
    # op here individually fits inside tREFI, so the anchored run accrues
    # zero refresh stall while the phased run crosses windows mid-chain —
    # the gate requires phased >= anchored (phase can only add stall).
    def _phase_chain(refresh_phase):
        with simdram_pipeline(timed=True, model="replay",
                              refresh_phase=refresh_phase) as p:
            x, y = p.load([a, b], 8)
            t = bbop_add(x, y, 8)
            t = bbop_sub(t, x, 8)
            t = bbop_relu(t, 8)
            t = bbop_add(t, y, 8)
            _block(p.store(t))
        return p.stats

    ph, an = _phase_chain(True), _phase_chain(False)
    row(f"replay/refresh_phase/chain4/n{n}", 0,
        f"refresh_phased_ns={ph.replay_ns:.1f} "
        f"refresh_anchored_ns={an.replay_ns:.1f} "
        f"phased_refresh_stall_ns={ph.replay_refresh_ns:.1f} "
        f"anchored_refresh_stall_ns={an.replay_refresh_ns:.1f}")

    # banked replay-mode pipeline: the desynchronized per-bank streams
    # (rank-coupled FSM array) with their per-bank stall breakdown
    rbanks = 4
    ab = jnp.asarray(rng.integers(0, 256, (rbanks, n)), jnp.int32)
    bb = jnp.asarray(rng.integers(0, 256, (rbanks, n)), jnp.int32)
    with simdram_pipeline(timed=True, model="replay", banks=rbanks) as p:
        x, y = p.load([ab, bb], 8)
        _block(p.store(bbop_relu(bbop_add(x, y, 8), 8)))
    ps = p.stats
    row(f"replaypipe/banked{rbanks}/n{n}", 0,
        f"replay_ns={ps.replay_ns:.1f} analytic_ns={ps.exec_ns:.1f} "
        f"tfaw_stall_ns={ps.replay_tfaw_ns:.1f} "
        f"refresh_stall_ns={ps.replay_refresh_ns:.1f} "
        f"bank_spread_ns={ps.replay_bank_spread_ns:.1f}")

    # per-op trace replay, every Table-5 op: desynchronized per-bank model
    # (tRRD/tFAW/refresh) vs the legacy lockstep broadcast FSM (refresh
    # off) vs the analytic command sum.  The orderings are gated: each
    # modeling layer can only add stalls.
    banks = 8
    m_full = SimdramPerfModel()
    m_lock = SimdramPerfModel(timing=DRAMTiming(desync_policy="lockstep",
                                                tREFI_ns=0.0))
    reps = {}
    for op in ALL_OPS:
        prog, trace = compile_trace(op, 8)
        analytic = m_full.latency_ns(prog)
        rep = reps[op] = m_full.replay_result(trace, banks=banks)
        lock = m_lock.replay_result(trace, banks=banks)
        row(f"replay/{op}/8b", 0,
            f"replay_ns={rep.ns:.2f} lockstep_ns={lock.ns:.2f} "
            f"analytic_ns={analytic:.2f} stall_ns={rep.stall_ns:.2f} "
            f"tfaw_stall_ns={rep.tfaw_stall_ns:.2f} "
            f"refresh_stall_ns={rep.refresh_stall_ns:.2f} "
            f"bank_spread_ns={rep.bank_spread_ns:.2f} banks={banks} "
            f"cycles={rep.cycles} acts={rep.n_acts}")

    # refresh A/B on the longest Table-5 op: periodic tREFI/tRFC windows
    # stall in-flight sequences, so refresh-on can only be slower
    m_noref = SimdramPerfModel(timing=DRAMTiming(tREFI_ns=0.0))
    _, trace = compile_trace("multiplication", 8)
    on = reps["multiplication"]          # already replayed with m_full above
    off = m_noref.replay_result(trace, banks=banks)
    row("replay/refresh_ab/multiplication/8b", 0,
        f"refresh_on_ns={on.ns:.2f} refresh_off_ns={off.ns:.2f} "
        f"refresh_stall_ns={on.refresh_stall_ns:.2f} "
        f"n_refresh_stalls={on.n_refresh_stalls}")

    # vectorized replay engine vs the stepped FSM oracle on the chain8
    # μProgram set: parity is exact-or-absent (the closed form either
    # reproduces the stepped cycle count bit-for-bit or declines and the
    # stepped oracle runs), so the ns delta is gated at exactly zero; and
    # the warm path — the TraceCache replay memo serving the closed-form
    # result as a table lookup — must clear a 100x speedup over
    # re-stepping the same traces edge by edge
    from repro.core.trace import TraceCache
    from repro.simdram.timing import TraceReplayTiming
    rt = TraceReplayTiming(DRAMTiming())
    chain_ops = ("addition", "multiplication", "subtraction", "relu", "abs")
    vtraces = [compile_trace(op, 8)[1] for op in chain_ops]
    memo = TraceCache()
    vbanks = 8
    delta = 0.0
    for tr in vtraces:
        v = rt.replay(tr, banks=vbanks, engine="vectorized", cache=memo)
        s = rt.replay(tr, banks=vbanks, engine="stepped")
        delta += abs(v.ns - s.ns)
    row(f"replay/vector_parity/chain8/{vbanks}bank", 0,
        f"vector_parity_delta_ns={delta:.6f} n_traces={len(vtraces)}")

    def vec_warm():
        for tr in vtraces:
            rt.replay(tr, banks=vbanks, engine="vectorized", cache=memo)

    def step_cold():
        for tr in vtraces:
            rt.replay(tr, banks=vbanks, engine="stepped")

    _, vec_us = timed(vec_warm, repeat=3 if smoke else 10)
    _, step_us = timed(step_cold, repeat=2 if smoke else 3)
    row(f"replay/vector_speedup/chain8/{vbanks}bank", vec_us,
        f"vector_speedup={step_us / vec_us:.1f}x "
        f"vector_warm_us={vec_us:.2f} stepped_us={step_us:.1f}")


# ---------------------------------------------------------------------------
# Cross-op trace fusion: fused single-trace pipeline vs per-op execution
# ---------------------------------------------------------------------------

def fusion_rows(smoke: bool = False) -> None:
    from repro.ops import (bbop_abs, bbop_add, bbop_mul, bbop_relu, bbop_sub,
                           simdram_pipeline)

    n = 512 if smoke else 4096
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, n), jnp.int32)

    def chain8(**pipe_kw):
        with simdram_pipeline(timed=True, **pipe_kw) as p:
            x, y = p.load([a, b], 8)
            t = bbop_add(x, y, 8)
            t = bbop_mul(t, x, 8)
            t = bbop_sub(t, y, 8)
            t = bbop_relu(t, 8)
            t = bbop_add(t, x, 8)
            t = bbop_abs(t, 8)
            t = bbop_sub(t, x, 8)
            t = bbop_relu(t, 8)
            out = _block(p.store(t))
        return out, p.stats

    # fuse/chain8: the whole 8-op pipeline compiled to ONE LoweredTrace —
    # the 7 inter-op LISA relocations become row-allocation reuse, so the
    # fused run must charge strictly fewer movement hops and its modeled
    # rate can only improve.  Gated: fuse_fused_gops >= fuse_unfused_gops
    # and fuse_elided_hops > 0.
    out_un, st_un = chain8()
    out_fu, st_fu = chain8(fused_trace=True)
    if not np.array_equal(np.asarray(out_un), np.asarray(out_fu)):
        raise AssertionError("fused chain8 result != unfused")
    elided = st_un.n_moves_intra - st_fu.n_moves_intra
    if elided != st_fu.n_moves_elided:
        raise AssertionError(
            f"elided-hop accounting drifted: intra delta {elided} vs "
            f"counted {st_fu.n_moves_elided}")
    row(f"fuse/chain8/n{n}", 0,
        f"fuse_fused_gops={st_fu.gops():.4f} "
        f"fuse_unfused_gops={st_un.gops():.4f} "
        f"fuse_elided_hops={elided} "
        f"fused_programs={st_fu.n_programs} "
        f"unfused_programs={st_un.n_programs} "
        f"fused_movement_ns={st_fu.movement_ns:.1f} "
        f"unfused_movement_ns={st_un.movement_ns:.1f}")

    # fuse/replay: the same chain through the cycle-accurate replay clock.
    # Both sides thread the refresh phase across op boundaries
    # (refresh_phase=True) so they replay the identical command stream
    # against the identical refresh grid — per-op anchoring would hand the
    # unfused side a free refresh reset at every seam and the comparison
    # would gate on an artifact, not on fusion.  Gated:
    # fuse_unfused_replay_ns >= fuse_fused_replay_ns.
    _, rp_un = chain8(model="replay", refresh_phase=True)
    _, rp_fu = chain8(model="replay", refresh_phase=True, fused_trace=True)
    row(f"fuse/replay/chain8/n{n}", 0,
        f"fuse_unfused_replay_ns={rp_un.replay_ns:.1f} "
        f"fuse_fused_replay_ns={rp_fu.replay_ns:.1f} "
        f"fused_stall_ns={rp_fu.replay_stall_ns:.1f} "
        f"unfused_stall_ns={rp_un.replay_stall_ns:.1f}")


# ---------------------------------------------------------------------------
# Bank-level scheduler: mixed-tenant submit/drain + refresh-policy A/B
# ---------------------------------------------------------------------------

def scheduler_rows(smoke: bool = False) -> None:
    from repro.core.trace import compile_trace
    from repro.ops import BankScheduler, SimdramMachine
    from repro.simdram.timing import TraceReplayTiming

    n = 512 if smoke else 4096
    n_banks = 8
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, n), jnp.int32)

    # two heterogeneous tenant streams drained through one controller:
    # independent requests pack across banks, so the shared makespan
    # tracks the longest stream instead of the serialized sum
    jobs = [("svcA", "addition"), ("svcB", "multiplication"),
            ("svcA", "maximum"), ("svcB", "relu"),
            ("svcA", "subtraction"), ("svcB", "greater")]
    mach = SimdramMachine(mode="replay")
    futs = [mach.submit(op, *((x,) if op == "relu" else (x, b)),
                        tenant=tenant)
            for (tenant, op), x in zip(jobs, [a] * len(jobs))]
    res = mach.drain(n_banks=n_banks)
    if not all(f.done() and f.timing is not None for f in futs):
        raise AssertionError("drain left unresolved futures")
    # baseline: the same requests replayed back-to-back as one stream
    rt = TraceReplayTiming(mach.timing)
    serial_ns = sum(rt.replay(compile_trace(op, 8)[1]).ns
                    for _, op in jobs)
    ops_total = sum(r.lanes for r in res.requests)
    mixed_gops = ops_total / res.ns
    serial_gops = ops_total / serial_ns
    # per-tenant attribution must reproduce the machine totals exactly —
    # a drifting meter means requests are cross-charging tenants
    ten_total = sum(st.total_ns for st in mach.stats.tenants.values())
    if abs(ten_total - mach.stats.total_ns) > 1e-6 * mach.stats.total_ns:
        raise AssertionError(
            f"tenant PerfStats drifted from machine totals: "
            f"{ten_total} vs {mach.stats.total_ns}")
    ten = res.per_tenant()
    for name, t in sorted(ten.items()):
        row(f"sched/tenant/{name}/{n_banks}bank/n{n}", 0,
            f"n_requests={t['n_requests']} "
            f"mean_queue_ns={t['queue_ns'] / t['n_requests']:.1f} "
            f"mean_service_ns={t['service_ns'] / t['n_requests']:.1f} "
            f"finish_ns={t['finish_ns']:.1f}")
    row(f"sched/mixed/{n_banks}bank/n{n}", 0,
        f"sched_mixed_gops={mixed_gops:.4f} "
        f"sched_serial_gops={serial_gops:.4f} "
        f"makespan_ns={res.ns:.1f} serial_ns={serial_ns:.1f} "
        f"n_requests={res.n_requests} tenants={len(ten)} "
        f"tfaw_stall_ns={res.tfaw_stall_ns:.1f} "
        f"refresh_stall_ns={res.refresh_stall_ns:.1f}")

    # refresh-policy A/B under refresh-heavy timing: eager issue keeps
    # losing in-flight sequences to mid-sequence refresh (abort+restart,
    # wasted ACT slots); pausing between sequences avoids every restart
    t_heavy = dataclasses.replace(DRAMTiming(), tREFI_ns=100.0,
                                  tRFC_ns=30.0)

    def run_policy(pol: str):
        sched = BankScheduler(timing=t_heavy, n_banks=16,
                              refresh_policy=pol)
        mix = ("addition", "multiplication", "relu", "maximum") * 2
        for i, op in enumerate(mix):
            sched.enqueue(compile_trace(op, 8)[1], banks=2,
                          tenant=f"t{i % 2}", name=op)
        return sched.run()

    aware, stall_res = run_policy("aware"), run_policy("stall")
    row("sched/refresh_ab/16bank/8req", 0,
        f"sched_aware_ns={aware.ns:.1f} sched_stall_ns={stall_res.ns:.1f} "
        f"aware_pause_ns={aware.refresh_stall_ns:.1f} "
        f"stall_restarts={stall_res.n_restarts} "
        f"stall_wasted_acts={stall_res.n_acts - aware.n_acts}")


# ---------------------------------------------------------------------------
# Live Fig. 9/10-style rows: speedup/efficiency from the executed pipeline
# ---------------------------------------------------------------------------

def live(smoke: bool = False) -> None:
    from repro.ops import (bbop_add, bbop_greater, bbop_mul, bbop_relu,
                           simdram_pipeline)

    n = 512 if smoke else 4096
    banks = 16
    rng = np.random.default_rng(1)
    cases = [("addition", 8, 2, bbop_add), ("relu", 8, 1, bbop_relu)]
    if not smoke:
        cases += [("multiplication", 8, 2, bbop_mul),
                  ("greater", 8, 2, bbop_greater),
                  ("addition", 32, 2, bbop_add)]
    for name, n_bits, arity, fn in cases:
        hi = 1 << n_bits
        xs = [jnp.asarray(rng.integers(0, hi, n), jnp.int32)
              for _ in range(arity)]

        def run():
            with simdram_pipeline(timed=True) as p:
                ops = p.load(xs, n_bits) if arity > 1 else [p.load(xs[0],
                                                                   n_bits)]
                _block(p.store(fn(*ops, n_bits)))
            return p.stats

        st, us = timed(run, repeat=2 if smoke else 3)
        m = st.model
        # rowscale/efficiency derive from the LIVE-charged per-op cost, not
        # a detached model pass: if charging regresses (hooks stop firing,
        # zero exec_ns) these go 0/non-finite and the --smoke gate fires
        live = st.per_op[f"{name}/{n_bits}b"]
        exec_ns, exec_nj = live["ns"] / live["calls"], live["nj"] / live["calls"]
        rowscale = m.timing.row_bits * banks / exec_ns
        cpu, gpu = m.cpu_gops(name, n_bits), m.gpu_gops(name, n_bits)
        row(f"fig9live/{name}/{n_bits}b/n{n}", us,
            f"modeled_gops={st.gops():.4f} modeled_ns={st.total_ns:.1f} "
            f"rowscale16_gops={rowscale:.2f} cpu_gops={cpu:.2f} "
            f"gpu_gops={gpu:.2f} speedup_cpu={rowscale / cpu:.1f}x "
            f"speedup_gpu={rowscale / gpu:.1f}x wall_us={us:.1f}")
        power_w = (exec_nj / exec_ns + m.energy.background_w) * banks
        spw = rowscale / power_w
        cpw = m.cpu_gops_per_watt(name, n_bits)
        gpw = m.gpu_gops_per_watt(name, n_bits)
        row(f"fig10live/{name}/{n_bits}b", 0,
            f"gops_per_w={spw:.2f} cpu_gops_per_w={cpw:.3f} "
            f"gpu_gops_per_w={gpw:.3f} eff_cpu={spw / cpw:.0f}x "
            f"eff_gpu={spw / gpw:.1f}x")


def main(smoke: bool = False) -> None:
    measured(smoke=smoke)
    cache_and_replay(smoke=smoke)
    fusion_rows(smoke=smoke)
    scheduler_rows(smoke=smoke)
    live(smoke=smoke)
    if smoke:
        return
    m = SimdramPerfModel()
    print("# Fig. 9 — GOps/s (32-bit elements)")
    sums = {k: 0.0 for k in ("s1", "s4", "s16", "cpu", "gpu", "ambit")}
    n_ops = 0
    for op in ALL_OPS:
        prog = compile_operation(op, 32)
        amb = compile_operation(op, 32, optimize=False)
        s1 = m.throughput_gops(prog, 1)
        s16 = m.throughput_gops(prog, 16)
        cpu = m.cpu_gops(op, 32)
        gpu = m.gpu_gops(op, 32)
        a1 = m.throughput_gops(amb, 1)
        sums["s1"] += s1 / cpu
        sums["s4"] += m.throughput_gops(prog, 4) / cpu
        sums["s16"] += s16 / cpu
        sums["gpu"] += gpu / cpu
        sums["ambit"] += s1 / a1
        n_ops += 1
        row(f"fig9/{op}/32b", 0,
            f"simdram1={s1:.2f} simdram16={s16:.2f} cpu={cpu:.2f} "
            f"gpu={gpu:.2f} ambit1={a1:.2f}")
    row("fig9/avg_vs_cpu", 0,
        f"simdram1={sums['s1']/n_ops:.1f}x simdram4={sums['s4']/n_ops:.1f}x "
        f"simdram16={sums['s16']/n_ops:.1f}x gpu={sums['gpu']/n_ops:.1f}x "
        f"(paper: 5.5x/22x/88x; gpu 15.9x)")
    row("fig9/avg_vs_ambit", 0,
        f"simdram1={sums['ambit']/n_ops:.2f}x (paper: 2.0x)")
    # element-size scaling (Fig. 9 right)
    for n in (8, 16, 32, 64):
        cls = {1: [], 2: [], 3: []}
        from repro.core.circuits import CLASS_OF
        for op in ALL_OPS:
            if op == "division" and n > 32:
                continue
            t = m.throughput_gops(compile_operation(op, n), 1)
            cls[CLASS_OF[op]].append(t)
        row(f"fig9/scaling/n{n}", 0,
            " ".join(f"class{c}={sum(v)/len(v):.2f}" for c, v in cls.items()
                     if v))


if __name__ == "__main__":
    main()
