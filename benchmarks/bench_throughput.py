"""Paper Fig. 9: throughput of the 16 operations — SIMDRAM:1/4/16 vs the
CPU/GPU bandwidth-roofline baselines and the Ambit baseline — plus the
*measured* section: wall-clock of the executable backends (unrolled /
pallas-interpret / reference oracle), fused plane-resident pipelines vs the
per-op transpose round-trip, and the multi-bank batch axis."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.circuits import ALL_OPS, compile_operation
from repro.simdram.timing import SimdramPerfModel

from .common import row, timed


# ---------------------------------------------------------------------------
# Measured: backends × fusion × banks
# ---------------------------------------------------------------------------

def _block(x):
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, x)
    return x


def measured(smoke: bool = False) -> None:
    from repro.ops import (bbop_add, bbop_mul, bbop_relu, simdram_pipeline)
    from repro.simdram.layout import reset_transpose_stats, transpose_counts

    n = 1024 if smoke else 8192
    banks_list = (1, 4) if smoke else (1, 16)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    c = jnp.asarray(rng.integers(0, 256, n), jnp.int32)

    # per-backend single-op wall clock (8-bit add)
    backends = ("unrolled", "pallas") if smoke else \
        ("unrolled", "pallas", "reference")
    for be in backends:
        _, us = timed(lambda: _block(bbop_add(a, b, 8, backend=be)),
                      repeat=2 if smoke else 3)
        row(f"measured/backend/{be}/add8/n{n}", us,
            f"melems_per_s={n / us:.2f}")

    # fused chain vs per-op transposes: relu(add(mul(a, b), c))
    def unfused():
        return _block(bbop_relu(bbop_add(bbop_mul(a, b, 8), c, 8), 8))

    def fused():
        with simdram_pipeline() as p:
            pa, pb, pc = p.load([a, b, c], 8)
            return _block(p.store(
                bbop_relu(bbop_add(bbop_mul(pa, pb, 8), pc, 8), 8)))

    reset_transpose_stats()
    unfused()
    t_un = sum(transpose_counts())
    reset_transpose_stats()
    fused()
    t_fu = sum(transpose_counts())
    _, us_un = timed(unfused, repeat=2 if smoke else 3)
    _, us_fu = timed(fused, repeat=2 if smoke else 3)
    row(f"measured/unfused/chain3/n{n}", us_un,
        f"transposes_per_call={t_un}")
    row(f"measured/fused/chain3/n{n}", us_fu,
        f"transposes_per_call={t_fu} speedup={us_un / us_fu:.2f}x")

    # multi-bank batch axis (the paper's 16-bank scaling, vmapped)
    for banks in banks_list:
        ab = jnp.asarray(rng.integers(0, 256, (banks, n)), jnp.int32)
        bb = jnp.asarray(rng.integers(0, 256, (banks, n)), jnp.int32)

        def banked():
            with simdram_pipeline(banks=banks) as p:
                pa, pb = p.load([ab, bb], 8)
                return _block(p.store(bbop_add(pa, pb, 8)))

        _, us = timed(banked, repeat=2 if smoke else 3)
        row(f"measured/banked/add8/banks{banks}/n{n}", us,
            f"melems_per_s={banks * n / us:.2f}")


def main(smoke: bool = False) -> None:
    measured(smoke=smoke)
    if smoke:
        return
    m = SimdramPerfModel()
    print("# Fig. 9 — GOps/s (32-bit elements)")
    sums = {k: 0.0 for k in ("s1", "s4", "s16", "cpu", "gpu", "ambit")}
    n_ops = 0
    for op in ALL_OPS:
        prog = compile_operation(op, 32)
        amb = compile_operation(op, 32, optimize=False)
        s1 = m.throughput_gops(prog, 1)
        s16 = m.throughput_gops(prog, 16)
        cpu = m.cpu_gops(op, 32)
        gpu = m.gpu_gops(op, 32)
        a1 = m.throughput_gops(amb, 1)
        sums["s1"] += s1 / cpu
        sums["s4"] += m.throughput_gops(prog, 4) / cpu
        sums["s16"] += s16 / cpu
        sums["gpu"] += gpu / cpu
        sums["ambit"] += s1 / a1
        n_ops += 1
        row(f"fig9/{op}/32b", 0,
            f"simdram1={s1:.2f} simdram16={s16:.2f} cpu={cpu:.2f} "
            f"gpu={gpu:.2f} ambit1={a1:.2f}")
    row("fig9/avg_vs_cpu", 0,
        f"simdram1={sums['s1']/n_ops:.1f}x simdram4={sums['s4']/n_ops:.1f}x "
        f"simdram16={sums['s16']/n_ops:.1f}x gpu={sums['gpu']/n_ops:.1f}x "
        f"(paper: 5.5x/22x/88x; gpu 15.9x)")
    row("fig9/avg_vs_ambit", 0,
        f"simdram1={sums['ambit']/n_ops:.2f}x (paper: 2.0x)")
    # element-size scaling (Fig. 9 right)
    for n in (8, 16, 32, 64):
        cls = {1: [], 2: [], 3: []}
        from repro.core.circuits import CLASS_OF
        for op in ALL_OPS:
            if op == "division" and n > 32:
                continue
            t = m.throughput_gops(compile_operation(op, n), 1)
            cls[CLASS_OF[op]].append(t)
        row(f"fig9/scaling/n{n}", 0,
            " ".join(f"class{c}={sum(v)/len(v):.2f}" for c, v in cls.items()
                     if v))


if __name__ == "__main__":
    main()
