"""Paper Fig. 9: throughput of the 16 operations — SIMDRAM:1/4/16 vs the
CPU/GPU bandwidth-roofline baselines and the Ambit baseline."""
from __future__ import annotations

from repro.core.circuits import ALL_OPS, compile_operation
from repro.simdram.timing import SimdramPerfModel

from .common import row


def main() -> None:
    m = SimdramPerfModel()
    print("# Fig. 9 — GOps/s (32-bit elements)")
    sums = {k: 0.0 for k in ("s1", "s4", "s16", "cpu", "gpu", "ambit")}
    n_ops = 0
    for op in ALL_OPS:
        prog = compile_operation(op, 32)
        amb = compile_operation(op, 32, optimize=False)
        s1 = m.throughput_gops(prog, 1)
        s16 = m.throughput_gops(prog, 16)
        cpu = m.cpu_gops(op, 32)
        gpu = m.gpu_gops(op, 32)
        a1 = m.throughput_gops(amb, 1)
        sums["s1"] += s1 / cpu
        sums["s4"] += m.throughput_gops(prog, 4) / cpu
        sums["s16"] += s16 / cpu
        sums["gpu"] += gpu / cpu
        sums["ambit"] += s1 / a1
        n_ops += 1
        row(f"fig9/{op}/32b", 0,
            f"simdram1={s1:.2f} simdram16={s16:.2f} cpu={cpu:.2f} "
            f"gpu={gpu:.2f} ambit1={a1:.2f}")
    row("fig9/avg_vs_cpu", 0,
        f"simdram1={sums['s1']/n_ops:.1f}x simdram4={sums['s4']/n_ops:.1f}x "
        f"simdram16={sums['s16']/n_ops:.1f}x gpu={sums['gpu']/n_ops:.1f}x "
        f"(paper: 5.5x/22x/88x; gpu 15.9x)")
    row("fig9/avg_vs_ambit", 0,
        f"simdram1={sums['ambit']/n_ops:.2f}x (paper: 2.0x)")
    # element-size scaling (Fig. 9 right)
    for n in (8, 16, 32, 64):
        cls = {1: [], 2: [], 3: []}
        from repro.core.circuits import CLASS_OF
        for op in ALL_OPS:
            if op == "division" and n > 32:
                continue
            t = m.throughput_gops(compile_operation(op, n), 1)
            cls[CLASS_OF[op]].append(t)
        row(f"fig9/scaling/n{n}", 0,
            " ".join(f"class{c}={sum(v)/len(v):.2f}" for c, v in cls.items()
                     if v))


if __name__ == "__main__":
    main()
