"""Paper Fig. 10: energy efficiency (GOps/s per Watt) vs CPU/GPU/Ambit."""
from __future__ import annotations

from repro.core.circuits import ALL_OPS, compile_operation
from repro.simdram.timing import SimdramPerfModel

from .common import row


def main() -> None:
    m = SimdramPerfModel()
    print("# Fig. 10 — Throughput per Watt (32-bit)")
    agg = {"cpu": 0.0, "gpu": 0.0, "ambit": 0.0}
    for op in ALL_OPS:
        prog = compile_operation(op, 32)
        amb = compile_operation(op, 32, optimize=False)
        s = m.throughput_per_watt(prog)
        c = m.cpu_gops_per_watt(op, 32)
        g = m.gpu_gops_per_watt(op, 32)
        a = m.throughput_per_watt(amb)
        agg["cpu"] += s / c
        agg["gpu"] += s / g
        agg["ambit"] += s / a
        row(f"fig10/{op}/32b", 0,
            f"simdram={s:.2f} cpu={c:.3f} gpu={g:.3f} ambit={a:.2f}")
    n = len(ALL_OPS)
    row("fig10/avg", 0,
        f"vs_cpu={agg['cpu']/n:.0f}x vs_gpu={agg['gpu']/n:.1f}x "
        f"vs_ambit={agg['ambit']/n:.2f}x (paper: 257x / 31x / 2.6x)")


if __name__ == "__main__":
    main()
