"""Serving-layer benchmark: continuous-batching decode over a pool of
bank-sharded SIMDRAM machines (the PR-10 tentpole), gated in --smoke.

Rows
----
* ``serve/batched`` — the headline gate: aggregate modeled tokens/s with
  N concurrent users continuously batched into the bank axis vs the same
  sessions served one at a time (``serve_batched_tokens_per_s >=
  serve_sequential_tokens_per_s``; bank-level packing of independent
  decode steps cannot lower throughput).
* ``serve/p99`` — the SLO surface: modeled p50/p99 ns-per-token and
  time-to-first-token percentiles at N users (finite, ``serve_p99_ns >=
  serve_p50_ns`` by construction of a percentile).
* ``serve/memo`` — the whole-schedule memo at work: a steady-state
  decode loop's repeated busy periods must mostly hit
  (``sched_memo_hit_rate``), which is what keeps the serving loop from
  re-stepping the scheduler event loop per session per step.

All throughput/latency values are modeled ns (deterministic); the
``us_per_call`` column is the host wall time of the serving loop.
"""
from __future__ import annotations

import time

from repro.serve import SimdramServer

from .common import row

MIX = ["qwen1_5_0_5b", "mamba2_130m", "whisper_large_v3", "olmoe_1b_7b"]


def _spawn(server: SimdramServer, users: int, tokens: int) -> None:
    for u in range(users):
        server.submit_session(MIX[u % len(MIX)], n_tokens=tokens,
                              arrival_ns=u * 200.0, seed=u)


def main(smoke: bool = False) -> None:
    users = 8
    machines = 2
    banks = 8
    tokens = 4 if smoke else 8

    batched = SimdramServer(n_machines=machines, n_banks=banks)
    _spawn(batched, users, tokens)
    t0 = time.perf_counter()
    stats = batched.run()
    wall_us = (time.perf_counter() - t0) * 1e6

    # sequential baseline: the same sessions (same seeds, same work),
    # each served alone — total tokens over the summed solo spans
    seq_span = 0.0
    for u in range(users):
        solo = SimdramServer(n_machines=1, n_banks=banks)
        solo.submit_session(MIX[u % len(MIX)], n_tokens=tokens, seed=u)
        seq_span += solo.run().span_ns
    seq_tps = stats.total_tokens / seq_span * 1e9

    row(f"serve/batched/u{users}m{machines}", wall_us,
        f"serve_batched_tokens_per_s={stats.tokens_per_s:.1f} "
        f"serve_sequential_tokens_per_s={seq_tps:.1f} "
        f"users={users} machines={machines} banks={banks} "
        f"tokens={stats.total_tokens} span_ns={stats.span_ns:.1f}")
    row(f"serve/p99/u{users}m{machines}", wall_us,
        f"serve_p99_ns={stats.p99_token_ns:.1f} "
        f"serve_p50_ns={stats.p50_token_ns:.1f} "
        f"ttft_p99_ns={stats.p99_ttft_ns:.1f} "
        f"ttft_p50_ns={stats.p50_ttft_ns:.1f} users={users}")

    hits = sum(m["cache"]["schedule_hits"] for m in stats.machines)
    misses = sum(m["cache"]["schedule_misses"] for m in stats.machines)
    rate = hits / (hits + misses) if hits + misses else 0.0
    steps = sum(m["steps"] for m in stats.machines)
    row(f"serve/memo/u{users}m{machines}", wall_us,
        f"sched_memo_hit_rate={rate:.3f} sched_memo_hits={hits} "
        f"sched_memo_misses={misses} steps={steps}")


if __name__ == "__main__":
    main()
