"""Paper Fig. 13: worst-case intra-bank (LISA) and inter-bank (RowClone PSM)
data-movement overhead as a fraction of operation latency."""
from __future__ import annotations

import numpy as np

from repro.core.circuits import ALL_OPS, compile_operation
from repro.simdram.timing import MovementModel, SimdramPerfModel

from .common import row


def main() -> None:
    m = SimdramPerfModel()
    mv = MovementModel()
    print("# Fig. 13 — data-movement overhead (% of op latency)")
    intra_all, inter_all = [], []
    for op in ALL_OPS:
        intra, inter = [], []
        for n in (8, 16, 32, 64):
            if op == "division" and n > 32:
                continue
            t_op = m.latency_ns(compile_operation(op, n))
            t_intra = mv.intra_bank_ns(n)     # move the n result rows
            t_inter = mv.inter_bank_ns(n)
            intra.append(100 * t_intra / (t_op + t_intra))
            inter.append(100 * t_inter / (t_op + t_inter))
        intra_all += intra
        inter_all += inter
        row(f"fig13/{op}", 0,
            f"intra={np.mean(intra):.2f}% inter={np.mean(inter):.2f}% "
            f"(max intra={max(intra):.2f}% inter={max(inter):.2f}%)")
    row("fig13/avg", 0,
        f"intra={np.mean(intra_all):.2f}% inter={np.mean(inter_all):.1f}% "
        f"(paper: 0.39% / 17.5%)")


if __name__ == "__main__":
    main()
