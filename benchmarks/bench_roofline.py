"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun_baseline.json (produced by repro.launch.dryrun --all)
and prints the per-cell three-term roofline."""
from __future__ import annotations

import json
import os

from .common import row

DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun_baseline.json")


def main(path: str | None = None) -> None:
    path = path or os.environ.get("DRYRUN_JSON", DEFAULT)
    if not os.path.exists(path):
        row("roofline/missing", 0, f"no dry-run artifact at {path}")
        return
    cells = json.load(open(path))
    print("# §Roofline — per (arch × shape), single-pod 16x16")
    for r in cells:
        if r.get("skipped") or "error" in r or r.get("mesh") != "16x16":
            continue
        ratio = r.get("useful_flops_ratio")
        row(f"roofline/{r['arch']}/{r['shape']}", 0,
            f"t_comp={r['t_compute_s']:.3e}s t_mem={r['t_memory_s']:.3e}s "
            f"t_coll={r['t_collective_s']:.3e}s dom={r['dominant']} "
            f"useful={ratio:.3f}" if ratio else "n/a")


if __name__ == "__main__":
    main()
